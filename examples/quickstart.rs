//! Quickstart — the 60-second tour (paper Fig. 1 + Listing 6).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Seeds a raw table, runs the paper's typed pipeline transactionally on
//! a feature branch, reviews the diff, and merges to production.

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== quickstart: a correct-by-design pipeline run ==\n");

    // One Client = the whole vertically-integrated lakehouse.
    let client = Client::open("artifacts")?;

    // Ingest: 3 batches x 1500 rows of synthetic taxi-ish events.
    client.seed_raw_table("main", 3, 1500)?;
    println!("[1] seeded raw_table on main");

    // Develop on a branch — production is never touched.
    let feature = client.create_branch("feature/quickstart", "main")?;
    println!("[2] created branch '{feature}' (zero-copy)");

    // Run the typed DAG: parent (SQL SUM..GROUP BY via the Pallas
    // one-hot-matmul kernel) -> child -> grand_child (explicit cast).
    let run = client.run_text(PAPER_PIPELINE_TEXT, &feature)?;
    println!(
        "[3] run {} finished: {:?}\n    outputs: {:?}",
        run.run_id, run.status, run.outputs
    );
    assert!(run.is_success());

    // Review the data PR.
    let diff = client.diff("main", &feature)?;
    println!("[4] PR diff vs main:");
    for d in &diff {
        println!("      {d:?}");
    }

    // Land it: atomic, pointer-only.
    client.merge(&feature, "main")?;
    println!("[5] merged into main");

    // Inspect the published tables.
    let head = client.catalog.read_ref("main")?;
    for t in ["parent_table", "child_table", "grand_child"] {
        let table = client.worker.read_table(&head, t)?;
        println!(
            "      {t:<14} rows={:<4} schema={}",
            table.row_count(),
            table.schema_name
        );
    }

    println!("\nhistory of main:");
    for c in client.log("main", 10)? {
        println!("  {}  {}", &c.id[..12], c.message);
    }
    Ok(())
}
