//! Agent counterexample — Fig. 4 + the paper's §4 model, end to end.
//!
//! Part 1 runs the bounded model checker on all four scenarios and
//! prints the shortest counterexample traces (the rust analogue of the
//! Alloy analyzer output).
//!
//! Part 2 replays the Fig. 4 trace on the *real* system twice: once with
//! the visibility guardrail (the agent is refused) and once with the
//! `allow_aborted` capability (the inconsistency materializes) — showing
//! model and implementation agree.

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::model::{check, Scenario};
use bauplan::runs::{FailurePlan, RunMode, RunStatus};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== Fig. 4: nested branches vs transactions ==\n");

    // ---------------- part 1: the model checker ---------------------------
    for sc in [
        Scenario::direct_writes(),
        Scenario::paper_protocol(),
        Scenario::counterexample(),
        Scenario::counterexample_fixed(),
    ] {
        let out = check(&sc);
        println!("model {:<30} states={:<7} depth={}",
                 out.scenario, out.states_explored, out.max_depth_reached);
        match &out.violation {
            Some(t) => println!("  VIOLATION (shortest trace):\n{}", t.render()),
            None => println!("  safe within scope\n"),
        }
    }

    // ---------------- part 2: replay on the real system -------------------
    println!("-- replaying Fig. 4 on the real catalog --\n");
    let client = Client::open("artifacts")?;
    client.seed_raw_table("main", 2, 1000)?;
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;

    // run_1 publishes atomically; run_2 aborts mid-run
    let r1 = client.run_plan(&plan, "main", RunMode::Transactional,
                             &FailurePlan::none(), &[])?;
    let r2 = client.run_plan(&plan, "main", RunMode::Transactional,
                             &FailurePlan::crash_after("parent_table"), &[])?;
    println!("run_1: {:?}", r1.status);
    println!("run_2: {:?}", r2.status);
    let RunStatus::Aborted { txn_branch, .. } = &r2.status else { unreachable!() };

    // the agent sees the dangling branch and tries to work off it
    println!("\n[agent] create_branch('agent/work', from='{txn_branch}')");
    match client.catalog.create_branch("agent/work", txn_branch, false) {
        Err(e) => println!("  GUARDRAIL: {e}"),
        Ok(_) => println!("  allowed?!"),
    }

    // with the explicit capability (≈ a system lacking the guardrail)
    println!("\n[agent] same fork with allow_aborted=true (no-guardrail world):");
    client.catalog.create_branch("agent/work", txn_branch, true)?;
    client.catalog.merge("agent/work", "main", false)?;
    let head = client.catalog.read_ref("main")?;
    let mut writers = std::collections::BTreeSet::new();
    for (t, s) in &head.tables {
        if t == "raw_table" { continue; }
        let snap = client.catalog.get_snapshot(s)?;
        println!("  main.{t:<14} written_by={}", snap.run_id);
        writers.insert(snap.run_id.clone());
    }
    println!("\n  distinct writers visible on main: {} => {}",
             writers.len(),
             if writers.len() > 1 { "GLOBALLY INCONSISTENT (Fig. 4)" } else { "consistent" });
    assert!(writers.len() > 1);
    Ok(())
}
