// probe: 4 concurrent transactional runs on distinct branches, pool=1 vs 2 vs 4
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;
use bauplan::catalog::Catalog;
use bauplan::client::Client;
use bauplan::contracts::schema::SchemaRegistry;
use bauplan::control_plane::ControlPlane;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode, Runner};
use bauplan::runtime::ExecHandle;
use bauplan::storage::ObjectStore;
use bauplan::worker::Worker;

fn main() {
    for pool in [1usize, 2, 4] {
        let runtime = Arc::new(ExecHandle::start_pool(Path::new("artifacts"), pool).unwrap());
        let catalog = Catalog::new(Arc::new(ObjectStore::new()));
        let registry = SchemaRegistry::with_paper_schemas();
        let worker = Worker::new(runtime.clone(), catalog.clone(), registry).with_lineage_skipping().unwrap();
        let control_plane = ControlPlane::new(runtime.clone());
        let runner = Runner::new(catalog.clone(), worker.clone());
        let client = Client { catalog, runtime, control_plane, runner, worker };
        client.seed_raw_table("main", 4, 1800).unwrap();
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
        // warmup
        client.run_plan(&plan, "main", RunMode::Transactional, &FailurePlan::none(), &[]).unwrap();
        let t0 = Instant::now();
        let mut handles = vec![];
        for i in 0..4 {
            let c = client.clone();
            let p = plan.clone();
            let b = format!("w{i}");
            c.create_branch(&b, "main").unwrap();
            handles.push(std::thread::spawn(move || {
                for _ in 0..5 {
                    c.run_plan(&p, &b, RunMode::Transactional, &FailurePlan::none(), &[]).unwrap();
                }
            }));
        }
        for h in handles { h.join().unwrap(); }
        let dt = t0.elapsed();
        println!("pool={pool}: 20 concurrent runs in {dt:?} = {:.1} runs/s", 20.0 / dt.as_secs_f64());
    }
}
