//! Collaboration — Git-for-data workflows (paper §3.2, Fig. 2).
//!
//! Demonstrates: feature branches, data PRs with review diffs, tags,
//! point-in-time reproduction of a production run (`get_run` →
//! branch-from-start-commit → identical outputs), and the zero-copy
//! nature of all of it (object-store byte counters as witnesses).

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== collaboration: Git-for-data (Fig. 2) ==\n");
    let client = Client::open("artifacts")?;
    client.seed_raw_table("main", 4, 1800)?;

    // -- experimentation: agent proposes on a branch ---------------------
    let agent_branch = client.create_branch("agent/proposal-1", "main")?;
    let run = client.run_text(PAPER_PIPELINE_TEXT, &agent_branch)?;
    println!("[agent] proposed pipeline on '{agent_branch}': {:?}", run.status);

    // -- human review: the PR diff ----------------------------------------
    println!("[human] reviewing data PR:");
    for d in client.diff("main", &agent_branch)? {
        println!("          {d:?}");
    }
    // verification is cheap: query the proposed tables directly
    let head = client.catalog.read_ref(&agent_branch)?;
    let grand = client.worker.read_table(&head, "grand_child")?;
    println!("[human] spot-check grand_child: {} rows, schema {}",
             grand.row_count(), grand.schema_name);

    // -- land + tag the release -------------------------------------------
    client.merge(&agent_branch, "main")?;
    client.tag("release-2026-07-10", "main")?;
    println!("[human] merged + tagged release-2026-07-10");

    // -- zero-copy evidence -------------------------------------------------
    let store = client.catalog.store();
    let bytes_before = store.stored_bytes();
    for i in 0..25 {
        client.create_branch(&format!("dev/scratch-{i}"), "main")?;
    }
    println!("\n[zero-copy] 25 new branches, bytes added to the lake: {}",
             store.stored_bytes() - bytes_before);

    // -- reproduce production from a run_id ---------------------------------
    println!("\n[repro] production incident workflow (Listing 6):");
    let prod_state = client.get_run(&run.run_id).expect("run recorded");
    println!("  get_run({}) -> start_commit {}, code {}",
             prod_state.run_id, &prod_state.start_commit[..12], &prod_state.code_hash[..12]);
    let debug = client.create_branch("repro/incident-42", &prod_state.start_commit)?;
    let rerun = client.run_text(PAPER_PIPELINE_TEXT, &debug)?;
    println!("  re-ran same code on same data: {:?}", rerun.status);

    // identical outputs, bit for bit
    let orig = client.catalog.read_ref("release-2026-07-10")?;
    let repro = client.catalog.read_ref(&debug)?;
    let a = client.catalog.get_snapshot(&orig.tables["grand_child"])?;
    let b = client.catalog.get_snapshot(&repro.tables["grand_child"])?;
    println!("  grand_child data objects identical: {}", a.objects == b.objects);
    assert_eq!(a.objects, b.objects);

    // time travel: the tag still resolves to the released state
    println!("\n[time-travel] diff release..main is empty: {}",
             client.diff("release-2026-07-10", "main")?.is_empty());
    Ok(())
}
