//! Failure injection — Fig. 3 top vs bottom, live, plus the durability
//! act: a process killed mid-run and recovered from the commit journal.
//!
//! Acts 1–2 run the identical pipeline with the identical mid-run crash
//! under both publication modes and print what downstream readers of
//! `main` observe (experiment E3/E4 in demo form; `bench_consistency`
//! quantifies it over hundreds of runs). They need the PJRT runtime
//! (`make artifacts` + the real `xla` crate) and are skipped when it is
//! unavailable. Act 3 needs only the catalog: it kills a "process"
//! between journal appends and shows `Catalog::recover` rebuilding a
//! consistent head — the target branch untouched, the orphaned
//! transactional branch `Aborted`, never half-merged.

use bauplan::catalog::{BranchState, Catalog, CommitRequest, Snapshot, MAIN};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};

fn describe_main(client: &Client, label: &str) {
    let head = client.catalog.read_ref("main").unwrap();
    println!("  {label}: main holds {} table(s):", head.tables.len());
    for (t, s) in &head.tables {
        let snap = client.catalog.get_snapshot(s).unwrap();
        println!("    {t:<14} written_by={}", snap.run_id);
    }
}

/// Acts 1–2: the live pipeline under both publication modes.
fn live_pipeline_acts() -> Result<(), Box<dyn std::error::Error>> {
    // ---------------- Fig. 3 top: direct writes (today's lakehouses) -----
    {
        let client = Client::open("artifacts")?;
        client.seed_raw_table("main", 2, 1000)?;
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;

        // run_1 succeeds
        let r1 = client.run_plan(&plan, "main", RunMode::DirectWrite,
                                 &FailurePlan::none(), &[])?;
        println!("[direct] run_1 {}: {:?}", r1.run_id, r1.status);

        // run_2 crashes after updating parent_table
        let r2 = client.run_plan(&plan, "main", RunMode::DirectWrite,
                                 &FailurePlan::crash_after("parent_table"), &[])?;
        println!("[direct] run_2 {}: {:?}", r2.run_id, r2.status);
        describe_main(&client, "reader view");
        println!("  => parent_table is run_2's, child/grand are run_1's: the");
        println!("     globally inconsistent state {{P**, C*, G*}} of Fig. 3.\n");
    }

    // ---------------- Fig. 3 bottom: transactional runs -------------------
    {
        let client = Client::open("artifacts")?;
        client.seed_raw_table("main", 2, 1000)?;
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;

        let r1 = client.run_plan(&plan, "main", RunMode::Transactional,
                                 &FailurePlan::none(), &[])?;
        println!("[txn]    run_1 {}: {:?}", r1.run_id, r1.status);

        let r2 = client.run_plan(&plan, "main", RunMode::Transactional,
                                 &FailurePlan::crash_after("parent_table"), &[])?;
        println!("[txn]    run_2 {}: {:?}", r2.run_id, r2.status);
        describe_main(&client, "reader view");
        println!("  => every table still run_1's — total failure, no partial state.");

        // triage: the aborted branch is queryable
        if let RunStatus::Aborted { txn_branch, .. } = &r2.status {
            let head = client.catalog.read_ref(txn_branch)?;
            println!("\n[triage] aborted branch '{txn_branch}' retains the partial run:");
            for t in head.tables.keys() {
                println!("    {t}");
            }
            let p = client.worker.read_table(&head, "parent_table")?;
            println!("  faulty intermediate parent_table queryable: {} rows", p.row_count());
        }
    }
    Ok(())
}

/// Act 3: kill -9 between journal append and checkpoint, then recover.
fn durability_act() -> Result<(), Box<dyn std::error::Error>> {
    println!("\n== durability: kill mid-run, recover from the commit journal ==\n");
    let dir = std::env::temp_dir().join(format!("bpl_failure_demo_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let pre_head;
    let pre_export;
    {
        // "process 1": a durable lake takes writes, then a run is killed
        let cat = Catalog::recover(&dir)?;
        let key = cat.store().put(vec![7; 256]);
        cat.commit(CommitRequest::new(MAIN, "raw_table",
                                      Snapshot::new(vec![key], "Raw", "fp", 1, "seed"))
                   .author("seed").message("ingest"))?;
        cat.checkpoint()?;
        // a second write lands in the journal tail, past the checkpoint
        let key2 = cat.store().put(vec![8; 256]);
        cat.commit(CommitRequest::new(MAIN, "features",
                                      Snapshot::new(vec![key2], "F", "fp", 1, "etl"))
                   .author("etl").message("derive features"))?;
        // A transactional run dies mid-flight. Preferred path: the real
        // run engine with FailurePlan::kill_after (needs PJRT); fallback:
        // the same journal footprint written at catalog level.
        match Client::open_with_catalog("artifacts", cat.clone()) {
            Ok(client) => {
                client.seed_raw_table(MAIN, 1, 500)?;
                pre_head = cat.resolve(MAIN)?;
                pre_export = cat.export().to_string();
                let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;
                let killed = client.run_plan(&plan, MAIN, RunMode::Transactional,
                                             &FailurePlan::kill_after("parent_table"), &[]);
                println!("[proc 1] pipeline killed mid-run: {}",
                         killed.err().map(|e| e.to_string()).unwrap_or_default());
            }
            Err(_) => {
                // no PJRT: hand-write the run's journal footprint
                pre_head = cat.resolve(MAIN)?;
                pre_export = cat.export().to_string();
                cat.create_txn_branch(MAIN, "r_kill")?;
                let key3 = cat.store().put(vec![9; 256]);
                cat.commit(CommitRequest::new("txn/r_kill", "parent_table",
                                              Snapshot::new(vec![key3], "P", "fp", 1, "r_kill"))
                           .author("runner").message("run r_kill: write parent_table")
                           .run_id(Some("r_kill".into())))?;
            }
        }
        println!("[proc 1] wrote main ({} journal records), txn run in flight...",
                 cat.journal_stats().map(|s| s.last_seq).unwrap_or(0));
        println!("[proc 1] *** killed -9 before merge/abort bookkeeping ***");
        // dropped here without checkpoint, merge, or abort: exactly what a
        // SIGKILL between journal append and checkpoint leaves on disk
    }

    // "process 2": recovery
    let cat = Catalog::recover(&dir)?;
    println!("[proc 2] Catalog::recover(dir) replayed the journal");
    assert_eq!(cat.resolve(MAIN)?, pre_head);
    // the export taken before the run began is contained verbatim in the
    // recovered state: main's history replayed byte-exact, and the only
    // additions are the retained (aborted) txn branch and its records
    assert!(pre_export.len() < cat.export().to_string().len());
    println!("  main head exact: {pre_head}");

    let b = cat
        .list_branches()
        .into_iter()
        .find(|b| b.transactional)
        .expect("the killed run's txn branch must be recovered");
    println!("  {} recovered as {:?} (transactional) — never half-merged", b.name, b.state);
    assert_eq!(b.state, BranchState::Aborted);
    // partial outputs retained for triage, target untouched
    let txn_head = cat.read_ref(&b.name)?;
    println!("  triage view retains {:?}", txn_head.tables.keys().collect::<Vec<_>>());
    assert!(!cat.read_ref(MAIN)?.tables.contains_key("parent_table"));
    println!("  PASS: total failure semantics survive kill -9 (spec: doc/COMMIT_PIPELINE.md)");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== failure injection: Fig. 3 top vs bottom ==\n");
    match live_pipeline_acts() {
        Ok(()) => {}
        Err(e) => {
            println!("(skipping live pipeline acts: {e})");
            println!("(build with the real `xla` crate + `make artifacts` to run them)");
        }
    }
    durability_act()
}
