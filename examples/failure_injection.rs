//! Failure injection — Fig. 3 top vs bottom, live.
//!
//! Runs the identical pipeline with the identical mid-run crash under
//! both publication modes and prints what downstream readers of `main`
//! observe. This is experiment E3/E4 in demo form; `bench_consistency`
//! quantifies it over hundreds of runs.

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};

fn describe_main(client: &Client, label: &str) {
    let head = client.catalog.read_ref("main").unwrap();
    println!("  {label}: main holds {} table(s):", head.tables.len());
    for (t, s) in &head.tables {
        let snap = client.catalog.get_snapshot(s).unwrap();
        println!("    {t:<14} written_by={}", snap.run_id);
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== failure injection: Fig. 3 top vs bottom ==\n");

    // ---------------- Fig. 3 top: direct writes (today's lakehouses) -----
    {
        let client = Client::open("artifacts")?;
        client.seed_raw_table("main", 2, 1000)?;
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;

        // run_1 succeeds
        let r1 = client.run_plan(&plan, "main", RunMode::DirectWrite,
                                 &FailurePlan::none(), &[])?;
        println!("[direct] run_1 {}: {:?}", r1.run_id, r1.status);

        // run_2 crashes after updating parent_table
        let r2 = client.run_plan(&plan, "main", RunMode::DirectWrite,
                                 &FailurePlan::crash_after("parent_table"), &[])?;
        println!("[direct] run_2 {}: {:?}", r2.run_id, r2.status);
        describe_main(&client, "reader view");
        println!("  => parent_table is run_2's, child/grand are run_1's: the");
        println!("     globally inconsistent state {{P**, C*, G*}} of Fig. 3.\n");
    }

    // ---------------- Fig. 3 bottom: transactional runs -------------------
    {
        let client = Client::open("artifacts")?;
        client.seed_raw_table("main", 2, 1000)?;
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;

        let r1 = client.run_plan(&plan, "main", RunMode::Transactional,
                                 &FailurePlan::none(), &[])?;
        println!("[txn]    run_1 {}: {:?}", r1.run_id, r1.status);

        let r2 = client.run_plan(&plan, "main", RunMode::Transactional,
                                 &FailurePlan::crash_after("parent_table"), &[])?;
        println!("[txn]    run_2 {}: {:?}", r2.run_id, r2.status);
        describe_main(&client, "reader view");
        println!("  => every table still run_1's — total failure, no partial state.");

        // triage: the aborted branch is queryable
        if let RunStatus::Aborted { txn_branch, .. } = &r2.status {
            let head = client.catalog.read_ref(txn_branch)?;
            println!("\n[triage] aborted branch '{txn_branch}' retains the partial run:");
            for t in head.tables.keys() {
                println!("    {t}");
            }
            let p = client.worker.read_table(&head, "parent_table")?;
            println!("  faulty intermediate parent_table queryable: {} rows", p.row_count());
        }
    }
    Ok(())
}
