//! End-to-end driver — the headline validation run (experiment E9).
//!
//! Exercises the full system on a realistic small workload: a stream of
//! pipeline runs (some failing, per an injected fault rate) against the
//! production branch while concurrent readers continuously snapshot
//! `main` and check global consistency. Reports:
//!
//! - runs/s and rows/s through the full three-layer stack (PJRT compute
//!   on every node);
//! - publish latency p50/p99;
//! - % inconsistent reader snapshots under DirectWrite vs Transactional
//!   (the paper's headline: 0% under the protocol);
//! - object-store traffic (zero-copy bookkeeping).
//!
//! Results are recorded in EXPERIMENTS.md §E9.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode};
use bauplan::testing::Rng;

const RUNS: usize = 30;
const FAILURE_RATE: f64 = 0.4;
const READERS: usize = 4;

/// A reader snapshot of main is consistent iff all pipeline tables
/// present were written by one run (all runs share the plan — DESIGN §5).
fn snapshot_consistent(client: &Client) -> bool {
    let head = client.catalog.read_ref("main").unwrap();
    let mut writers = std::collections::BTreeSet::new();
    let mut seen = 0;
    for t in ["parent_table", "child_table", "grand_child"] {
        if let Some(s) = head.tables.get(t) {
            writers.insert(client.catalog.get_snapshot(s).unwrap().run_id);
            seen += 1;
        }
    }
    seen == 0 || (seen == 3 && writers.len() == 1)
}

fn drive(mode: RunMode) -> (f64, f64, u64, u64, u128, u128) {
    let client = Client::open("artifacts").unwrap();
    client.seed_raw_table("main", 4, 1800).unwrap();
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let reads = Arc::new(AtomicU64::new(0));
    let inconsistent = Arc::new(AtomicU64::new(0));
    let mut readers = Vec::new();
    for _ in 0..READERS {
        let client = client.clone();
        let stop = stop.clone();
        let reads = reads.clone();
        let inconsistent = inconsistent.clone();
        readers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                reads.fetch_add(1, Ordering::Relaxed);
                if !snapshot_consistent(&client) {
                    inconsistent.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::yield_now();
            }
        }));
    }

    let mut rng = Rng::new(2026);
    let mut publish_latencies = Vec::new();
    let mut rows_written = 0u64;
    let t0 = Instant::now();
    for _ in 0..RUNS {
        let failure = if rng.bool(FAILURE_RATE) {
            let node = *rng.pick(&["parent_table", "child_table", "grand_child"]);
            FailurePlan::crash_after(node)
        } else {
            FailurePlan::none()
        };
        let t1 = Instant::now();
        let run = client.run_plan(&plan, "main", mode, &failure, &[]).unwrap();
        publish_latencies.push(t1.elapsed().as_micros());
        if run.is_success() {
            let head = client.catalog.read_ref("main").unwrap();
            for t in &run.outputs {
                rows_written += client.catalog.get_snapshot(&head.tables[t]).unwrap().row_count;
            }
        }
    }
    let wall = t0.elapsed();
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }

    publish_latencies.sort_unstable();
    let p50 = publish_latencies[publish_latencies.len() / 2];
    let p99 = publish_latencies[publish_latencies.len() * 99 / 100];
    let runs_per_s = RUNS as f64 / wall.as_secs_f64();
    let rows_per_s = rows_written as f64 / wall.as_secs_f64();
    (
        runs_per_s,
        rows_per_s,
        reads.load(Ordering::Relaxed),
        inconsistent.load(Ordering::Relaxed),
        p50,
        p99,
    )
}

fn main() {
    println!("== e2e lakehouse driver: {RUNS} runs, {:.0}% injected failures, {READERS} readers ==\n",
             FAILURE_RATE * 100.0);
    for (label, mode) in [
        ("direct-write (baseline)", RunMode::DirectWrite),
        ("transactional (paper)", RunMode::Transactional),
    ] {
        let (rps, rows, reads, bad, p50, p99) = drive(mode);
        println!("{label}");
        println!("  runs/s              : {rps:.2}");
        println!("  rows published/s    : {rows:.0}");
        println!("  run latency p50/p99 : {:.2} ms / {:.2} ms", p50 as f64 / 1e3, p99 as f64 / 1e3);
        println!("  reader snapshots    : {reads}");
        println!("  inconsistent reads  : {bad} ({:.2}%)\n",
                 100.0 * bad as f64 / reads.max(1) as f64);
    }
    println!("expected shape (paper Fig. 3): baseline shows a nonzero inconsistent-read");
    println!("fraction under failures; the transactional protocol shows exactly 0.");
}
