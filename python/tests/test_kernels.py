"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps row counts, group distributions, validity patterns and
adversarial values (NaN, inf, denormal-ish) and asserts allclose against
ref.py.  This is the CORE correctness signal for the compute layer: the
same jitted functions are what aot.py lowers into the artifacts the rust
worker executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import G, TN
from compile.kernels import ref
from compile.kernels.grouped_agg import grouped_agg
from compile.kernels.join import equi_join
from compile.kernels.stats import column_stats
from compile.kernels.transform import filter_project_cast

SIZES = [64, 256, 512, 2048]


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- grouped_agg

@pytest.mark.parametrize("n", SIZES)
def test_grouped_agg_matches_ref(n):
    r = _rng(n)
    col3 = r.normal(size=n).astype(np.float32)
    gid = r.integers(0, G, size=n).astype(np.int32)
    valid = (r.random(n) < 0.8).astype(np.float32)
    s, c, m = grouped_agg(col3, gid, valid)
    rs, rc, rm = ref.grouped_agg_ref(jnp.asarray(col3), jnp.asarray(gid),
                                     jnp.asarray(valid), G)
    np.testing.assert_allclose(s, rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c, rc, rtol=0, atol=0)
    np.testing.assert_allclose(m, rm, rtol=1e-6)


def test_grouped_agg_all_invalid_rows():
    n = 256
    col3 = np.ones(n, np.float32)
    gid = np.zeros(n, np.int32)
    valid = np.zeros(n, np.float32)
    s, c, m = grouped_agg(col3, gid, valid)
    assert float(jnp.sum(s)) == 0.0
    assert float(jnp.sum(c)) == 0.0
    assert float(jnp.sum(m)) == 0.0  # empty groups report 0, not -inf


def test_grouped_agg_single_group_gets_everything():
    n = 512
    col3 = np.full(n, 2.0, np.float32)
    gid = np.full(n, 7, np.int32)
    valid = np.ones(n, np.float32)
    s, c, m = grouped_agg(col3, gid, valid)
    assert float(s[7]) == pytest.approx(2.0 * n)
    assert float(c[7]) == n
    assert float(m[7]) == 2.0
    assert float(jnp.sum(s)) == pytest.approx(2.0 * n)


def test_grouped_agg_out_of_domain_gid_is_dropped():
    # gids >= G one-hot to nothing: contributions must vanish, not alias.
    n = 64
    col3 = np.ones(n, np.float32)
    gid = np.full(n, G + 3, np.int32)
    valid = np.ones(n, np.float32)
    s, c, _ = grouped_agg(col3, gid, valid)
    assert float(jnp.sum(s)) == 0.0 and float(jnp.sum(c)) == 0.0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 256, 512]))
def test_grouped_agg_hypothesis(seed, n):
    r = _rng(seed)
    col3 = (r.normal(size=n) * r.choice([1e-3, 1.0, 1e3])).astype(np.float32)
    gid = r.integers(0, G, size=n).astype(np.int32)
    valid = (r.random(n) < r.random()).astype(np.float32)
    s, c, m = grouped_agg(col3, gid, valid)
    rs, rc, rm = ref.grouped_agg_ref(jnp.asarray(col3), jnp.asarray(gid),
                                     jnp.asarray(valid), G)
    np.testing.assert_allclose(s, rs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(c, rc)
    np.testing.assert_allclose(m, rm, rtol=1e-6)


def test_grouped_agg_sum_invariant_total():
    # Property: sum over groups == masked sum over rows (conservation).
    r = _rng(99)
    n = 2048
    col3 = r.normal(size=n).astype(np.float32)
    gid = r.integers(0, G, size=n).astype(np.int32)
    valid = (r.random(n) < 0.5).astype(np.float32)
    s, c, _ = grouped_agg(col3, gid, valid)
    np.testing.assert_allclose(float(jnp.sum(s)), float(np.sum(col3 * valid)),
                               rtol=1e-4, atol=1e-4)
    assert float(jnp.sum(c)) == float(np.sum(valid))


# ---------------------------------------------------------------- stats

@pytest.mark.parametrize("n", SIZES)
def test_stats_matches_ref(n):
    r = _rng(n + 1)
    x = r.normal(size=n).astype(np.float32)
    inc = (r.random(n) < 0.7).astype(np.float32)
    out = column_stats(x, inc)
    expect = ref.stats_ref(jnp.asarray(x), jnp.asarray(inc))
    np.testing.assert_allclose(out[:6], expect, rtol=1e-5, atol=1e-5)


def test_stats_counts_nans_but_excludes_from_minmax():
    n = 256
    x = np.ones(n, np.float32)
    x[3] = np.nan
    x[10] = 100.0
    x[11] = -5.0
    inc = np.ones(n, np.float32)
    out = np.asarray(column_stats(x, inc))
    assert out[0] == n            # included
    assert out[2] == -5.0         # min ignores NaN
    assert out[3] == 100.0        # max ignores NaN
    assert out[4] == 1.0          # one NaN counted


def test_stats_empty_inclusion_gives_inf_bounds():
    n = 64
    x = np.ones(n, np.float32)
    inc = np.zeros(n, np.float32)
    out = np.asarray(column_stats(x, inc))
    assert out[0] == 0 and out[1] == n
    assert np.isinf(out[2]) and out[2] > 0
    assert np.isinf(out[3]) and out[3] < 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 512, 2048]),
       st.floats(0.0, 1.0))
def test_stats_hypothesis(seed, n, nan_frac):
    r = _rng(seed)
    x = r.normal(size=n).astype(np.float32)
    x[r.random(n) < nan_frac * 0.3] = np.nan
    inc = (r.random(n) < 0.6).astype(np.float32)
    out = column_stats(x, inc)
    expect = ref.stats_ref(jnp.asarray(x), jnp.asarray(inc))
    np.testing.assert_allclose(out[:6], expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- transform

@pytest.mark.parametrize("n", SIZES)
def test_transform_matches_ref(n):
    r = _rng(n + 2)
    x = (r.normal(size=n) * 10).astype(np.float32)
    valid = (r.random(n) < 0.9).astype(np.float32)
    params = np.array([-5.0, 5.0, 2.0, 1.0], np.float32)
    y, yi, keep = filter_project_cast(x, valid, params)
    ry, ryi, rkeep = ref.transform_ref(jnp.asarray(x), jnp.asarray(valid),
                                       *[jnp.float32(p) for p in params])
    np.testing.assert_allclose(y, ry, rtol=1e-6)
    np.testing.assert_array_equal(yi, ryi)
    np.testing.assert_array_equal(keep, rkeep)


def test_transform_cast_truncates_toward_zero():
    n = 64
    x = np.array([1.9, -1.9, 0.49, -0.49] * 16, np.float32)
    valid = np.ones(n, np.float32)
    params = np.array([-100.0, 100.0, 1.0, 0.0], np.float32)
    _, yi, _ = filter_project_cast(x, valid, params)
    np.testing.assert_array_equal(np.asarray(yi)[:4], [1, -1, 0, 0])


def test_transform_filters_out_of_bounds():
    n = 64
    x = np.linspace(-10, 10, n).astype(np.float32)
    valid = np.ones(n, np.float32)
    params = np.array([0.0, 5.0, 1.0, 0.0], np.float32)
    y, _, keep = filter_project_cast(x, valid, params)
    keep = np.asarray(keep)
    x_np = np.asarray(x)
    assert ((x_np >= 0) & (x_np <= 5)).astype(np.float32).tolist() == keep.tolist()
    assert np.all(np.asarray(y)[keep == 0] == 0.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(-50, 0), st.floats(0, 50),
       st.floats(-4, 4), st.floats(-4, 4))
def test_transform_hypothesis(seed, lo, hi, scale, offset):
    n = 256
    r = _rng(seed)
    x = (r.normal(size=n) * 20).astype(np.float32)
    valid = (r.random(n) < 0.8).astype(np.float32)
    params = np.array([lo, hi, scale, offset], np.float32)
    y, yi, keep = filter_project_cast(x, valid, params)
    ry, ryi, rkeep = ref.transform_ref(jnp.asarray(x), jnp.asarray(valid),
                                       *[jnp.float32(p) for p in params])
    np.testing.assert_allclose(y, ry, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(yi, ryi)
    np.testing.assert_array_equal(keep, rkeep)


# ---------------------------------------------------------------- join

@pytest.mark.parametrize("n,m", [(64, 64), (256, 64), (2048, 64), (512, 32)])
def test_join_matches_ref(n, m):
    r = _rng(n * m)
    lkey = r.integers(0, m + 10, size=n).astype(np.int32)
    lvalid = (r.random(n) < 0.9).astype(np.float32)
    rkey = r.permutation(m).astype(np.int32)    # unique keys
    rval = r.normal(size=m).astype(np.float32)
    rvalid = (r.random(m) < 0.9).astype(np.float32)
    out, matched = equi_join(lkey, lvalid, rkey, rval, rvalid)
    rout, rmatched = ref.join_ref(jnp.asarray(lkey), jnp.asarray(lvalid),
                                  jnp.asarray(rkey), jnp.asarray(rval),
                                  jnp.asarray(rvalid))
    np.testing.assert_allclose(out, rout, rtol=1e-6)
    np.testing.assert_array_equal(matched, rmatched)


def test_join_duplicate_right_keys_takes_first():
    n, m = 64, 64
    lkey = np.zeros(n, np.int32)
    lvalid = np.ones(n, np.float32)
    rkey = np.zeros(m, np.int32)                # all duplicate key 0
    rval = np.arange(m, dtype=np.float32) + 1.0
    rvalid = np.ones(m, np.float32)
    out, matched = equi_join(lkey, lvalid, rkey, rval, rvalid)
    assert np.all(np.asarray(out) == 1.0)       # first right row wins
    assert np.all(np.asarray(matched) == 1.0)


def test_join_invalid_right_rows_never_match():
    n, m = 64, 64
    lkey = np.arange(n, dtype=np.int32) % m
    lvalid = np.ones(n, np.float32)
    rkey = np.arange(m, dtype=np.int32)
    rval = np.ones(m, np.float32)
    rvalid = np.zeros(m, np.float32)
    out, matched = equi_join(lkey, lvalid, rkey, rval, rvalid)
    assert float(np.sum(np.asarray(matched))) == 0.0
    assert float(np.sum(np.asarray(out))) == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_join_hypothesis(seed):
    r = _rng(seed)
    n, m = 256, 64
    lkey = r.integers(-5, m + 5, size=n).astype(np.int32)
    lvalid = (r.random(n) < 0.7).astype(np.float32)
    rkey = r.integers(0, m, size=m).astype(np.int32)  # duplicates allowed
    rval = r.normal(size=m).astype(np.float32)
    rvalid = (r.random(m) < 0.7).astype(np.float32)
    out, matched = equi_join(lkey, lvalid, rkey, rval, rvalid)
    rout, rmatched = ref.join_ref(jnp.asarray(lkey), jnp.asarray(lvalid),
                                  jnp.asarray(rkey), jnp.asarray(rval),
                                  jnp.asarray(rvalid))
    np.testing.assert_allclose(out, rout, rtol=1e-6)
    np.testing.assert_array_equal(matched, rmatched)
