"""Layer-2 correctness: node semantics, shape contracts, AOT round-trip."""

import json
import os
import subprocess
import sys
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import ARTIFACTS, to_hlo_text
from compile.kernels import G, N


def _raw(seed=0, n=N):
    r = np.random.default_rng(seed)
    col1 = r.integers(0, G, size=n).astype(np.int32)
    col2 = (1.7e9 + r.random(n) * 1e5).astype(np.float32)
    col3 = (r.random(n) * 10).astype(np.float32)
    valid = (r.random(n) < 0.9).astype(np.float32)
    return col1, col2, col3, valid


# ------------------------------------------------------------------ parent

def test_parent_group_sums():
    col1, col2, col3, valid = _raw(1)
    k, c2, s, v = model.parent(col1, col2, col3, valid)
    k, c2, s, v = map(np.asarray, (k, c2, s, v))
    assert k.shape == (G,) and s.shape == (G,)
    # spot-check group 5 against numpy
    mask = (col1 == 5) & (valid > 0)
    np.testing.assert_allclose(s[5], np.sum(col3[mask]), rtol=1e-4)
    assert v[5] == (1.0 if mask.any() else 0.0)
    if mask.any():
        np.testing.assert_allclose(c2[5], np.max(col2[mask]), rtol=1e-6)


def test_parent_empty_input():
    n = N
    z = np.zeros(n, np.float32)
    k, c2, s, v = model.parent(np.zeros(n, np.int32), z, z, z)
    assert float(jnp.sum(s)) == 0.0
    assert float(jnp.sum(v)) == 0.0


# ------------------------------------------------------------------ child

def test_child_fresh_columns_and_nullability():
    r = np.random.default_rng(2)
    col2 = r.random(G).astype(np.float32)
    s = (r.random(G) * 100).astype(np.float32)
    valid = np.ones(G, np.float32)
    params = np.array([10.0, 80.0, 0.5, 1.0], np.float32)
    c2, c4, c5, c5n, v = map(np.asarray,
                             model.child(col2, s, valid, params))
    np.testing.assert_allclose(c2, col2)
    np.testing.assert_allclose(c4, s * 0.5 + 1.0, rtol=1e-6)
    in_range = (s >= 10.0) & (s <= 80.0)
    np.testing.assert_array_equal(c5n, 1.0 - in_range.astype(np.float32))
    # col5 is only meaningful where not null
    np.testing.assert_allclose(c5[in_range], s[in_range] - 10.0, rtol=1e-5)


def test_child_invalid_rows_produce_nothing():
    col2 = np.ones(G, np.float32)
    s = np.ones(G, np.float32) * 50
    valid = np.zeros(G, np.float32)
    params = np.array([0.0, 100.0, 1.0, 0.0], np.float32)
    _, c4, _, c5n, v = map(np.asarray, model.child(col2, s, valid, params))
    assert np.all(c4 == 0.0)
    assert np.all(c5n == 1.0)   # everything null on invalid rows
    assert np.all(v == 0.0)


# ------------------------------------------------------------------ grand_child

def test_grand_child_narrowing_cast():
    r = np.random.default_rng(3)
    col2 = r.random(G).astype(np.float32)
    col4 = (r.random(G) * 20 - 10).astype(np.float32)
    valid = np.ones(G, np.float32)
    params = np.array([-100.0, 100.0, 1.0, 0.0], np.float32)
    c2, c4i, v = map(np.asarray, model.grand_child(col2, col4, valid, params))
    np.testing.assert_array_equal(c4i, np.trunc(col4).astype(np.int32))
    assert c4i.dtype == np.int32  # the narrowed type


def test_grand_child_bounds_filter():
    col2 = np.zeros(G, np.float32)
    col4 = np.linspace(-10, 10, G).astype(np.float32)
    valid = np.ones(G, np.float32)
    params = np.array([0.0, 5.0, 1.0, 0.0], np.float32)
    _, _, v = map(np.asarray, model.grand_child(col2, col4, valid, params))
    expect = ((col4 >= 0) & (col4 <= 5)).astype(np.float32)
    np.testing.assert_array_equal(v, expect)


# ------------------------------------------------------------------ family_friend

def test_family_friend_joins_and_filters():
    r = np.random.default_rng(4)
    c_key = r.integers(0, G, size=N).astype(np.int32)
    c_col2 = r.random(N).astype(np.float32)
    c_col4 = r.integers(0, 5, size=N).astype(np.float32)
    c_col5 = r.random(N).astype(np.float32)
    c_col5n = (r.random(N) < 0.3).astype(np.float32)
    c_valid = np.ones(N, np.float32)
    g_key = np.arange(G, dtype=np.int32)
    g_col4i = r.integers(0, 5, size=G).astype(np.int32)
    g_valid = np.ones(G, np.float32)
    params = np.array([0.5, 0, 0, 0], np.float32)

    o2, o4, o5, keep = map(np.asarray, model.family_friend(
        c_key, c_col2, c_col4, c_col5, c_col5n, c_valid,
        g_key, g_col4i, g_valid, params))

    # reference row-by-row
    gmap = {int(k): float(v) for k, v in zip(g_key, g_col4i)}
    for i in range(0, N, 97):
        k = int(c_key[i])
        expect_keep = (k in gmap and c_col5n[i] < 1.0 and
                       abs(gmap[k] - c_col4[i]) < 0.5)
        assert bool(keep[i]) == expect_keep, i
        if expect_keep:
            assert o4[i] == gmap[k]
    # NOT NULL contract holds on the output by construction
    assert np.all(keep[(c_col5n >= 1.0)] == 0.0)


# ------------------------------------------------------------------ AOT

def test_every_artifact_lowers_to_hlo_text():
    for name, (fn, specs) in ARTIFACTS.items():
        text, _ = to_hlo_text(fn, specs)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_aot_main_writes_manifest(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(__file__))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
         "--only", "validate_g,transform_g"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env)
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["N"] == N and man["G"] == G
    assert set(man["artifacts"]) == {"validate_g", "transform_g"}
    a = man["artifacts"]["validate_g"]
    assert a["inputs"][0]["shape"] == [G]
    assert (tmp_path / a["file"]).exists()


def test_pipeline_end_to_end_composition():
    """parent -> child -> grand_child composes with consistent shapes."""
    col1, col2, col3, valid = _raw(7)
    k, c2, s, v = model.parent(col1, col2, col3, valid)
    cparams = np.array([0.0, 1e6, 0.5, 1.0], np.float32)
    cc2, c4, c5, c5n, cv = model.child(c2, s, v, cparams)
    gparams = np.array([-1e9, 1e9, 1.0, 0.0], np.float32)
    g2, g4i, gv = model.grand_child(cc2, c4, cv, gparams)
    g2, g4i, gv = map(np.asarray, (g2, g4i, gv))
    assert g4i.shape == (G,)
    # every surviving group's int col4 equals trunc(0.5*sum+1)
    s_np, v_np = np.asarray(s), np.asarray(v)
    expect = np.trunc(s_np * 0.5 + 1.0).astype(np.int32)
    np.testing.assert_array_equal(g4i[gv > 0], expect[gv > 0])
