"""Fused filter + affine projection + explicit-cast kernel.

This is the hot path of the imperative (Python-function) nodes in the
paper's running example: ``child`` projects fresh columns off the parent
table, ``grand_child`` narrows a float column to int *via an explicit
cast* (contracts make an implicit narrowing a plan-time error, §3.1).

One elementwise VMEM pass produces all three outputs — filtering mask,
projected float column, and truncation-cast int column — so a node that
needs any subset pays for exactly one HBM read of the input.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import TN


def _kernel(x_ref, valid_ref, params_ref, y_ref, yint_ref, keep_ref):
    x = x_ref[...]
    valid = valid_ref[...]
    lo, hi, scale, offset = (params_ref[0], params_ref[1],
                             params_ref[2], params_ref[3])

    keep = (x >= lo) & (x <= hi) & (valid > 0)
    y = jnp.where(keep, x * scale + offset, 0.0)

    y_ref[...] = y
    yint_ref[...] = jnp.trunc(y).astype(jnp.int32)
    keep_ref[...] = keep.astype(jnp.float32)


@jax.jit
def filter_project_cast(x, valid, params):
    """Fused transform; see ref.transform_ref.

    Args:
      x:      [n] f32 input column (n a multiple of min(TN, n)).
      valid:  [n] f32 row validity.
      params: [4] f32 — (lo, hi, scale, offset), a runtime argument so one
              AOT artifact serves every parameterization of the node.

    Returns (y [n] f32, y_int [n] i32, valid_out [n] f32).
    """
    n = x.shape[0]
    tn = min(TN, n)
    grid = (n // tn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(x, valid, params)
