"""Equality inner-join kernel (the Appendix-A ``family_friend`` binary node).

GPU joins probe warp-parallel hash tables; the TPU mapping replaces the
probe with an **equality one-hot outer product** followed by a gather
expressed as a matmul (again MXU work, no scatter/atomics):

    eq[n, m]   = (lkey[n] == rkey[m]) & lvalid[n] & rvalid[m]
    first[n]   = argmax_m eq[n, m]               (lowest-index match)
    out[n]     = onehot(first)[n, :] @ rval      ([tn,M] @ [M] matmul)

The left side is tiled over its row dimension (BlockSpec streams tn-row
key blocks through VMEM); the right side (M rows — typically the G-row
grouped table) is small and held resident in VMEM across all grid steps.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import TN


def _kernel(lkey_ref, lvalid_ref, rkey_ref, rval_ref, rvalid_ref,
            out_ref, matched_ref):
    lkey = lkey_ref[...]                   # [tn] i32
    lvalid = lvalid_ref[...]               # [tn]
    rkey = rkey_ref[...]                   # [M] i32
    rval = rval_ref[...]                   # [M]
    rvalid = rvalid_ref[...]               # [M]

    eq = (lkey[:, None] == rkey[None, :])                       # [tn, M]
    eq = eq & (lvalid[:, None] > 0) & (rvalid[None, :] > 0)

    matched = eq.any(axis=1)                                    # [tn]
    first = jnp.argmax(eq, axis=1)                              # [tn]
    m = rkey.shape[0]
    gather = (first[:, None] ==
              jnp.arange(m, dtype=first.dtype)[None, :]).astype(jnp.float32)
    out = gather @ rval                                         # MXU gather

    out_ref[...] = jnp.where(matched, out, 0.0)
    matched_ref[...] = matched.astype(jnp.float32)


@jax.jit
def equi_join(lkey, lvalid, rkey, rval, rvalid):
    """Inner equality join left [n] x right [m]; see ref.join_ref.

    Returns (out [n] f32 — first-match payload, matched [n] f32).
    """
    n = lkey.shape[0]
    m = rkey.shape[0]
    tn = min(TN, n)
    grid = (n // tn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),    # right side VMEM-resident
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(lkey, lvalid, rkey, rval, rvalid)
