"""Validation-statistics kernel: the worker's M3 runtime contract check.

Before a worker persists any node output, it must validate that the
physical data conforms to the declared schema (paper §3.1): nullability,
value bounds, NaN poisoning.  Computing (count, min, max, nan, sum) in
five separate passes would stream the column from HBM five times; this
kernel fuses all of them into **one** VMEM pass per tile — the difference
is directly visible in the HBM-bytes-moved arithmetic in DESIGN.md §Perf.

Output layout (f32[8], padded to 8 for lane alignment):
  0: included count        3: max over included non-NaN (-inf if none)
  1: excluded count        4: NaN count among included
  2: min over included     5: sum over included non-NaN
     non-NaN (+inf if none) 6,7: reserved (0)
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import TN

STATS_W = 8  # output width


def _kernel(x_ref, inc_ref, out_ref):
    step = pl.program_id(0)

    x = x_ref[...]
    inc = inc_ref[...] > 0

    isnan = jnp.isnan(x)
    ok = inc & ~isnan

    cnt = jnp.sum(inc.astype(jnp.float32))
    exc = jnp.sum((~inc).astype(jnp.float32))
    mn = jnp.min(jnp.where(ok, x, jnp.inf))
    mx = jnp.max(jnp.where(ok, x, -jnp.inf))
    nans = jnp.sum((inc & isnan).astype(jnp.float32))
    sm = jnp.sum(jnp.where(ok, x, 0.0))
    zero = jnp.float32(0.0)

    part = jnp.stack([cnt, exc, mn, mx, nans, sm, zero, zero])

    @pl.when(step == 0)
    def _init():
        out_ref[...] = part

    @pl.when(step != 0)
    def _accum():
        prev = out_ref[...]
        out_ref[...] = jnp.stack([
            prev[0] + part[0],
            prev[1] + part[1],
            jnp.minimum(prev[2], part[2]),
            jnp.maximum(prev[3], part[3]),
            prev[4] + part[4],
            prev[5] + part[5],
            zero, zero,
        ])


@jax.jit
def column_stats(x, include):
    """Fused single-pass column statistics; see ref.stats_ref.

    Returns f32[STATS_W]; slots documented in the module docstring.
    """
    n = x.shape[0]
    tn = min(TN, n)
    grid = (n // tn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((STATS_W,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((STATS_W,), jnp.float32),
        interpret=True,
    )(x, include)
