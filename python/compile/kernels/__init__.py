"""Layer-1 Pallas kernels: the compute hot-spots of Bauplan pipeline nodes.

Every kernel is written for TPU idioms (MXU matmuls, VMEM tiling via
BlockSpec) but lowered with ``interpret=True`` so the resulting HLO runs on
the CPU PJRT client that the rust worker embeds.  Correctness oracles live
in :mod:`compile.kernels.ref` and are enforced by pytest + hypothesis.

Fixed compile-time shapes (PJRT executables are static):

- ``N``  — rows per columnar batch (padded; a validity mask marks real rows)
- ``G``  — group-id domain for the grouped aggregation
- ``TN`` — N-tile processed per Pallas grid step (VMEM sizing knob)
"""

N = 2048
G = 64
TN = 256
