"""Grouped aggregation (the SQL ``SUM ... GROUP BY`` of the parent node).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the classic GPU
implementation is an atomicAdd histogram into a shared-memory hash table.
TPUs have neither atomics nor shared memory; the idiomatic mapping is a
**one-hot matmul on the MXU systolic array**:

    sums[g] = sum_n onehot[n, g] * col3[n]        (a [TN,G]^T @ [TN] matmul)

The kernel tiles the row dimension into ``TN``-row blocks (BlockSpec
moves one tile from HBM into VMEM per grid step) and accumulates partial
group sums into the output block, which is revisited on every step — the
standard Pallas reduction pattern (initialize at step 0, accumulate
afterwards).

VMEM budget per step (f32): onehot TN*G + col3/gid/valid 3*TN + out 3*G
= 256*64 + 3*256 + 3*64 floats ≈ 68 KiB ≪ 16 MiB VMEM.  The fused
(sum, count, max) triple is produced in a single pass over the tile —
three separate reductions would stream the column from HBM three times.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import G, TN


def _kernel(col3_ref, gid_ref, valid_ref, sums_ref, counts_ref, rep_ref):
    step = pl.program_id(0)

    col3 = col3_ref[...]                     # [tn]
    gid = gid_ref[...]                       # [tn]
    valid = valid_ref[...]                   # [tn]

    # One-hot encode this tile's group ids, masked by row validity.
    onehot = (gid[:, None] == jnp.arange(G, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(jnp.float32) * valid[:, None]            # [tn, G]

    # MXU: partial sums and counts are matmuls against the one-hot block.
    part_sums = onehot.T @ col3                                     # [G]
    part_counts = onehot.T @ jnp.ones_like(col3)                    # [G]
    # Per-group running MAX of col3 (VPU reduction over the tile).
    masked = jnp.where(onehot.T > 0, col3[None, :], -jnp.inf)       # [G, tn]
    part_rep = jnp.max(masked, axis=1)                              # [G]

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = part_sums
        counts_ref[...] = part_counts
        rep_ref[...] = part_rep

    @pl.when(step != 0)
    def _accum():
        sums_ref[...] += part_sums
        counts_ref[...] += part_counts
        rep_ref[...] = jnp.maximum(rep_ref[...], part_rep)


@jax.jit
def grouped_agg(col3, gid, valid):
    """Pallas grouped (SUM, COUNT, MAX); see ref.grouped_agg_ref.

    ``n = col3.shape[0]`` must be a multiple of the tile ``min(TN, n)``.
    Returns (sums [G] f32, counts [G] f32, rep [G] f32); ``rep`` is the
    per-group max of ``col3`` with empty groups mapped to 0.0.
    """
    n = col3.shape[0]
    tn = min(TN, n)
    grid = (n // tn,)
    sums, counts, rep = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((G,), lambda i: (0,)),
            pl.BlockSpec((G,), lambda i: (0,)),
            pl.BlockSpec((G,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G,), jnp.float32),
            jax.ShapeDtypeStruct((G,), jnp.float32),
            jax.ShapeDtypeStruct((G,), jnp.float32),
        ],
        interpret=True,
    )(col3, gid, valid)
    rep = jnp.where(counts > 0, rep, 0.0)
    return sums, counts, rep
