"""Pure-jnp correctness oracles for every Pallas kernel.

These are the semantics the kernels must match bit-for-close: simple,
obviously-correct jnp formulations with no tiling, no MXU tricks, no
accumulation games.  pytest sweeps shapes/dtypes with hypothesis and
asserts ``allclose(kernel(...), ref(...))``.
"""

import jax.numpy as jnp


def grouped_agg_ref(col3, gid, valid, g):
    """Grouped SUM + COUNT + per-group MAX of a carried column.

    Args:
      col3:  [N] f32 values to sum.
      gid:   [N] i32 group ids in [0, g).
      valid: [N] f32 row-validity mask (1.0 real row / 0.0 padding).
      g:     static group domain size.

    Returns:
      sums   [g] f32 — sum of col3 over valid rows per group.
      counts [g] f32 — number of valid rows per group.
      rep    [g] f32 — max of col3 over valid rows per group (0 if empty).
    """
    onehot = (gid[:, None] == jnp.arange(g)[None, :]).astype(jnp.float32)
    onehot = onehot * valid[:, None]                       # [N, g]
    sums = onehot.T @ col3                                 # [g]
    counts = onehot.sum(axis=0)                            # [g]
    masked = jnp.where(onehot.T > 0, col3[None, :], -jnp.inf)  # [g, N]
    rep = jnp.max(masked, axis=1)
    rep = jnp.where(counts > 0, rep, 0.0)
    return sums, counts, rep


def stats_ref(x, include):
    """Validation statistics over the included entries of a column.

    Args:
      x:       [N] f32 column values.
      include: [N] f32 inclusion mask (row valid AND value present).

    Returns:
      [6] f32 — (included_count, excluded_count, min, max, nan_count, sum).
      min/max are +inf/-inf when nothing is included (callers treat an
      empty column as vacuously in-bounds).  NaNs are excluded from
      min/max/sum but counted.
    """
    inc = include > 0
    isnan = jnp.isnan(x)
    ok = inc & ~isnan
    cnt = jnp.sum(inc.astype(jnp.float32))
    exc = jnp.sum((~inc).astype(jnp.float32))
    mn = jnp.min(jnp.where(ok, x, jnp.inf))
    mx = jnp.max(jnp.where(ok, x, -jnp.inf))
    nans = jnp.sum((inc & isnan).astype(jnp.float32))
    sm = jnp.sum(jnp.where(ok, x, 0.0))
    return jnp.stack([cnt, exc, mn, mx, nans, sm])


def transform_ref(x, valid, lo, hi, scale, offset):
    """Fused filter + affine project + cast used by imperative nodes.

    Rows where ``x`` lies outside [lo, hi] are filtered (validity zeroed).
    Surviving rows are projected ``y = x * scale + offset`` and also cast
    to i32 by truncation (the paper's "narrowing requires an explicit
    cast" example).

    Returns (y [N] f32, y_int [N] i32, valid_out [N] f32).
    """
    keep = (x >= lo) & (x <= hi) & (valid > 0)
    y = jnp.where(keep, x * scale + offset, 0.0)
    y_int = jnp.trunc(y).astype(jnp.int32)
    return y, y_int, keep.astype(jnp.float32)


def join_ref(lkey, lvalid, rkey, rval, rvalid):
    """Inner equality join: for each left row, the first matching right row.

    Args:
      lkey:   [N] i32 left keys.
      lvalid: [N] f32 left row validity.
      rkey:   [M] i32 right keys.
      rval:   [M] f32 right payload.
      rvalid: [M] f32 right row validity.

    Returns:
      out     [N] f32 — payload of the first (lowest right index) match.
      matched [N] f32 — 1.0 where a match exists (both rows valid).
    """
    eq = (lkey[:, None] == rkey[None, :])                      # [N, M]
    eq = eq & (lvalid[:, None] > 0) & (rvalid[None, :] > 0)
    matched = eq.any(axis=1)
    first = jnp.argmax(eq, axis=1)                             # 0 if none
    out = jnp.where(matched, rval[first], 0.0)
    return out, matched.astype(jnp.float32)
