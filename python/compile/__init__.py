"""Build-time compile path: Layer-2 jax nodes + Layer-1 Pallas kernels.

Nothing in this package is imported at runtime — ``make artifacts`` runs
:mod:`compile.aot` once, and the rust coordinator only ever touches the
emitted ``artifacts/*.hlo.txt`` + ``manifest.json``.
"""
