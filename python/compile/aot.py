"""AOT exporter: lower every Layer-2 node to an HLO-text artifact.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True`` so the rust runtime
can uniformly unwrap a tuple result.  A ``manifest.json`` records, per
artifact: the node name, input specs (shape + dtype) and output specs —
the rust runtime validates its call sites against the manifest at load
time (one more fail-fast moment, in the spirit of the paper).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import G, N
from .kernels.stats import STATS_W


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _f32(*shape):
    return _spec(shape, jnp.float32)


def _i32(*shape):
    return _spec(shape, jnp.int32)


# name -> (fn, [input ShapeDtypeStructs])
ARTIFACTS = {
    # Node 1: raw_table [N] -> parent [G]
    "parent": (model.parent, [_i32(N), _f32(N), _f32(N), _f32(N)]),
    # Node 2: parent [G] -> child [G]
    "child": (model.child, [_f32(G), _f32(G), _f32(G), _f32(4)]),
    # Node 3: child [G] -> grand_child [G]
    "grand_child": (model.grand_child, [_f32(G), _f32(G), _f32(G), _f32(4)]),
    # Node 4 (appendix): child-tall [N] x grand [G] -> friend [N]
    "family_friend": (model.family_friend,
                      [_i32(N), _f32(N), _f32(N), _f32(N), _f32(N), _f32(N),
                       _i32(G), _i32(G), _f32(G), _f32(4)]),
    # Generic reusable nodes for custom pipelines.
    "join_n": (model.join_node, [_i32(N), _f32(N), _i32(G), _f32(G), _f32(G)]),
    "transform_n": (model.transform_node, [_f32(N), _f32(N), _f32(4)]),
    "transform_g": (model.transform_node, [_f32(G), _f32(G), _f32(4)]),
    # Worker M3 contract checks (one artifact per table width class).
    "validate_n": (model.validate, [_f32(N), _f32(N)]),
    "validate_g": (model.validate, [_f32(G), _f32(G)]),
}


def to_hlo_text(fn, in_specs):
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(), lowered


def _out_specs(lowered):
    out = lowered.out_info
    leaves = jax.tree_util.tree_leaves(out)
    return [{"shape": list(x.shape), "dtype": str(x.dtype)} for x in leaves]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for *.hlo.txt + manifest.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of artifact names")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = list(ARTIFACTS) if not args.only else args.only.split(",")

    manifest = {"version": 1, "N": N, "G": G, "STATS_W": STATS_W,
                "artifacts": {}}
    for name in names:
        fn, in_specs = ARTIFACTS[name]
        text, lowered = to_hlo_text(fn, in_specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in in_specs],
            "outputs": _out_specs(lowered),
        }
        print(f"  {name:<16} {len(text):>9} chars  sha={digest}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(names)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
