"""Layer-2: the paper's running-example DAG as jax compute graphs.

Each public function below is one pipeline node with the fixed signature
``Table(s) -> Table`` (paper §3.3): columnar arrays in, columnar arrays
out, all shapes static so the whole node lowers to a single AOT-compiled
XLA executable the rust worker invokes via PJRT.  The node bodies call
the Layer-1 Pallas kernels, so kernel and glue fuse into one HLO module.

Node inventory (paper §2 Listings 1-5 and Appendix A):

  parent        raw_table -> parent_table       SQL SUM ... GROUP BY
  child         parent_table -> child_table     projection + fresh columns
  grand_child   child_table -> grand_child      float->int narrowing cast
  family_friend child x grand -> friend         binary join + filter
  validate      any f32 column -> stats[6]      worker M3 contract check

Nullable columns are carried as (values, null_mask) pairs; row validity
is a separate mask (padding rows of the fixed-shape batch).
"""

import jax.numpy as jnp

from .kernels import G, N  # noqa: F401  (re-exported for aot.py / tests)
from .kernels.grouped_agg import grouped_agg
from .kernels.join import equi_join
from .kernels.stats import column_stats
from .kernels.transform import filter_project_cast


def parent(col1, col2, col3, valid):
    """Node 1 — ``SELECT col1, col2, SUM(col3) AS _S FROM raw_table GROUP BY col1``.

    Args:
      col1:  [N] i32 group key (str dictionary codes on the rust side).
      col2:  [N] f32 datetime (epoch seconds).
      col3:  [N] f32 measure.
      valid: [N] f32 row validity.

    Returns (ParentSchema, grouped to [G] rows):
      col1_out  [G] i32 — the group key (arange over the domain).
      col2_out  [G] f32 — latest (max) col2 in the group.
      s_out     [G] f32 — SUM(col3).
      valid_out [G] f32 — 1.0 for non-empty groups.
    """
    sums, counts, _ = grouped_agg(col3, col1, valid)
    _, _, rep2 = grouped_agg(col2, col1, valid)
    col1_out = jnp.arange(G, dtype=jnp.int32)
    valid_out = (counts > 0).astype(jnp.float32)
    return col1_out, rep2, sums, valid_out


def child(col2, s, valid, params):
    """Node 2 — projection with fresh columns (ChildSchema).

    col4 = _S * scale + offset (fresh, non-null float); col5 is a fresh
    *nullable* string-ish score: null whenever _S falls outside [lo, hi]
    (the paper's ``UNION(str, None)``).

    Args:
      col2:   [G] f32 inherited datetime.
      s:      [G] f32 parent ``_S``.
      valid:  [G] f32 row validity.
      params: [4] f32 — (lo, hi, scale, offset).

    Returns: col2 [G] f32, col4 [G] f32, col5 [G] f32, col5_null [G] f32,
    valid [G] f32.
    """
    lo, hi, scale, offset = params[0], params[1], params[2], params[3]
    col4 = jnp.where(valid > 0, s * scale + offset, 0.0)
    in_range = (s >= lo) & (s <= hi) & (valid > 0)
    col5 = jnp.where(in_range, s - lo, 0.0)
    col5_null = 1.0 - in_range.astype(jnp.float32)  # 1.0 => NULL
    return col2, col4, col5, col5_null, valid


def grand_child(col2, col4, valid, params):
    """Node 3 — narrowing cast (Grand): col4 float -> int via explicit trunc.

    Uses the fused Layer-1 transform kernel (shape-polymorphic: the same
    source serves the [G] grouped table here and [N] tall tables in
    custom pipelines; each shape is its own AOT artifact).  Callers pass
    params = (lo, hi, 1.0, 0.0) with the contract's declared bounds so
    out-of-bounds rows are filtered rather than silently wrapped.

    Returns: col2 [G] f32, col4_int [G] i32, valid_out [G] f32.
    """
    _, col4_int, keep = filter_project_cast(col4, valid, params)
    return col2, col4_int, keep


def family_friend(c_key, c_col2, c_col4, c_col5, c_col5_null, c_valid,
                  g_key, g_col4i, g_valid, params):
    """Node 4 (Appendix A) — binary join of child and grand on the key.

    Joins child rows ([N]-shaped, tall) against the grand table
    ([G]-shaped, grouped) on integer key equality, keeps rows where
    col5 IS NOT NULL and |col4_grand - col4_child| < eps, and emits
    FriendSchema with col5 explicitly NOT NULL — violating rows are
    filtered, which is what makes the ``[NotNull]`` annotation sound.

    Args: child columns (c_*), grand columns (g_*), params [4] f32 with
    params[0] = eps (join tolerance); remaining slots reserved.
    """
    eps = params[0]
    g4f, matched = equi_join(c_key, c_valid, g_key,
                             g_col4i.astype(jnp.float32), g_valid)
    keep = (matched > 0) & (c_col5_null < 1.0) & \
           (jnp.abs(g4f - c_col4) < eps) & (c_valid > 0)
    keepf = keep.astype(jnp.float32)
    return (jnp.where(keep, c_col2, 0.0),
            jnp.where(keep, g4f, 0.0),
            jnp.where(keep, c_col5, 0.0),
            keepf)


def join_node(lkey, lvalid, rkey, rval, rvalid):
    """Raw equality-join node: the reusable Table x Table -> Table join."""
    return equi_join(lkey, lvalid, rkey, rval, rvalid)


def validate(x, include):
    """Worker-side M3 contract check: fused stats for one f32 column."""
    return (column_stats(x, include),)


def transform_node(x, valid, params):
    """Generic fused filter/project/cast node (reused by custom pipelines)."""
    return filter_project_cast(x, valid, params)
