//! Property-based tests over the coordinator invariants.
//!
//! No PJRT needed — these hammer the catalog/merge/model layers with
//! randomized operation sequences (deterministic xorshift seeds, failing
//! seed reported) and check the invariants the paper's claims rest on:
//!
//! 1. catalog linearizability (history length == writes applied);
//! 2. branch isolation (work on a branch never moves other heads);
//! 3. merge atomicity (readers see pre-merge or post-merge, never mid);
//! 4. content addressing (equal states collapse to equal ids);
//! 5. the model's protocol safety over random schedules.

use std::sync::Arc;

use bauplan::catalog::{Catalog, Snapshot, MAIN};
use bauplan::error::BauplanError;
use bauplan::storage::ObjectStore;
use bauplan::testing::{commit_table, for_cases, Rng};

fn catalog() -> Catalog {
    Catalog::new(Arc::new(ObjectStore::new()))
}

fn snap(rng: &mut Rng, run: &str) -> Snapshot {
    Snapshot::new(
        vec![format!("obj_{}", rng.next_u64())],
        "S",
        "fp",
        rng.below(100) as u64,
        run,
    )
}

#[test]
fn prop_history_is_linear_under_random_writes() {
    for_cases(30, |rng| {
        let c = catalog();
        let writes = 1 + rng.below(40);
        for i in 0..writes {
            let t = format!("t{}", rng.below(5));
            commit_table(&c, MAIN, &t, snap(rng, "r"), "u", &format!("w{i}"), None)
                .unwrap();
        }
        let log = c.log(MAIN, usize::MAX).unwrap();
        assert_eq!(log.len(), writes + 1, "linear history");
        // parents chain correctly
        for w in log.windows(2) {
            assert_eq!(w[0].parents, vec![w[1].id.clone()]);
        }
    });
}

#[test]
fn prop_branches_are_isolated() {
    for_cases(30, |rng| {
        let c = catalog();
        // base state
        for i in 0..1 + rng.below(5) {
            commit_table(&c, MAIN, &format!("t{i}"), snap(rng, "r"), "u", "m", None)
                .unwrap();
        }
        let branches: Vec<String> = (0..1 + rng.below(4))
            .map(|i| {
                let name = format!("b{i}");
                c.create_branch(&name, MAIN, false).unwrap();
                name
            })
            .collect();
        let main_head = c.resolve(MAIN).unwrap();
        let heads: Vec<String> =
            branches.iter().map(|b| c.resolve(b).unwrap()).collect();
        // random writes on random branches
        for _ in 0..rng.below(30) {
            let b = rng.pick(&branches).clone();
            commit_table(&c, &b, &format!("t{}", rng.below(5)), snap(rng, "r"), "u", "m", None)
                .unwrap();
        }
        // main never moved
        assert_eq!(c.resolve(MAIN).unwrap(), main_head);
        // every branch either kept its head or moved past it (its own
        // writes), but no branch saw another branch's head
        for (b, h0) in branches.iter().zip(&heads) {
            let h1 = c.resolve(b).unwrap();
            assert!(c.is_ancestor(h0, &h1).unwrap(), "branch {b} rebased?");
        }
    });
}

#[test]
fn prop_merge_is_all_or_nothing() {
    for_cases(30, |rng| {
        let c = catalog();
        commit_table(&c, MAIN, "base", snap(rng, "r0"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        // dev writes k tables
        let k = 1 + rng.below(6);
        for i in 0..k {
            commit_table(&c, "dev", &format!("n{i}"), snap(rng, "r1"), "u", "m", None)
                .unwrap();
        }
        let before = c.read_ref(MAIN).unwrap();
        c.merge("dev", MAIN, false).unwrap();
        let after = c.read_ref(MAIN).unwrap();
        // pre-merge state had none of the new tables; post has all
        for i in 0..k {
            let t = format!("n{i}");
            assert!(!before.tables.contains_key(&t));
            assert!(after.tables.contains_key(&t));
        }
        // idempotent
        let again = c.merge("dev", MAIN, false).unwrap();
        assert_eq!(again, after.id);
    });
}

#[test]
fn prop_conflicts_always_detected_never_spurious() {
    for_cases(40, |rng| {
        let c = catalog();
        let tables: Vec<String> = (0..4).map(|i| format!("t{i}")).collect();
        for t in &tables {
            commit_table(&c, MAIN, t, snap(rng, "base"), "u", "m", None).unwrap();
        }
        c.create_branch("dev", MAIN, false).unwrap();
        // pick disjoint or overlapping change sets
        let src_set: Vec<&String> =
            tables.iter().filter(|_| rng.bool(0.5)).collect();
        let dst_set: Vec<&String> =
            tables.iter().filter(|_| rng.bool(0.5)).collect();
        for t in &src_set {
            commit_table(&c, "dev", t, snap(rng, "src"), "u", "m", None).unwrap();
        }
        for t in &dst_set {
            commit_table(&c, MAIN, t, snap(rng, "dst"), "u", "m", None).unwrap();
        }
        let overlap: Vec<_> = src_set.iter().filter(|t| dst_set.contains(t)).collect();
        let res = c.merge("dev", MAIN, false);
        if overlap.is_empty() {
            res.unwrap(); // disjoint changes must merge
        } else {
            match res {
                Err(BauplanError::MergeConflict(msg)) => {
                    for t in overlap {
                        assert!(msg.contains(t.as_str()), "missing {t} in '{msg}'");
                    }
                }
                other => panic!("expected conflict, got {other:?}"),
            }
        }
    });
}

#[test]
fn prop_content_addressing_dedups_equal_snapshots() {
    for_cases(20, |rng| {
        let objects: Vec<String> = (0..3).map(|i| format!("o{i}")).collect();
        let a = Snapshot::new(objects.clone(), "S", "fp", 5, "r");
        let b = Snapshot::new(objects, "S", "fp", 5, "r");
        assert_eq!(a.id, b.id);
        let c2 = Snapshot::new(vec![format!("o{}", rng.below(100) + 10)], "S", "fp", 5, "r");
        assert_ne!(a.id, c2.id);
    });
}

#[test]
fn prop_store_dedup_means_branching_is_free() {
    for_cases(10, |rng| {
        let store = Arc::new(ObjectStore::new());
        let c = Catalog::new(store.clone());
        let payload: Vec<u8> = (0..256).map(|_| rng.below(256) as u8).collect();
        let key = store.put(payload.clone());
        commit_table(
            &c,
            MAIN,
            "t",
            Snapshot::new(vec![key], "S", "fp", 1, "r"),
            "u",
            "m",
            None,
        )
        .unwrap();
        let bytes_before = store.stored_bytes();
        for i in 0..20 {
            c.create_branch(&format!("b{i}"), MAIN, false).unwrap();
        }
        // twenty branches, zero new bytes
        assert_eq!(store.stored_bytes(), bytes_before);
        // and re-putting the same data is a dedup hit
        store.put(payload);
        assert_eq!(store.stored_bytes(), bytes_before);
    });
}

// ---------------------------------------------------------------- model

#[test]
fn prop_model_random_schedules_respect_protocol_safety() {
    use bauplan::model::{ModelState, Scenario};
    // random walks through the transactional+guardrail scenario never
    // reach an inconsistent main — the BFS result, revalidated pointwise.
    let sc = Scenario::counterexample_fixed();
    for_cases(50, |rng| {
        let mut state = ModelState::init();
        for _ in 0..rng.below(25) {
            let succ = state.successors(&sc);
            if succ.is_empty() {
                break;
            }
            let (_, next) = &succ[rng.below(succ.len())];
            state = next.clone();
            assert!(
                state.main_consistent(sc.plan_len),
                "protocol violated on a random schedule"
            );
        }
    });
}

#[test]
fn prop_model_direct_writes_violations_are_reachable_and_detected() {
    use bauplan::model::{ModelState, Scenario};
    let sc = Scenario::direct_writes();
    let mut violations = 0;
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 1);
        let mut state = ModelState::init();
        for _ in 0..12 {
            let succ = state.successors(&sc);
            if succ.is_empty() {
                break;
            }
            let (_, next) = &succ[rng.below(succ.len())];
            state = next.clone();
            if !state.main_consistent(sc.plan_len) {
                violations += 1;
                break;
            }
        }
    }
    // partial states are common under direct writes — the Fig. 3 claim
    assert!(violations > 50, "only {violations}/200 runs hit a partial state");
}

// ---------------------------------------------------------------- replay ops

#[test]
fn prop_rebase_preserves_branch_content_on_disjoint_tables() {
    for_cases(25, |rng| {
        let c = catalog();
        commit_table(&c, MAIN, "base", snap(rng, "r0"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        // dev writes tables d0..dk, main writes m0..mj — disjoint
        let k = 1 + rng.below(4);
        let j = rng.below(4);
        for i in 0..k {
            commit_table(&c, "dev", &format!("d{i}"), snap(rng, "rd"), "u", "m", None).unwrap();
        }
        for i in 0..j {
            commit_table(&c, MAIN, &format!("m{i}"), snap(rng, "rm"), "u", "m", None).unwrap();
        }
        let dev_tables_before = c.read_ref("dev").unwrap().tables;
        c.rebase("dev", MAIN).unwrap();
        let dev_after = c.read_ref("dev").unwrap();
        // all of dev's own tables survive with identical snapshots
        for (t, s) in &dev_tables_before {
            assert_eq!(dev_after.tables.get(t), Some(s), "table {t} changed by rebase");
        }
        // and main's tables are now visible
        for i in 0..j {
            assert!(dev_after.tables.contains_key(&format!("m{i}")));
        }
        // rebase makes the merge a fast-forward
        assert!(c.is_ancestor(MAIN, "dev").unwrap());
    });
}

#[test]
fn prop_cherry_pick_applies_exactly_one_delta() {
    for_cases(25, |rng| {
        let c = catalog();
        commit_table(&c, MAIN, "base", snap(rng, "r0"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        let n_commits = 2 + rng.below(4);
        let mut ids = Vec::new();
        for i in 0..n_commits {
            ids.push(
                commit_table(
                    &c,
                    "dev",
                    &format!("t{i}"),
                    snap(rng, "rd"),
                    "u",
                    &format!("c{i}"),
                    None,
                )
                .unwrap(),
            );
        }
        let pick = rng.below(n_commits);
        c.cherry_pick(&ids[pick], MAIN).unwrap();
        let main = c.read_ref(MAIN).unwrap();
        for (i, _) in ids.iter().enumerate() {
            assert_eq!(
                main.tables.contains_key(&format!("t{i}")),
                i == pick,
                "pick={pick} i={i}"
            );
        }
    });
}

// ---------------------------------------------------------------- run cache

#[test]
fn prop_cache_keys_deterministic_and_node_order_insensitive() {
    use bauplan::contracts::schema::SchemaRegistry;
    use bauplan::dag::PipelineSpec;

    let fwd = PipelineSpec::paper_pipeline().plan().unwrap();

    // same pipeline, nodes declared in reverse order: every node's
    // fingerprint is identical (keys are content, not position)
    let spec = PipelineSpec::paper_pipeline();
    let mut rev = PipelineSpec::new("paper_dag", SchemaRegistry::with_paper_schemas());
    rev.sources = spec.sources.clone();
    for n in spec.nodes.iter().rev() {
        rev.nodes.push(n.clone());
    }
    let rev_plan = rev.plan().unwrap();
    for (i, n) in fwd.nodes.iter().enumerate() {
        assert_eq!(
            Some(fwd.node_fps[i].as_str()),
            rev_plan.node_fp(&n.output),
            "node '{}' fingerprint depends on declaration order",
            n.output
        );
    }

    // independently rebuilt registry + spec ("a fresh process"): same fps
    let again = PipelineSpec::paper_pipeline().plan().unwrap();
    assert_eq!(fwd.node_fps, again.node_fps);

    // the run-key combine is a pure function of its strings, pinned by a
    // golden digest — any process-dependent input would break this
    // across restarts (golden = sha256-16 of the length-framed parts;
    // changes only if the derivation itself changes)
    let k = bauplan::cache::run_cache_key(
        "sfp",
        "afp",
        &["snapA".to_string(), "snapB".to_string()],
    );
    assert_eq!(k, "a7e92e87bfdc1ea0fb8e2ec224cf99e1");
}

#[test]
fn prop_plan_fingerprint_canonical_encoding_golden() {
    use bauplan::dag::PipelineSpec;
    use bauplan::runs::plan_fingerprint;

    // golden digest — sha256-16 over the length-framed canonical
    // encoding (explicit field framing + counts, f32 params as
    // little-endian bit patterns; no Debug formatting anywhere), so the
    // fingerprint is stable across Rust versions and processes. Changes
    // only if the derivation itself changes.
    let plan = PipelineSpec::paper_pipeline().plan().unwrap();
    assert_eq!(plan_fingerprint(&plan), "6e1cbcd665436c7cec1b856f3f3ee969");

    // independently rebuilt spec ("a fresh process"): same digest
    let again = PipelineSpec::paper_pipeline().plan().unwrap();
    assert_eq!(plan_fingerprint(&plan), plan_fingerprint(&again));

    // sensitive to params bit-exactly: a single flipped mantissa bit
    // (and even -0.0 vs 0.0) changes the identity
    for_cases(20, |rng| {
        let mut spec = PipelineSpec::paper_pipeline();
        let p = &mut spec.nodes[1].params[rng.below(4)];
        *p = f32::from_bits(p.to_bits() ^ 1);
        let edited = spec.plan().unwrap();
        assert_ne!(plan_fingerprint(&plan), plan_fingerprint(&edited));
    });
    let mut negz = PipelineSpec::paper_pipeline();
    negz.nodes[1].params[0] = -0.0;
    assert_ne!(
        plan_fingerprint(&plan),
        plan_fingerprint(&negz.plan().unwrap())
    );

    // and to structure: renaming an output table is a different plan
    let mut renamed = PipelineSpec::paper_pipeline();
    renamed.nodes[2].output = "grand_child2".into();
    assert_ne!(
        plan_fingerprint(&plan),
        plan_fingerprint(&renamed.plan().unwrap())
    );
}

#[test]
fn prop_cache_static_fingerprint_is_bit_exact_in_params() {
    use bauplan::cache::node_static_fingerprint;
    for_cases(40, |rng| {
        let params: Vec<f32> = (0..rng.below(5)).map(|_| rng.f32() * 100.0).collect();
        let a = node_static_fingerprint("child", &params, "out_fp", &["in_fp".into()]);
        let b = node_static_fingerprint("child", &params, "out_fp", &["in_fp".into()]);
        assert_eq!(a, b);
        if !params.is_empty() {
            let mut flipped = params.clone();
            flipped[0] = f32::from_bits(flipped[0].to_bits() ^ 1);
            assert_ne!(
                a,
                node_static_fingerprint("child", &flipped, "out_fp", &["in_fp".into()]),
                "single-bit param change must change the key"
            );
        }
        assert_ne!(a, node_static_fingerprint("parent", &params, "out_fp", &["in_fp".into()]));
        assert_ne!(a, node_static_fingerprint("child", &params, "out2", &["in_fp".into()]));
    });
}

// ---------------------------------------------------------------- persistence

#[test]
fn prop_persistence_roundtrip_after_random_histories() {
    use bauplan::util::json::Json;
    for_cases(15, |rng| {
        let c = catalog();
        let branches = vec![MAIN.to_string()];
        let mut all: Vec<String> = branches.clone();
        for step in 0..rng.below(25) {
            match rng.below(4) {
                0 => {
                    let name = format!("b{step}");
                    if c.create_branch(&name, rng.pick(&all).as_str(), false).is_ok() {
                        all.push(name);
                    }
                }
                1 => {
                    let _ = c.tag(&format!("tag{step}"), rng.pick(&all).as_str());
                }
                _ => {
                    let b = rng.pick(&all).clone();
                    let _ = commit_table(
                        &c,
                        &b,
                        &format!("t{}", rng.below(4)),
                        snap(rng, "r"),
                        "u",
                        "m",
                        None,
                    );
                }
            }
        }
        let exported = c.export().to_string();
        let c2 = Catalog::import(&Json::parse(&exported).unwrap(), c.store().clone()).unwrap();
        assert_eq!(c2.export().to_string(), exported, "roundtrip not canonical");
        // every ref resolves identically
        for b in c.list_branches() {
            assert_eq!(c2.resolve(&b.name).unwrap(), b.head);
        }
    });
}

#[test]
fn prop_gc_never_drops_reachable_state() {
    for_cases(20, |rng| {
        let c = catalog();
        let mut all = vec![MAIN.to_string()];
        for step in 0..rng.below(20) {
            match rng.below(3) {
                0 => {
                    let name = format!("b{step}");
                    if c.create_branch(&name, rng.pick(&all).as_str(), false).is_ok() {
                        all.push(name);
                    }
                }
                _ => {
                    let b = rng.pick(&all).clone();
                    let data: Vec<u8> = (0..32).map(|_| rng.below(256) as u8).collect();
                    let key = c.store().put(data);
                    let _ = commit_table(
                        &c,
                        &b,
                        &format!("t{}", rng.below(3)),
                        Snapshot::new(vec![key], "S", "fp", 1, "r"),
                        "u",
                        "m",
                        None,
                    );
                }
            }
        }
        // maybe delete some branches (creates garbage)
        for b in all.clone() {
            if b != MAIN && rng.bool(0.4) {
                let _ = c.delete_branch(&b);
            }
        }
        c.gc().unwrap();
        // everything reachable still reads back
        for b in c.list_branches() {
            let head = c.read_ref(&b.name).unwrap();
            for snap_id in head.tables.values() {
                let s = c.get_snapshot(snap_id).unwrap();
                for obj in &s.objects {
                    c.store().get(obj).unwrap();
                }
            }
            // full history still walkable
            c.log(&b.name, usize::MAX).unwrap();
        }
    });
}

// ---------------------------------------------------------------- journal

#[test]
fn prop_segmented_journal_maintenance_is_invisible_to_state() {
    // the LSM shape must be unobservable: replaying one mutation list
    // through (a) a tiny-segment journal with rotations, delta
    // checkpoints and compactions sprinkled mid-stream and (b) a
    // never-rotated single-segment journal with no maintenance at all
    // yields the same logical state after recovery — same commit ids,
    // heads, tags, `log` history and `diff` answers. (Byte-identical
    // exports are compared within each lake across recoveries; across
    // lakes the export differs only by wall-clock commit timestamps,
    // which are excluded from every id.)
    use bauplan::catalog::{JournalConfig, SyncPolicy};

    #[derive(Clone)]
    enum LakeOp {
        Commit(String, String, Snapshot),
        CreateBranch(String, String),
        Tag(String, String),
        Rotate,
        Checkpoint,
        Compact,
    }

    // timestamp-free digest of everything user-visible
    fn state_digest(c: &Catalog, tags: &[String]) -> String {
        let mut out = String::new();
        for b in c.list_branches() {
            out.push_str(&format!("branch {} {} {:?}\n", b.name, b.head, b.state));
            for commit in c.log(&b.name, usize::MAX).unwrap() {
                out.push_str(&format!("  {} {} {:?}\n", commit.id, commit.message, commit.tables));
            }
        }
        for (name, id) in c.dump_tags() {
            out.push_str(&format!("tag {name} {id}\n"));
        }
        for t in tags {
            out.push_str(&format!("diff {t}: {:?}\n", c.diff(t, MAIN).unwrap()));
        }
        out
    }

    for_cases(8, |rng| {
        // build the op list once, replay it into both lakes
        let mut ops: Vec<LakeOp> = Vec::new();
        let mut branches = vec![MAIN.to_string()];
        let mut tags: Vec<String> = Vec::new();
        for step in 0..25 + rng.below(15) {
            match rng.below(10) {
                0 => {
                    let name = format!("b{step}");
                    let from = rng.pick(&branches).clone();
                    ops.push(LakeOp::CreateBranch(name.clone(), from));
                    branches.push(name);
                }
                1 => {
                    let name = format!("v{step}");
                    ops.push(LakeOp::Tag(name.clone(), rng.pick(&branches).clone()));
                    tags.push(name);
                }
                2 => ops.push(LakeOp::Rotate),
                3 => ops.push(LakeOp::Checkpoint),
                4 => ops.push(LakeOp::Compact),
                _ => {
                    let b = rng.pick(&branches).clone();
                    ops.push(LakeOp::Commit(b, format!("t{}", rng.below(4)), snap(rng, "r")));
                }
            }
        }

        let replay = |tag: &str, config: JournalConfig, maintenance: bool| -> String {
            let dir = std::env::temp_dir()
                .join(format!("bpl_prop_seg_{tag}_{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            let c = Catalog::open_durable_cfg(&dir, config).unwrap();
            for op in &ops {
                match op {
                    LakeOp::Commit(b, t, s) => {
                        commit_table(&c, b, t, s.clone(), "u", "m", None).unwrap();
                    }
                    LakeOp::CreateBranch(name, from) => {
                        c.create_branch(name, from, false).unwrap();
                    }
                    LakeOp::Tag(name, at) => {
                        c.tag(name, at).unwrap();
                    }
                    LakeOp::Rotate if maintenance => c.journal_rotate().unwrap(),
                    LakeOp::Checkpoint if maintenance => {
                        c.checkpoint().unwrap();
                    }
                    LakeOp::Compact if maintenance => {
                        c.compact().unwrap();
                    }
                    _ => {}
                }
            }
            c.journal_sync().unwrap();
            let live_export = c.export().to_string();
            drop(c);
            // recovery must land byte-identical within the lake …
            let r = Catalog::open_durable_cfg(&dir, config).unwrap();
            assert_eq!(r.export().to_string(), live_export, "{tag}: recovery diverged");
            // … and the user-visible state is the cross-lake digest
            let digest = state_digest(&r, &tags);
            drop(r);
            let _ = std::fs::remove_dir_all(&dir);
            digest
        };

        let segmented = replay(
            "lsm",
            JournalConfig {
                sync: SyncPolicy::Batch(16),
                segment_bytes: 1200, // a handful of records per segment
                compact_after_deltas: 2,
                sync_latency_micros: 0,
            },
            true,
        );
        let flat = replay(
            "flat",
            JournalConfig {
                sync: SyncPolicy::EveryAppend,
                segment_bytes: u64::MAX, // never rotates: one segment, ever
                compact_after_deltas: u64::MAX,
                sync_latency_micros: 0,
            },
            false,
        );
        assert_eq!(segmented, flat, "maintenance changed the observable state");
    });
}

// ---------------------------------------------------------------- json

#[test]
fn prop_json_roundtrips_random_values() {
    use bauplan::util::json::Json;
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 {
            rng.below(4)
        } else {
            rng.below(6)
        } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| *rng.pick(&['a', 'é', '"', '\\', '\n', '\t', 'z', '€']))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_cases(100, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "text: {text}");
    });
}

// ---------------------------------------------------------------- data plane

#[test]
fn prop_zone_map_pruning_is_byte_invisible() {
    // Zone-map predicate pushdown (doc/DATA_PLANE.md) must be a pure
    // wall-clock optimization: a pruned scan and an unpruned scan over
    // the same seeded random batches — including all-NULL columns,
    // empty batches and inverted/out-of-range predicates — publish
    // byte-identical encoded outputs.
    use bauplan::client::Client;
    use bauplan::dag::NodeSpec;
    use bauplan::runtime::sim::SIM_N;
    use bauplan::storage::codec::encode_batch;
    use bauplan::storage::{Batch, Column};

    for_cases(12, |rng| {
        let client = Client::open_sim().unwrap();
        let n_batches = 1 + rng.below(6);
        let mut keys = Vec::new();
        for _ in 0..n_batches {
            let rows = match rng.below(4) {
                0 => 0, // empty batch
                1 => 1 + rng.below(5),
                _ => 1 + rng.below(SIM_N),
            };
            let base = (rng.below(2000) as f32) - 1000.0;
            let x: Vec<f32> = (0..rows).map(|_| base + rng.f32() * 100.0).collect();
            let mut col = Column::f32("x", x);
            match rng.below(3) {
                0 => {} // non-nullable
                1 => col = col.with_nulls(vec![1.0; rows]), // all-NULL
                _ => {
                    let nulls = (0..rows)
                        .map(|_| if rng.bool(0.3) { 1.0 } else { 0.0 })
                        .collect();
                    col = col.with_nulls(nulls);
                }
            }
            let valid: Vec<f32> =
                (0..rows).map(|_| if rng.bool(0.9) { 1.0 } else { 0.0 }).collect();
            let b = Batch::new(vec![col], valid).unwrap();
            keys.push(client.catalog.store().put(encode_batch(&b)));
        }
        let snap = Snapshot::new(keys, "RawSchema", "fp", 0, "prop");
        commit_table(&client.catalog, MAIN, "rand", snap, "u", "seed", None).unwrap();
        let state = client.catalog.read_ref(MAIN).unwrap();
        let unpruned = client.worker.clone().with_pruning(false);

        for _ in 0..4 {
            let a = (rng.below(4000) as f32) - 2000.0;
            let c = (rng.below(4000) as f32) - 2000.0;
            // mostly sane ranges, sometimes inverted (matches nothing)
            let (lo, hi) =
                if rng.bool(0.2) { (a.max(c) + 1.0, a.min(c)) } else { (a.min(c), a.max(c)) };
            let node = NodeSpec::new("out", "T", "transform_n")
                .input("rand", "RawSchema")
                .with_params(vec![lo, hi, 2.0, 0.5]);
            let fast = client.worker.execute_node(&node, &state).unwrap();
            let slow = unpruned.execute_node(&node, &state).unwrap();
            assert_eq!(fast.batches.len(), slow.batches.len());
            for (p, u) in fast.batches.iter().zip(&slow.batches) {
                assert_eq!(
                    encode_batch(p),
                    encode_batch(u),
                    "pruning changed published bytes (lo={lo}, hi={hi})"
                );
            }
        }
        // An inverted range matches nothing, so every batch must prune —
        // and the result must still match the unpruned oracle.
        let before = client.worker.metrics.counter("scan.batches_pruned");
        let node = NodeSpec::new("out", "T", "transform_n")
            .input("rand", "RawSchema")
            .with_params(vec![1.0, -1.0, 2.0, 0.5]);
        let fast = client.worker.execute_node(&node, &state).unwrap();
        let slow = unpruned.execute_node(&node, &state).unwrap();
        for (p, u) in fast.batches.iter().zip(&slow.batches) {
            assert_eq!(encode_batch(p), encode_batch(u));
        }
        let after = client.worker.metrics.counter("scan.batches_pruned");
        assert_eq!(after - before, n_batches as u64, "inverted range prunes every batch");
    });
}
