//! Integration tests for the deterministic lakehouse simulator
//! (`rust/src/sim/`): determinism, the guardrail-on safety sweep, the
//! Fig. 3 / Fig. 4 counterexample rediscovery with shrinking, the
//! jobs=1-vs-jobs=4 projection property, and trace/outcome JSON.
//!
//! Spec: `doc/SIMULATION.md`. The CI `simulate` job runs the same
//! checks at larger scale through the CLI (200 seeds + pinned
//! counterexample seeds).

use bauplan::model::{check, Scenario};
use bauplan::sim::{
    generate_trace, replay, shrink, simulate, trace_from_json, trace_to_json, AgentSource,
    SimConfig, SimOp, ViolationKind,
};
use bauplan::testing::for_cases;
use bauplan::util::json::Json;

/// Pinned no-guardrail seed whose first violation is the Fig. 3 mixed-
/// main state (a direct-write run leaves a partial prefix on main).
const FIG3_SEED: u64 = 11;

/// Pinned no-guardrail seed whose first violation is the Fig. 4 move
/// (agent forks an aborted transactional branch and merges it to main).
const FIG4_SEED: u64 = 199;

#[test]
fn same_seed_same_trace_same_verdict() {
    let a = simulate(&SimConfig::new(17)).unwrap();
    let b = simulate(&SimConfig::new(17)).unwrap();
    assert_eq!(
        trace_to_json(&a.trace).to_string(),
        trace_to_json(&b.trace).to_string(),
        "same seed must generate the same trace"
    );
    assert_eq!(
        a.verdict_json().to_string(),
        b.verdict_json().to_string(),
        "same trace must reach the same verdict"
    );
    assert_eq!(a.model_digest, b.model_digest);
}

#[test]
fn guardrails_hold_across_a_seed_sweep() {
    // the paper's stack: transactional protocol + visibility guardrail.
    // Crashes, kills, journal faults, GC, checkpoints — no trace may
    // violate any oracle. (CI runs 200 seeds through the CLI; this is
    // the in-tree smoke slice.)
    for seed in 1..=25u64 {
        let report = simulate(&SimConfig::new(seed)).unwrap();
        assert!(
            report.violation.is_none(),
            "seed {seed} violated with guardrails on: {:?}",
            report.violation
        );
    }
}

#[test]
fn concurrent_committers_never_contend_across_a_seed_sweep() {
    // the OCC schedule oracle: every few trace ops, two OS threads
    // chain strict-CAS commits on disjoint scratch branches. Per-branch
    // OCC promises disjoint branches never conflict, and the bursts
    // must not disturb any other oracle — nor the model digest, since
    // the scratch branches never enter the model.
    for seed in [1u64, 7, 11, 42] {
        let plain = simulate(&SimConfig::new(seed)).unwrap();
        let report = simulate(&SimConfig::concurrent(seed)).unwrap();
        assert!(
            report.violation.is_none(),
            "seed {seed} violated with concurrent committers: {:?}",
            report.violation
        );
        assert_eq!(
            report.model_digest, plain.model_digest,
            "seed {seed}: committer bursts changed the published state"
        );
    }
}

#[test]
fn no_guardrail_rediscovers_fig3_and_shrinks() {
    let config = SimConfig::no_guardrail(FIG3_SEED);
    let report = simulate(&config).unwrap();
    let v = report.violation.clone().expect("no-guardrail seed must violate");
    assert_eq!(v.kind, ViolationKind::Fig3MixedMain, "got: {v:?}");

    let end = (v.at_op + 1).min(report.trace.len());
    let shrunk = shrink(&report.trace[..end], &config, v.kind);
    assert!(shrunk.len() <= 8, "shrunk trace too long ({} ops): {shrunk:?}", shrunk.len());

    // the shrunken trace still reproduces the exact verdict kind
    let replayed = replay(&shrunk, &config).unwrap();
    assert_eq!(replayed.violation.as_ref().map(|v| v.kind), Some(ViolationKind::Fig3MixedMain));
}

#[test]
fn no_guardrail_rediscovers_fig4_and_shrinks() {
    let config = SimConfig::no_guardrail(FIG4_SEED);
    let report = simulate(&config).unwrap();
    let v = report.violation.clone().expect("no-guardrail seed must violate");
    assert_eq!(v.kind, ViolationKind::Fig4AbortedBranchMerge, "got: {v:?}");

    let end = (v.at_op + 1).min(report.trace.len());
    let shrunk = shrink(&report.trace[..end], &config, v.kind);
    assert!(shrunk.len() <= 8, "shrunk trace too long ({} ops): {shrunk:?}", shrunk.len());
    // the minimal Fig. 4 trace must still contain the attack: a fork of
    // an aborted branch and the merge to main
    assert!(shrunk.iter().any(|o| matches!(o, SimOp::AgentFork { .. })), "{shrunk:?}");
    assert!(shrunk.iter().any(|o| matches!(o, SimOp::AgentMerge)), "{shrunk:?}");

    let replayed = replay(&shrunk, &config).unwrap();
    assert_eq!(
        replayed.violation.as_ref().map(|v| v.kind),
        Some(ViolationKind::Fig4AbortedBranchMerge)
    );
}

#[test]
fn shrunken_trace_replays_byte_identical_verdicts() {
    let config = SimConfig::no_guardrail(FIG4_SEED);
    let report = simulate(&config).unwrap();
    let v = report.violation.clone().unwrap();
    let end = (v.at_op + 1).min(report.trace.len());
    let shrunk = shrink(&report.trace[..end], &config, v.kind);
    // replaying the same shrunken trace twice yields byte-identical
    // verdict JSON — what makes a CI-reported seed reproducible locally
    let a = replay(&shrunk, &config).unwrap();
    let b = replay(&shrunk, &config).unwrap();
    assert_eq!(a.verdict_json().to_string(), b.verdict_json().to_string());
    assert_eq!(a.model_digest, b.model_digest);
}

#[test]
fn handcrafted_fig4_trace_needs_no_search() {
    // the paper's Fig. 4 counterexample, written out by hand: a txn run
    // writes one table and aborts; an agent forks the aborted branch and
    // merges it into main — main now holds a partial state
    let trace = vec![
        SimOp::BeginRun { transactional: true },
        SimOp::StepRun { run: 0 },
        SimOp::FailRun { run: 0 },
        SimOp::AgentFork { from: AgentSource::AbortedTxn(0) },
        SimOp::AgentMerge,
    ];
    let report = replay(&trace, &SimConfig::no_guardrail(0)).unwrap();
    let v = report.violation.expect("fig4 trace must violate without the guardrail");
    assert_eq!(v.kind, ViolationKind::Fig4AbortedBranchMerge);
    assert_eq!(v.at_op, 4, "the merge is the violating op");

    // with the guardrail on, the same trace is safe: the fork is refused
    let report = replay(&trace, &SimConfig::new(0)).unwrap();
    assert!(report.violation.is_none(), "guardrail failed: {:?}", report.violation);
    assert_eq!(report.guardrail_refusals, 1, "the fork must have been refused");
}

#[test]
fn handcrafted_fig3_trace_needs_no_search() {
    // Fig. 3 top: a direct-write run's very first table commit exposes a
    // partial state on main
    let trace = vec![SimOp::BeginRun { transactional: false }, SimOp::StepRun { run: 0 }];
    let report = replay(&trace, &SimConfig::no_guardrail(0)).unwrap();
    let v = report.violation.expect("direct write must violate");
    assert_eq!(v.kind, ViolationKind::Fig3MixedMain);
    assert_eq!(v.at_op, 1);

    // guardrail on: direct-write runs are unrepresentable (skipped)
    let report = replay(&trace, &SimConfig::new(0)).unwrap();
    assert!(report.violation.is_none());
    assert_eq!(report.applied, 0);
    assert_eq!(report.skipped, 2);
}

#[test]
fn jobs_width_is_projection_invariant() {
    // satellite property: the same trace with every FullRun forced to
    // jobs=1 vs jobs=4 publishes the same model projection and verdict
    for_cases(4, |rng| {
        let seed = rng.next_u64() % 1_000 + 1;
        let base = generate_trace(seed, 25, true);
        let with_jobs = |j: u8| -> Vec<SimOp> {
            base.iter()
                .map(|op| match op {
                    SimOp::FullRun { transactional, fault, mid_run_write, .. } => {
                        SimOp::FullRun {
                            transactional: *transactional,
                            jobs: j,
                            fault: *fault,
                            mid_run_write: *mid_run_write,
                        }
                    }
                    other => other.clone(),
                })
                .collect()
        };
        let config = SimConfig::new(seed);
        let r1 = replay(&with_jobs(1), &config).unwrap();
        let r4 = replay(&with_jobs(4), &config).unwrap();
        assert_eq!(
            r1.model_digest, r4.model_digest,
            "seed {seed}: jobs=1 and jobs=4 must project onto the same model state"
        );
        assert_eq!(r1.verdict_json().to_string(), r4.verdict_json().to_string());
    });
}

#[test]
fn generator_schedules_rotation_and_compaction() {
    // the maintenance cycle (checkpoint -> rotate_segment -> compact)
    // must put segment rotations and compactions *inside* traces, so the
    // double-recover oracle routinely crosses segment boundaries and
    // retired history
    let mut saw_rotate = false;
    let mut saw_compact = false;
    for seed in 1..=40u64 {
        let t = generate_trace(seed, 60, true);
        saw_rotate |= t.iter().any(|o| matches!(o, SimOp::RotateSegment));
        saw_compact |= t.iter().any(|o| matches!(o, SimOp::Compact));
        if saw_rotate && saw_compact {
            break;
        }
    }
    assert!(saw_rotate, "no generated trace contained a RotateSegment op");
    assert!(saw_compact, "no generated trace contained a Compact op");
}

#[test]
fn rotation_and_compaction_mid_trace_keep_recovery_idempotent() {
    // a handcrafted trace that rotates and compacts between mutations and
    // crash-recoveries: every CrashRecover (plus the end-of-trace one)
    // runs the double-recover byte-identical oracle against a segmented,
    // partially retired journal
    let trace = vec![
        SimOp::BeginRun { transactional: true },
        SimOp::StepRun { run: 0 },
        SimOp::RotateSegment,
        SimOp::StepRun { run: 0 },
        SimOp::Checkpoint,
        SimOp::EnvWrite,
        SimOp::Compact,
        SimOp::CrashRecover,
        SimOp::EnvWrite,
        SimOp::RotateSegment,
        SimOp::Compact,
        SimOp::CrashRecover,
    ];
    let report = replay(&trace, &SimConfig::new(0)).unwrap();
    assert!(report.violation.is_none(), "violation: {:?}", report.violation);
    // maintenance ops are always applicable on a live journal
    assert_eq!(report.skipped, 0, "maintenance ops were skipped: {report:?}");
}

#[test]
fn trace_files_roundtrip_through_text() {
    // what `--ops-file` consumes: trace -> JSON text -> trace
    let trace = generate_trace(42, 35, false);
    let text = trace_to_json(&trace).to_string();
    let back = trace_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, trace);
}

#[test]
fn model_check_outcomes_export_canonical_json() {
    // satellite: CheckOutcome/Trace machine-readable export (what
    // `bauplan model-check` prints)
    let out = check(&Scenario::counterexample());
    let parsed = Json::parse(&out.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("scenario").as_str(), Some("fig4_aborted_branch_visible"));
    assert!(parsed.get("states_explored").as_usize().unwrap() > 0);
    let violation = parsed.get("violation");
    let ops = violation.get("ops").as_arr().expect("fig4 must violate");
    assert!(!ops.is_empty());
    // every op is a tagged object
    assert!(ops.iter().all(|o| o.get("op").as_str().is_some()));
    assert!(violation.get("main_tables").as_obj().is_some());

    // a clean scenario exports violation: null
    let clean = check(&Scenario::counterexample_fixed());
    let parsed = Json::parse(&clean.to_json().to_string()).unwrap();
    assert_eq!(*parsed.get("violation"), Json::Null);
}
