//! Runtime-layer integration: the AOT artifacts produce the same numbers
//! through PJRT-from-rust as the jax/pallas kernels did under pytest.
//!
//! This closes the loop on the three-layer architecture: L1/L2 are
//! verified against ref.py in python; here we verify L3's view of the
//! same executables (HLO-text round-trip, literal conversion, tuple
//! unwrapping) against independent rust reference implementations.

use std::path::Path;
use std::sync::Arc;

use bauplan::runtime::{ExecHandle, TensorArg};
use bauplan::testing::Rng;
use std::sync::OnceLock;

static RT: OnceLock<Option<Arc<ExecHandle>>> = OnceLock::new();

/// The shared PJRT runtime, or `None` when it cannot start (missing
/// `artifacts/` or the stub `runtime::pjrt` shim) — tests skip instead
/// of failing.
fn runtime() -> Option<Arc<ExecHandle>> {
    RT.get_or_init(|| {
        ExecHandle::start_pool(Path::new("artifacts"), 2).ok().map(Arc::new)
    })
    .clone()
}

/// Skip the test (early return) when the PJRT runtime is unavailable.
macro_rules! require_rt {
    ($rt:ident) => {
        let Some($rt) = runtime() else {
            eprintln!("skipping: PJRT runtime unavailable (needs artifacts + xla crate)");
            return;
        };
    };
}

const N: usize = 2048;
const G: usize = 64;

#[test]
fn manifest_matches_compiled_artifacts() {
    require_rt!(rt);
    assert_eq!(rt.manifest().n, N);
    assert_eq!(rt.manifest().g, G);
    let mut names = rt.artifact_names();
    names.sort();
    assert!(names.contains(&"parent"));
    assert!(names.contains(&"validate_n"));
    assert_eq!(names.len(), rt.manifest().artifacts.len());
}

#[test]
fn parent_artifact_matches_rust_reference() {
    require_rt!(rt);
    let mut rng = Rng::new(11);
    let col1: Vec<i32> = (0..N).map(|_| rng.below(G) as i32).collect();
    let col2: Vec<f32> = (0..N).map(|_| 1.7e9 + rng.f32() * 1e5).collect();
    let col3: Vec<f32> = (0..N).map(|_| rng.f32() * 10.0).collect();
    let valid: Vec<f32> = (0..N).map(|_| if rng.bool(0.85) { 1.0 } else { 0.0 }).collect();

    let out = rt
        .execute(
            "parent",
            &[
                TensorArg::I32(col1.clone()),
                TensorArg::F32(col2.clone()),
                TensorArg::F32(col3.clone()),
                TensorArg::F32(valid.clone()),
            ],
        )
        .unwrap();

    let keys = out[0].as_i32().unwrap();
    let rep2 = out[1].as_f32().unwrap();
    let sums = out[2].as_f32().unwrap();
    let vout = out[3].as_f32().unwrap();

    let mut esum = vec![0f64; G];
    let mut emax = vec![f32::NEG_INFINITY; G];
    let mut ecnt = vec![0u32; G];
    for i in 0..N {
        if valid[i] > 0.0 {
            let g = col1[i] as usize;
            esum[g] += col3[i] as f64;
            emax[g] = emax[g].max(col2[i]);
            ecnt[g] += 1;
        }
    }
    for g in 0..G {
        assert_eq!(keys[g], g as i32);
        assert_eq!(vout[g] > 0.0, ecnt[g] > 0, "group {g}");
        if ecnt[g] > 0 {
            assert!(
                (sums[g] as f64 - esum[g]).abs() < 1e-2 + esum[g].abs() * 1e-4,
                "group {g}: {} vs {}",
                sums[g],
                esum[g]
            );
            assert_eq!(rep2[g], emax[g], "group {g} max col2");
        } else {
            assert_eq!(sums[g], 0.0);
        }
    }
}

#[test]
fn validate_artifact_matches_rust_stats() {
    require_rt!(rt);
    let mut rng = Rng::new(13);
    let mut x: Vec<f32> = (0..N).map(|_| rng.f32() * 100.0 - 50.0).collect();
    x[7] = f32::NAN;
    x[19] = f32::NAN;
    let include: Vec<f32> = (0..N).map(|_| if rng.bool(0.7) { 1.0 } else { 0.0 }).collect();

    let out = rt
        .execute("validate_n", &[TensorArg::F32(x.clone()), TensorArg::F32(include.clone())])
        .unwrap();
    let s = out[0].as_f32().unwrap();

    let mut cnt = 0.0;
    let mut exc = 0.0;
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    let mut nans = 0.0;
    let mut sum = 0.0f64;
    for i in 0..N {
        if include[i] > 0.0 {
            cnt += 1.0;
            if x[i].is_nan() {
                nans += 1.0;
            } else {
                mn = mn.min(x[i]);
                mx = mx.max(x[i]);
                sum += x[i] as f64;
            }
        } else {
            exc += 1.0;
        }
    }
    assert_eq!(s[0], cnt);
    assert_eq!(s[1], exc);
    assert_eq!(s[2], mn);
    assert_eq!(s[3], mx);
    assert_eq!(s[4], nans);
    assert!((s[5] as f64 - sum).abs() < 1e-1 + sum.abs() * 1e-4);
}

#[test]
fn transform_artifact_filters_projects_casts() {
    require_rt!(rt);
    let x: Vec<f32> = (0..N).map(|i| i as f32 / 100.0 - 5.0).collect();
    let valid = vec![1.0f32; N];
    let params = vec![-2.0f32, 3.0, 2.0, 0.5];
    let out = rt
        .execute(
            "transform_n",
            &[TensorArg::F32(x.clone()), TensorArg::F32(valid), TensorArg::F32(params)],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    let yi = out[1].as_i32().unwrap();
    let keep = out[2].as_f32().unwrap();
    for i in (0..N).step_by(53) {
        let expect_keep = x[i] >= -2.0 && x[i] <= 3.0;
        assert_eq!(keep[i] > 0.0, expect_keep, "row {i}");
        if expect_keep {
            let expect_y = x[i] * 2.0 + 0.5;
            assert!((y[i] - expect_y).abs() < 1e-5);
            assert_eq!(yi[i], expect_y.trunc() as i32);
        } else {
            assert_eq!(y[i], 0.0);
        }
    }
}

#[test]
fn join_artifact_matches_reference() {
    require_rt!(rt);
    let mut rng = Rng::new(17);
    let lkey: Vec<i32> = (0..N).map(|_| rng.range(-3, G as i64 + 3) as i32).collect();
    let lvalid: Vec<f32> = (0..N).map(|_| if rng.bool(0.8) { 1.0 } else { 0.0 }).collect();
    let rkey: Vec<i32> = (0..G as i32).collect();
    let rval: Vec<f32> = (0..G).map(|_| rng.f32() * 9.0).collect();
    let rvalid: Vec<f32> = (0..G).map(|_| if rng.bool(0.9) { 1.0 } else { 0.0 }).collect();

    let out = rt
        .execute(
            "join_n",
            &[
                TensorArg::I32(lkey.clone()),
                TensorArg::F32(lvalid.clone()),
                TensorArg::I32(rkey.clone()),
                TensorArg::F32(rval.clone()),
                TensorArg::F32(rvalid.clone()),
            ],
        )
        .unwrap();
    let oval = out[0].as_f32().unwrap();
    let omatch = out[1].as_f32().unwrap();
    for i in (0..N).step_by(31) {
        let k = lkey[i];
        let expect = if lvalid[i] > 0.0 && k >= 0 && (k as usize) < G && rvalid[k as usize] > 0.0 {
            Some(rval[k as usize])
        } else {
            None
        };
        match expect {
            Some(v) => {
                assert_eq!(omatch[i], 1.0, "row {i}");
                assert_eq!(oval[i], v, "row {i}");
            }
            None => {
                assert_eq!(omatch[i], 0.0, "row {i}");
                assert_eq!(oval[i], 0.0, "row {i}");
            }
        }
    }
}

#[test]
fn executor_rejects_bad_calls() {
    require_rt!(rt);
    // wrong arity
    assert!(rt.execute("parent", &[TensorArg::F32(vec![0.0; N])]).is_err());
    // wrong shape
    assert!(rt
        .execute(
            "validate_n",
            &[TensorArg::F32(vec![0.0; 17]), TensorArg::F32(vec![0.0; 17])]
        )
        .is_err());
    // wrong dtype
    assert!(rt
        .execute(
            "validate_n",
            &[TensorArg::I32(vec![0; N]), TensorArg::F32(vec![0.0; N])]
        )
        .is_err());
    // unknown artifact
    assert!(rt.execute("nonexistent", &[]).is_err());
}

#[test]
fn executor_is_thread_safe() {
    require_rt!(rt);
    let mut handles = vec![];
    for t in 0..4 {
        let rt = rt.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for _ in 0..5 {
                let x: Vec<f32> = (0..N).map(|_| rng.f32()).collect();
                let inc = vec![1.0f32; N];
                let out = rt
                    .execute("validate_n", &[TensorArg::F32(x.clone()), TensorArg::F32(inc)])
                    .unwrap();
                let s = out[0].as_f32().unwrap();
                assert_eq!(s[0], N as f32);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
