//! The crash matrix (CI job `crash-matrix`): every kill point of the
//! durable commit pipeline must recover to a byte-identical catalog
//! export, and recovery must stay tail-bounded — O(uncovered journal
//! tail), never O(history).
//!
//! The matrix itself lives in `bauplan::testing::crash` so other tests
//! (and future subsystems) can reuse it; this file is the CI entry point
//! plus the acceptance-criteria pins.

use bauplan::catalog::{Catalog, JournalConfig, SyncPolicy, Snapshot, MAIN};
use bauplan::testing::commit_table;
use bauplan::testing::crash::{run_crash_matrix, CrashScenario};

fn tmp(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bpl_cmx_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn snap(tag: &str) -> Snapshot {
    Snapshot::new(vec![format!("obj_{tag}")], "S", "fp", 1, "rw")
}

#[test]
fn every_kill_point_recovers_byte_identical() {
    let base = tmp("matrix");
    let outcomes = run_crash_matrix(&base);
    assert_eq!(outcomes.len(), CrashScenario::all().len(), "matrix must run every scenario");
    for outcome in &outcomes {
        outcome.assert_byte_identical();
        // The integrity-audit contract rides the same matrix: every
        // kill point must leave a lake that `bauplan fsck --deep`
        // passes, both before and after recovery (doc/FSCK.md).
        outcome.assert_fsck_clean();
    }
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn lost_sync_window_actually_loses_the_unsynced_burst() {
    // guard against the lost-window scenario degenerating into a no-op:
    // the recovered export equals the *synced* prefix, which must differ
    // from what the crashed process had applied in memory
    let base = tmp("window");
    let outcome = bauplan::testing::crash::run_scenario(
        &base.join("lost_sync_window"),
        CrashScenario::LostSyncWindow,
    )
    .unwrap();
    outcome.assert_byte_identical();
    outcome.assert_fsck_clean();
    // the harness stores real content-addressed objects, so the lost
    // burst is identified by the hash its snapshot would have carried
    let lost_key = bauplan::util::id::content_hash(b"crash matrix object lost0");
    assert!(
        !outcome.recovered_export.contains(&lost_key),
        "the unsynced burst survived the power cut"
    );
    let _ = std::fs::remove_dir_all(&base);
}

/// The acceptance-criteria pin: after a long history with a fresh
/// checkpoint, recovery reads only the journal tail. 10k commits produce
/// megabytes of journal across dozens of segments; the reopened catalog
/// must prove it scanned only the active tail — covered segments are
/// skipped by file name with zero bytes read.
#[test]
fn recovery_is_tail_bounded() {
    let dir = tmp("tail");
    let config = JournalConfig {
        sync: SyncPolicy::Batch(1024),
        segment_bytes: 64 * 1024,
        compact_after_deltas: u64::MAX, // keep the delta path (no compaction)
        sync_latency_micros: 0,
    };

    let total_journal_bytes;
    let head_before;
    {
        let cat = Catalog::open_durable_cfg(&dir, config).unwrap();
        for i in 0..10_000u32 {
            commit_table(&cat, MAIN, "t", snap(&i.to_string()), "u", "m", None).unwrap();
        }
        cat.checkpoint().unwrap();
        // a short tail above the checkpoint floor
        for i in 0..3u32 {
            commit_table(&cat, MAIN, "tail", snap(&format!("tl{i}")), "u", "m", None).unwrap();
        }
        total_journal_bytes = cat.journal_stats().unwrap().bytes_written;
        head_before = cat.resolve(MAIN).unwrap();
    }

    let cat = Catalog::open_durable_cfg(&dir, config).unwrap();
    assert_eq!(cat.resolve(MAIN).unwrap(), head_before);
    let stats = cat.recovery_stats().unwrap();

    assert!(stats.segments_skipped >= 20, "long history must be skipped: {stats:?}");
    assert_eq!(stats.records_replayed, 3, "only the tail replays: {stats:?}");
    assert!(
        stats.bytes_scanned <= 2 * config.segment_bytes,
        "recovery read {} bytes of a {} byte journal — not tail-bounded: {stats:?}",
        stats.bytes_scanned,
        total_journal_bytes,
    );
    // the skipped history dwarfs what was scanned
    assert!(
        stats.bytes_scanned * 10 < total_journal_bytes,
        "scanned {} of {} journal bytes: {stats:?}",
        stats.bytes_scanned,
        total_journal_bytes,
    );
    drop(cat);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Compaction bounds recovery even harder: covered segments are deleted,
/// so a recover after compact scans only the fresh active segment.
#[test]
fn compaction_retires_covered_segments() {
    let dir = tmp("compact");
    let config = JournalConfig {
        sync: SyncPolicy::Batch(256),
        segment_bytes: 8 * 1024,
        compact_after_deltas: 4,
        sync_latency_micros: 0,
    };
    {
        let cat = Catalog::open_durable_cfg(&dir, config).unwrap();
        for i in 0..500u32 {
            commit_table(&cat, MAIN, "t", snap(&i.to_string()), "u", "m", None).unwrap();
        }
        let covered = cat.compact().unwrap();
        assert!(covered >= 500);
    }
    // after compaction the segment directory holds only the fresh active
    // segment (plus nothing else)
    let seg_count = std::fs::read_dir(dir.join("journal"))
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().starts_with("seg-"))
        .count();
    assert_eq!(seg_count, 1, "compaction must retire covered segments");

    let cat = Catalog::open_durable_cfg(&dir, config).unwrap();
    let stats = cat.recovery_stats().unwrap();
    assert_eq!(stats.records_replayed, 0);
    assert_eq!(stats.segments_scanned, 1);
    assert!(stats.base_seq >= 500);
    assert_eq!(cat.read_ref(MAIN).unwrap().tables["t"], snap("499").id);
    drop(cat);
    let _ = std::fs::remove_dir_all(&dir);
}
