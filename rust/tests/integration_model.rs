//! Model-checker integration: the paper's §4 findings, as assertions.
//!
//! These mirror the Alloy runs the paper reports: minimal adequacy
//! (Fig. 3 asymmetry reproducible) and the Fig. 4 counterexample with
//! its guardrail fix — plus cross-validation against the *real* catalog:
//! the trace the model finds is replayed on the actual implementation
//! and produces the same inconsistency.

use std::sync::Arc;

use bauplan::catalog::{BranchState, Catalog, Snapshot, MAIN};
use bauplan::model::{check, Op, Scenario};
use bauplan::storage::ObjectStore;
use bauplan::testing::commit_table;

#[test]
fn adequacy_fig3_top_found_bottom_safe() {
    let top = check(&Scenario::direct_writes());
    assert!(top.violation.is_some(), "Fig.3-top must be reachable");

    let bottom = check(&Scenario::paper_protocol());
    assert!(bottom.violation.is_none(), "Fig.3-bottom must be safe");
    // exhaustive within scope, not a truncated search
    assert!(bottom.states_explored < Scenario::paper_protocol().max_states);
}

#[test]
fn fig4_shortest_trace_has_the_paper_shape() {
    let out = check(&Scenario::counterexample());
    let t = out.violation.expect("counterexample must exist");
    // shape: a run begins, writes at least one table, fails; an agent
    // forks the aborted branch and merges into main.
    let has = |f: &dyn Fn(&Op) -> bool| t.ops.iter().any(|o| f(o));
    assert!(has(&|o| matches!(o, Op::BeginRun { transactional: true, .. })));
    assert!(has(&|o| matches!(o, Op::StepRun { .. })));
    assert!(has(&|o| matches!(o, Op::FailRun { .. })));
    assert!(has(&|o| matches!(o, Op::AgentFork { .. })));
    assert!(has(&|o| matches!(o, Op::MergeToMain { .. })));
    println!("Fig.4 counterexample:\n{}", t.render());
}

#[test]
fn guardrail_scenario_is_exhaustively_safe() {
    let out = check(&Scenario::counterexample_fixed());
    assert!(out.violation.is_none());
    assert!(out.states_explored < Scenario::counterexample_fixed().max_states,
            "search must exhaust the scope, not truncate");
}

/// Replay the model's counterexample trace against the real catalog:
/// the implementation without the guardrail reaches the same mixed state,
/// and the guardrail blocks exactly the offending step.
#[test]
fn counterexample_replays_on_real_catalog() {
    let c = Catalog::new(Arc::new(ObjectStore::new()));
    let snap = |tag: &str, run: &str| Snapshot::new(vec![tag.into()], "S", "fp", 1, run);

    // run_1 publishes the full pipeline (P, C) atomically
    c.create_txn_branch(MAIN, "run1").unwrap();
    commit_table(&c, "txn/run1", "P", snap("p1", "run1"), "u", "m", Some("run1".into())).unwrap();
    commit_table(&c, "txn/run1", "C", snap("c1", "run1"), "u", "m", Some("run1".into())).unwrap();
    c.merge("txn/run1", MAIN, false).unwrap();
    c.set_branch_state("txn/run1", BranchState::Merged).unwrap();
    c.delete_branch("txn/run1").unwrap();

    // run_2 writes P then fails; branch aborted
    c.create_txn_branch(MAIN, "run2").unwrap();
    commit_table(&c, "txn/run2", "P", snap("p2", "run2"), "u", "m", Some("run2".into())).unwrap();
    c.set_branch_state("txn/run2", BranchState::Aborted).unwrap();

    // main is consistent: all tables from run1
    let writers_consistent = |cat: &Catalog| {
        let head = cat.read_ref(MAIN).unwrap();
        let runs: std::collections::BTreeSet<String> = ["P", "C"]
            .iter()
            .filter_map(|t| head.tables.get(*t))
            .map(|s| cat.get_snapshot(s).unwrap().run_id)
            .collect();
        runs.len() <= 1
    };
    assert!(writers_consistent(&c));

    // the agent move, guardrail ON: blocked
    assert!(c.create_branch("agent", "txn/run2", false).is_err());
    assert!(writers_consistent(&c));

    // the agent move with the capability (modeling a system WITHOUT the
    // guardrail): the Fig. 4 inconsistency materializes on main
    c.create_branch("agent", "txn/run2", true).unwrap();
    c.merge("agent", MAIN, false).unwrap();
    assert!(!writers_consistent(&c), "Fig.4: main now mixes run1 and run2");
}

#[test]
fn model_scales_with_scope() {
    // sanity: bigger scopes explore strictly more states (bench E7 input)
    let small = check(&Scenario { max_runs: 1, ..Scenario::paper_protocol() });
    let big = check(&Scenario::paper_protocol());
    assert!(big.states_explored > small.states_explored);
}
