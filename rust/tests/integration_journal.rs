//! Durability integration: the commit journal, checkpoints, and
//! `Catalog::recover`.
//!
//! These tests are the enforcement arm of `doc/COMMIT_PIPELINE.md` —
//! each spec invariant names the test here that pins it. The central
//! acceptance property: a process killed at *any* point between a
//! journal append and the next checkpoint recovers to the exact
//! pre-crash state, demonstrated as byte-identical canonical exports.

use std::io::Write;
use std::path::PathBuf;

use bauplan::catalog::{BranchState, Catalog, Snapshot, SyncPolicy, JOURNAL_DIR, MAIN};
use bauplan::error::BauplanError;
use bauplan::testing::{commit_table, commit_table_cas};

/// Fresh per-test scratch directory.
fn test_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "bpl_journal_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Sorted `seg-*.jsonl` paths under the lake's journal directory. The
/// name embeds the segment's first sequence number, so lexicographic
/// order is replay order and the last entry is the active tail.
fn seg_files(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(dir.join(JOURNAL_DIR))
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("seg-") && n.ends_with(".jsonl"))
                .unwrap_or(false)
        })
        .collect();
    v.sort();
    v
}

fn put_snap(c: &Catalog, tag: u8) -> Snapshot {
    let key = c.store().put(vec![tag; 32]);
    Snapshot::new(vec![key], "S", "fp", 1, "r")
}

/// A representative mutation workload touching every journaled op:
/// plain commits, a CAS commit, branch create, tag, three-way merge,
/// fast-forward merge, table deletion, txn-branch lifecycle, branch
/// deletion.
fn workload(c: &Catalog) {
    commit_table(c, MAIN, "base", put_snap(c, 1), "u", "seed base", None).unwrap();
    commit_table(c, MAIN, "doomed", put_snap(c, 2), "u", "seed doomed", None).unwrap();

    // optimistic-concurrency write
    let head = c.resolve(MAIN).unwrap();
    commit_table_cas(c, MAIN, &head, "base", put_snap(c, 3), "u", "cas write", None)
        .unwrap();

    // three-way merge: disjoint tables on dev vs main
    c.create_branch("dev", MAIN, false).unwrap();
    commit_table(c, "dev", "from_dev", put_snap(c, 4), "u", "dev adds", None).unwrap();
    commit_table(c, MAIN, "from_main", put_snap(c, 5), "u", "main adds", None).unwrap();
    c.merge("dev", MAIN, false).unwrap();

    // fast-forward merge
    c.create_branch("ff", MAIN, false).unwrap();
    commit_table(c, "ff", "ffed", put_snap(c, 6), "u", "ff adds", None).unwrap();
    c.merge("ff", MAIN, false).unwrap();

    // tag + table drop + branch drop
    c.tag("v1", MAIN).unwrap();
    c.delete_table(MAIN, "doomed", "u", None).unwrap();
    c.delete_branch("ff").unwrap();

    // a finished (aborted) transactional run, retained for triage
    c.create_txn_branch(MAIN, "r_aborted").unwrap();
    commit_table(c, "txn/r_aborted", "partial", put_snap(c, 7), "u", "partial", None)
        .unwrap();
    c.set_branch_state("txn/r_aborted", BranchState::Aborted).unwrap();
}

#[test]
fn fresh_recover_starts_at_init() {
    let dir = test_dir("fresh");
    let c = Catalog::recover(&dir).unwrap();
    assert!(c.is_durable());
    assert_eq!(c.durable_dir().unwrap(), dir);
    let main = c.read_ref(MAIN).unwrap();
    assert!(main.tables.is_empty());
    // two fresh durable lakes are byte-identical (deterministic init)
    let dir2 = test_dir("fresh2");
    let c2 = Catalog::recover(&dir2).unwrap();
    assert_eq!(c.export().to_string(), c2.export().to_string());
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

#[test]
fn recovery_without_checkpoint_is_byte_identical() {
    let dir = test_dir("nockpt");
    let pre;
    {
        let c = Catalog::recover(&dir).unwrap();
        workload(&c);
        pre = c.export().to_string();
        // process dies here: no checkpoint was ever written
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.export().to_string(), pre, "recovered state must be byte-identical");
    // refs behave identically
    assert_eq!(r.resolve("v1").unwrap(), r.resolve("v1").unwrap());
    assert!(r.read_ref(MAIN).unwrap().tables.contains_key("from_dev"));
    assert!(!r.read_ref(MAIN).unwrap().tables.contains_key("doomed"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_between_append_and_checkpoint_recovers_exact_head() {
    // The acceptance scenario: checkpoint, then more journaled writes,
    // then the process dies before the *next* checkpoint.
    let dir = test_dir("midtail");
    let pre_head;
    let pre_export;
    {
        let c = Catalog::recover(&dir).unwrap();
        workload(&c);
        c.checkpoint().unwrap();
        // journal tail past the checkpoint
        commit_table(&c, MAIN, "tail1", put_snap(&c, 8), "u", "after ckpt 1", None).unwrap();
        commit_table(&c, MAIN, "tail2", put_snap(&c, 9), "u", "after ckpt 2", None).unwrap();
        c.tag("v2", MAIN).unwrap();
        pre_head = c.resolve(MAIN).unwrap();
        pre_export = c.export().to_string();
        // killed here — between journal append and checkpoint
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.resolve(MAIN).unwrap(), pre_head, "exact pre-crash head");
    assert_eq!(r.export().to_string(), pre_export, "byte-identical export");
    assert_eq!(r.resolve("v2").unwrap(), pre_head);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_bounds_replay_and_compact_retires_segments() {
    let dir = test_dir("truncate");
    let covered;
    {
        let c = Catalog::recover(&dir).unwrap();
        workload(&c);
        assert!(!seg_files(&dir).is_empty(), "journal grew during the workload");
        // a delta checkpoint does not rewrite the journal — it bounds
        // the next recovery's replay
        covered = c.checkpoint().unwrap();
        assert!(covered > 0);
        commit_table(&c, MAIN, "more", put_snap(&c, 10), "u", "post ckpt", None).unwrap();
        let stats = c.journal_stats().unwrap();
        assert!(stats.last_seq > covered, "seq continues past the checkpoint floor");
    }
    // recovery replays only the tail past the checkpoint
    let r = Catalog::recover(&dir).unwrap();
    assert!(r.read_ref(MAIN).unwrap().tables.contains_key("more"));
    let stats = r.recovery_stats().unwrap();
    assert_eq!(
        stats.records_replayed, 1,
        "only the post-checkpoint tail replays: {stats:?}"
    );
    // compaction folds the deltas into a base snapshot and retires every
    // covered journal segment
    let compacted = r.compact().unwrap();
    assert!(compacted > covered);
    assert_eq!(seg_files(&dir).len(), 1, "covered segments retired");
    let post = r.export().to_string();
    drop(r);
    let r2 = Catalog::recover(&dir).unwrap();
    assert_eq!(r2.export().to_string(), post);
    let stats = r2.recovery_stats().unwrap();
    assert_eq!(stats.records_replayed, 0, "base covers everything: {stats:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_tail_is_discarded_and_journal_reusable() {
    let dir = test_dir("torn");
    let pre;
    {
        let c = Catalog::recover(&dir).unwrap();
        workload(&c);
        pre = c.export().to_string();
    }
    // simulate a write torn mid-record: partial JSON, no newline,
    // appended to the active tail segment
    {
        let active = seg_files(&dir).pop().unwrap();
        let mut f = std::fs::OpenOptions::new().append(true).open(active).unwrap();
        f.write_all(br#"{"crc":"dead","data":{"branch":"main","co"#).unwrap();
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.export().to_string(), pre, "torn suffix ignored, prefix exact");
    // the repaired journal accepts new appends and they survive
    commit_table(&r, MAIN, "after_torn", put_snap(&r, 11), "u", "post repair", None).unwrap();
    let post = r.export().to_string();
    drop(r);
    let r2 = Catalog::recover(&dir).unwrap();
    assert_eq!(r2.export().to_string(), post);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn frozen_segment_corruption_fails_loudly_naming_the_segment() {
    // the contrast with `torn_tail_is_discarded_and_journal_reusable`:
    // damage confined to the active tail is an in-flight write the crash
    // interrupted, so the prefix rule repairs it silently. Damage inside
    // a sealed (frozen) segment means *acknowledged* history was lost,
    // and recovery must refuse to guess — it fails, and the error names
    // the file an operator has to restore.
    let dir = test_dir("frozen");
    {
        let c = Catalog::recover(&dir).unwrap();
        workload(&c);
        c.journal_rotate().unwrap();
        commit_table(&c, MAIN, "tail", put_snap(&c, 12), "u", "post rotate", None).unwrap();
    }
    let segs = seg_files(&dir);
    assert!(segs.len() >= 2, "rotation must have sealed a segment: {segs:?}");
    let frozen = &segs[0];
    // flip one record's payload key without touching line structure: the
    // line still parses as JSON, but its crc no longer matches (headers
    // and seals have no "data" key, so this hits a record line)
    let text = std::fs::read_to_string(frozen).unwrap();
    let corrupted = text.replacen("\"data\"", "\"dat@\"", 1);
    assert_ne!(text, corrupted, "corruption must land on a record line");
    std::fs::write(frozen, corrupted).unwrap();

    let err = Catalog::recover(&dir).unwrap_err();
    assert!(matches!(err, BauplanError::Parse(_)), "got: {err:?}");
    let msg = err.to_string();
    let name = frozen.file_name().unwrap().to_str().unwrap();
    assert!(msg.contains(name), "error must name the corrupt segment: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn aborted_branch_replays_aborted_and_guardrail_holds() {
    // Fig. 4 satellite: the visibility guardrail survives recovery.
    let dir = test_dir("guardrail");
    {
        let c = Catalog::recover(&dir).unwrap();
        commit_table(&c, MAIN, "t", put_snap(&c, 1), "u", "seed", None).unwrap();
        c.create_txn_branch(MAIN, "r1").unwrap();
        commit_table(&c, "txn/r1", "p", put_snap(&c, 2), "u", "partial", Some("r1".into()))
            .unwrap();
        c.set_branch_state("txn/r1", BranchState::Aborted).unwrap();
    }
    let r = Catalog::recover(&dir).unwrap();
    let b = r.branch_info("txn/r1").unwrap();
    assert!(b.transactional);
    assert_eq!(b.state, BranchState::Aborted, "Aborted survives replay");
    // fork refused without the capability...
    let err = r.create_branch("agent", "txn/r1", false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)));
    // ...merge too...
    let err = r.merge("txn/r1", MAIN, false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)));
    // ...and the explicit escape hatch still works
    assert!(r.create_branch("agent", "txn/r1", true).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn orphaned_open_txn_branch_aborts_on_recovery() {
    // A run killed mid-flight leaves its txn branch Open in the journal;
    // recovery must transition it to Aborted (the owning process is
    // gone) and leave the target branch exactly where it was — total
    // failure, never partial.
    let dir = test_dir("orphan");
    let main_head;
    {
        let c = Catalog::recover(&dir).unwrap();
        commit_table(&c, MAIN, "t", put_snap(&c, 1), "u", "seed", None).unwrap();
        main_head = c.resolve(MAIN).unwrap();
        c.create_txn_branch(MAIN, "r_killed").unwrap();
        commit_table(&c, "txn/r_killed", "p1", put_snap(&c, 2), "u", "w1", Some("r_killed".into()))
            .unwrap();
        commit_table(&c, "txn/r_killed", "p2", put_snap(&c, 3), "u", "w2", Some("r_killed".into()))
            .unwrap();
        // killed before merge / abort bookkeeping
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.resolve(MAIN).unwrap(), main_head, "target branch untouched");
    let b = r.branch_info("txn/r_killed").unwrap();
    assert_eq!(b.state, BranchState::Aborted, "orphan aborted by recovery");
    // the partial outputs remain queryable for triage
    let head = r.read_ref("txn/r_killed").unwrap();
    assert!(head.tables.contains_key("p1") && head.tables.contains_key("p2"));
    // recovery is idempotent: a second recover changes nothing
    let export1 = r.export().to_string();
    drop(r);
    let r2 = Catalog::recover(&dir).unwrap();
    assert_eq!(r2.export().to_string(), export1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_sync_recovers_after_flush() {
    let dir = test_dir("batched");
    let pre;
    {
        let c = Catalog::open_durable(&dir, SyncPolicy::Batch(64)).unwrap();
        workload(&c);
        let stats = c.journal_stats().unwrap();
        assert!(
            stats.syncs < stats.appends,
            "batching must amortize fsyncs ({} syncs for {} appends)",
            stats.syncs,
            stats.appends
        );
        c.journal_sync().unwrap();
        pre = c.export().to_string();
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.export().to_string(), pre);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_record_replays_to_identical_state() {
    let dir = test_dir("gc");
    let pre;
    {
        let c = Catalog::recover(&dir).unwrap();
        commit_table(&c, MAIN, "keep", put_snap(&c, 1), "u", "keep", None).unwrap();
        // garbage: branch with unique data, then deleted
        c.create_branch("tmp", MAIN, false).unwrap();
        commit_table(&c, "tmp", "junk", put_snap(&c, 2), "u", "junk", None).unwrap();
        c.delete_branch("tmp").unwrap();
        let (commits, snaps, _, _) = c.gc().unwrap();
        assert_eq!((commits, snaps), (1, 1));
        pre = c.export().to_string();
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.export().to_string(), pre, "gc replays deterministically");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn data_objects_survive_recovery() {
    let dir = test_dir("objects");
    let payload = vec![0xAB; 4096];
    {
        let c = Catalog::recover(&dir).unwrap();
        let key = c.store().put(payload.clone());
        commit_table(&c, MAIN, "blob", Snapshot::new(vec![key], "S", "fp", 1, "r"), "u", "m", None)
            .unwrap();
    }
    let r = Catalog::recover(&dir).unwrap();
    let head = r.read_ref(MAIN).unwrap();
    let snap = r.get_snapshot(&head.tables["blob"]).unwrap();
    assert_eq!(&*r.store().get(&snap.objects[0]).unwrap(), payload.as_slice());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn journal_append_vs_full_export_write_set() {
    // The point of the journal: a commit writes O(delta), not O(history).
    let dir = test_dir("delta");
    let c = Catalog::recover(&dir).unwrap();
    for i in 0..50 {
        commit_table(&c, MAIN, &format!("t{i}"), put_snap(&c, i as u8), "u", "m", None)
            .unwrap();
    }
    let stats_before = c.journal_stats().unwrap();
    commit_table(&c, MAIN, "one_more", put_snap(&c, 200), "u", "m", None).unwrap();
    let stats_after = c.journal_stats().unwrap();
    let record_bytes = stats_after.bytes_written - stats_before.bytes_written;
    let export_bytes = c.export().to_string().len() as u64;
    assert!(
        record_bytes * 10 < export_bytes,
        "journal record ({record_bytes} B) should be far smaller than a \
         full export ({export_bytes} B) on a 50-table lake"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
