//! End-to-end tracing integration: wire-propagated trace context
//! producing one stitched client → server → scheduler → journal trace,
//! journaled run traces surviving kills and double recovery
//! byte-identically, the flight-recorder dump on catalog poisoning, and
//! the Chrome trace-event export.
//!
//! Spec: `doc/OBSERVABILITY.md`.

use std::sync::atomic::{AtomicU64, Ordering};

use bauplan::catalog::{Catalog, Snapshot, MAIN};
use bauplan::client::remote::{RemoteClient, RemoteRunOpts};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::dag::PipelineSpec;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};
use bauplan::server::{Server, ServerConfig};
use bauplan::testing::commit_table;
use bauplan::trace::{chrome_trace_events, TraceCtx, FLIGHT_DIR};
use bauplan::util::json::Json;

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bpl_trace_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The spans of a trace document, as a Vec for direct indexing.
fn spans(trace: &Json) -> &[Json] {
    trace.get("spans").as_arr().expect("trace has spans")
}

fn span_named<'a>(trace: &'a Json, name: &str) -> &'a Json {
    spans(trace)
        .iter()
        .find(|s| s.get("name").as_str() == Some(name))
        .unwrap_or_else(|| panic!("no span named {name}"))
}

// ------------------------------------------------------------ stitching

#[test]
fn loopback_run_produces_one_stitched_trace() {
    let dir = temp_dir("stitch");
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc = RemoteClient::new(&handle.base_url());
    rc.seed_raw_table(MAIN, 2, 300).unwrap();

    // the client-side root context: what a CLI invocation would mint
    let ctx = TraceCtx::new();
    let opts = RemoteRunOpts {
        run_id: Some("run_stitch".into()),
        trace: Some(ctx.clone()),
        ..RemoteRunOpts::default()
    };
    let run = rc.submit_run(PAPER_PIPELINE_TEXT, MAIN, &opts).unwrap();
    assert!(matches!(run.status, RunStatus::Success), "{:?}", run.status);

    // the journaled server-side trace continues the caller's trace id,
    // and its root span is parented at the caller's span
    let trace = rc.get_trace("run_stitch").unwrap().expect("run trace journaled");
    assert_eq!(trace.get("trace_id").as_str(), Some(ctx.trace_id.as_str()));
    assert_eq!(trace.get("origin").as_f64(), Some(ctx.span_id as f64));
    assert_eq!(trace.get("truncated").as_f64(), Some(0.0));

    let run_span = span_named(&trace, "run");
    assert_eq!(run_span.get("parent").as_f64(), Some(ctx.span_id as f64));
    assert_eq!(run_span.get("attrs").get("run_id").as_str(), Some("run_stitch"));
    assert_eq!(run_span.get("attrs").get("mode").as_str(), Some("transactional"));

    // scheduler + one node and one commit span per plan table, all
    // nested inside the run span's interval
    let (run_start, run_end) = (
        run_span.get("start_us").as_f64().unwrap(),
        run_span.get("end_us").as_f64().unwrap(),
    );
    span_named(&trace, "scheduler");
    span_named(&trace, "run.publish");
    for table in ["parent_table", "child_table", "grand_child"] {
        let commit_name = format!("commit:{table}");
        let commits = spans(&trace)
            .iter()
            .filter(|s| s.get("name").as_str() == Some(commit_name.as_str()))
            .count();
        assert_eq!(commits, 1, "commit spans for {table}");
        let n = span_named(&trace, &format!("node:{table}"));
        assert!(n.get("start_us").as_f64().unwrap() >= run_start);
        assert!(n.get("end_us").as_f64().unwrap() <= run_end);
    }

    // the wire half: the server's flight recorder saw the submit
    // request under the same propagated header
    let flight = rc.trace_flight().unwrap();
    let req_span = flight
        .get("spans")
        .as_arr()
        .unwrap()
        .iter()
        .find(|s| {
            s.get("name").as_str() == Some("server.request")
                && s.get("attrs").get("path").as_str() == Some("/v1/runs")
        })
        .expect("submit request in the flight ring");
    assert_eq!(
        req_span.get("attrs").get("trace").as_str(),
        Some(ctx.header_value().as_str())
    );
    assert_eq!(req_span.get("attrs").get("status").as_f64(), Some(200.0));

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ durability

#[test]
fn journaled_trace_survives_kill_and_double_recovery() {
    let dir = temp_dir("kill");
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    client.seed_raw_table(MAIN, 2, 300).unwrap();
    let plan = PipelineSpec::paper_pipeline().plan().unwrap();

    // run A completes; its trace is journaled with the terminal state
    let state = client
        .runner
        .run_with_id(&plan, MAIN, RunMode::Transactional, &FailurePlan::none(), &[], "run_a")
        .unwrap();
    assert!(matches!(state.status, RunStatus::Success));
    let trace_a = client.catalog.get_run_trace("run_a").expect("run_a trace").to_string();

    // run B is killed mid-run (process dies after child_table's commit):
    // no terminal state, so no journaled trace — ever
    let err = client
        .runner
        .run_with_id(
            &plan,
            MAIN,
            RunMode::Transactional,
            &FailurePlan::kill_after("child_table"),
            &[],
            "run_b",
        )
        .unwrap_err();
    assert!(err.to_string().contains("process died"), "{err}");
    assert!(client.catalog.get_run_trace("run_b").is_none());
    drop(client); // the "kill": no checkpoint, the journal is the witness

    // recover twice; run A's trace must come back byte-identically both
    // times, and run B must still have none
    let c1 = Catalog::recover(&dir).unwrap();
    let t1 = c1.get_run_trace("run_a").expect("trace lost in recovery").to_string();
    drop(c1);
    let c2 = Catalog::recover(&dir).unwrap();
    let t2 = c2.get_run_trace("run_a").expect("trace lost in second recovery").to_string();
    assert_eq!(t1, trace_a, "first recovery changed the trace bytes");
    assert_eq!(t2, trace_a, "second recovery changed the trace bytes");
    assert!(c2.get_run_trace("run_b").is_none());
    drop(c2);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ flight dump

#[test]
fn poisoning_dumps_the_flight_ring() {
    let dir = temp_dir("poison");
    let catalog = Catalog::recover(&dir).unwrap();
    let snap = |tag: &str| Snapshot::new(vec![format!("obj_{tag}")], "S", "fp", 1, "rw");
    commit_table(&catalog, MAIN, "t", snap("ok"), "u", "m", None).unwrap();

    // the next group-commit fsync fails: the catalog poisons itself and
    // must dump its recent operations for the post-mortem
    catalog.debug_fail_next_group_sync();
    let _ = commit_table(&catalog, MAIN, "t", snap("doomed"), "u", "m", None).unwrap_err();
    assert!(catalog.is_poisoned());

    let flight_dir = dir.join(FLIGHT_DIR);
    let dumps: Vec<_> = std::fs::read_dir(&flight_dir)
        .expect("flight dir created on poisoning")
        .map(|e| e.unwrap().path())
        .collect();
    assert!(!dumps.is_empty(), "no flight dump written");
    let text = std::fs::read_to_string(&dumps[0]).unwrap();
    let doc = Json::parse(text.trim()).unwrap();
    assert_eq!(doc.get("reason").as_str(), Some("catalog poisoned"));
    assert!(doc.get("flight").get("spans").as_arr().is_some());
    drop(catalog);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ chrome export

#[test]
fn chrome_export_carries_every_span_as_complete_events() {
    let dir = temp_dir("chrome");
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    client.seed_raw_table(MAIN, 2, 300).unwrap();
    let plan = PipelineSpec::paper_pipeline().plan().unwrap();
    client
        .runner
        .run_with_id(&plan, MAIN, RunMode::Transactional, &FailurePlan::none(), &[], "run_c")
        .unwrap();
    let trace = client.catalog.get_run_trace("run_c").unwrap();

    let chrome = chrome_trace_events(&trace);
    let events = chrome.get("traceEvents").as_arr().unwrap();
    assert_eq!(events.len(), spans(&trace).len());
    for e in events {
        assert_eq!(e.get("ph").as_str(), Some("X"));
        assert!(e.get("dur").as_f64().unwrap() >= 0.0);
    }
    assert_eq!(
        chrome.get("otherData").get("trace_id").as_str(),
        trace.get("trace_id").as_str()
    );
    // node spans open their own lanes (parallel tracks in the viewer)
    let node_tid = events
        .iter()
        .find(|e| e.get("name").as_str() == Some("node:parent_table"))
        .unwrap()
        .get("tid")
        .as_f64()
        .unwrap();
    assert_ne!(node_tid, 1.0);
    // the document round-trips as JSON (what `bauplan trace --chrome` writes)
    assert!(Json::parse(&chrome.to_string()).is_ok());
    drop(client);
    let _ = std::fs::remove_dir_all(&dir);
}
