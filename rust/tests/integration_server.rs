//! Wire-layer integration: the zero-dep API server + `RemoteClient`
//! over real loopback TCP connections.
//!
//! Covers the service-boundary checklist from `doc/SERVER.md`:
//! concurrent clients committing to distinct branches, two clients
//! racing one branch (exactly one CAS wins, the loser retries
//! informed), malformed/oversized/truncated request fuzz that must
//! return clean errors without killing the server, server kill +
//! `Catalog::recover` + restart resuming `run get` from the durable
//! registry, error-variant mapping across the wire, and the loopback
//! simulator agreeing with the in-process simulator verdict for verdict,
//! digest for digest.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};

use bauplan::catalog::{BranchState, Catalog, Snapshot, MAIN};
use bauplan::client::remote::{decode_table_frames, RemoteClient, RemoteCommit, RemoteRunOpts};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::dag::NodeSpec;
use bauplan::error::BauplanError;
use bauplan::runs::RunStatus;
use bauplan::server::{Server, ServerConfig, ServerHandle};
use bauplan::sim::{simulate, SimConfig};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "bpl_srv_{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// In-memory sim-backed server on an ephemeral loopback port.
fn start_mem_server() -> (ServerHandle, RemoteClient) {
    let client = Client::open_sim().unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc = RemoteClient::new(&handle.base_url());
    (handle, rc)
}

/// Raw HTTP exchange: send `req` bytes, half-close, read to EOF. Write
/// errors are tolerated — a server refusing an oversized request may
/// close the socket while the client is still sending.
fn raw_request(addr: SocketAddr, req: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    let _ = s.write_all(req);
    let _ = s.shutdown(Shutdown::Write);
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

/// [`raw_request`] for binary-bodied responses (the frame stream is not
/// UTF-8, so `read_to_string` would drop it).
fn raw_request_bytes(addr: SocketAddr, req: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    let _ = s.write_all(req);
    let _ = s.shutdown(Shutdown::Write);
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    out
}

/// Split a raw HTTP response into (head, body) at the blank line.
fn split_response(raw: &[u8]) -> (String, &[u8]) {
    let at = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("head/body split") + 4;
    (String::from_utf8_lossy(&raw[..at]).into_owned(), &raw[at..])
}

// ------------------------------------------------------------ concurrency

#[test]
fn concurrent_clients_commit_to_distinct_branches() {
    let (handle, rc0) = start_mem_server();
    let clients = 6usize;
    let commits = 8usize;
    let mut joins = Vec::new();
    for t in 0..clients {
        let url = handle.base_url();
        joins.push(std::thread::spawn(move || {
            let rc = RemoteClient::new(&url);
            let branch = format!("tenant{t}");
            rc.create_branch(&branch, MAIN, false).unwrap();
            for i in 0..commits {
                let table = format!("t{i}");
                let content = format!("{branch}:{i}");
                let commit = RemoteCommit::new(&branch, &table, &content).retrying();
                rc.commit(&commit).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // every tenant's writes landed, linearly, on its own branch
    for t in 0..clients {
        let branch = format!("tenant{t}");
        let head = rc0.read_ref(&branch).unwrap();
        assert_eq!(head.tables.len(), commits, "{branch}");
        assert_eq!(rc0.log(&branch, 100).unwrap().len(), commits + 1, "{branch}");
    }
    // main untouched by tenant branches
    assert!(rc0.read_ref(MAIN).unwrap().tables.is_empty());
    handle.shutdown();
}

#[test]
fn cas_race_on_one_branch_exactly_one_wins() {
    let (handle, rc) = start_mem_server();
    let head = rc.branch_info(MAIN).unwrap().head;
    // two clients race the same expected head
    let mut joins = Vec::new();
    for t in 0..2 {
        let url = handle.base_url();
        let head = head.clone();
        joins.push(std::thread::spawn(move || {
            let rc = RemoteClient::new(&url);
            let content = format!("racer{t}");
            let mut commit = RemoteCommit::new(MAIN, "contested", &content);
            commit.expected_head = Some(&head);
            rc.commit(&commit).map(|_| ())
        }));
    }
    let results: Vec<Result<(), BauplanError>> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    let wins = results.iter().filter(|r| r.is_ok()).count();
    assert_eq!(wins, 1, "exactly one CAS must win: {results:?}");
    for r in &results {
        if let Err(e) = r {
            assert!(matches!(e, BauplanError::CasConflict { .. }), "loser got {e}");
        }
    }
    // the loser retries informed (fresh head) and succeeds
    let out = rc.commit(&RemoteCommit::new(MAIN, "contested", "retry").retrying()).unwrap();
    assert_eq!(rc.branch_info(MAIN).unwrap().head, out.commit);
    assert_eq!(rc.log(MAIN, 10).unwrap().len(), 3); // init + winner + retry
    handle.shutdown();
}

#[test]
fn cas_conflict_crosses_the_wire_as_retryable_409() {
    let (handle, rc) = start_mem_server();
    let stale = rc.branch_info(MAIN).unwrap().head;
    rc.commit(&RemoteCommit::new(MAIN, "t", "move the head").retrying()).unwrap();
    let body = format!(
        "{{\"branch\":\"main\",\"table\":\"t\",\"content\":\"x\",\"expected_head\":\"{stale}\"}}"
    );
    let req = format!(
        "POST /v1/commit HTTP/1.1\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    let resp = raw_request(handle.addr(), req.as_bytes());
    assert!(resp.starts_with("HTTP/1.1 409"), "{resp}");
    assert!(resp.contains("\"code\":\"cas_conflict\""), "{resp}");
    assert!(resp.contains("\"retryable\":true"), "{resp}");
    handle.shutdown();
}

// ------------------------------------------------------------ fuzz

#[test]
fn malformed_oversized_truncated_requests_fail_clean() {
    let (handle, rc) = start_mem_server();
    let addr = handle.addr();

    // garbage request line -> 400, structured error (the payload ends
    // exactly at the line the server reads, so the close is a clean FIN)
    let resp = raw_request(addr, b"NOT-HTTP\r\n");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("\"code\":\"malformed_request\""), "{resp}");

    // oversized declared body -> 413 before reading it
    let resp = raw_request(addr, b"POST /v1/commit HTTP/1.1\r\ncontent-length: 99999999\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");

    // unbounded header line -> 413, not unbounded memory. The server
    // closes mid-upload, so depending on timing the client sees the 413
    // or a connection reset — both are clean refusals; the liveness
    // check below is the real assertion.
    let mut huge = b"GET /".to_vec();
    huge.extend(std::iter::repeat(b'A').take(64 * 1024));
    let resp = raw_request(addr, &huge);
    assert!(resp.is_empty() || resp.starts_with("HTTP/1.1 413"), "{resp}");

    // truncated body (client died mid-request) -> 400, worker survives
    let resp = raw_request(addr, b"POST /v1/commit HTTP/1.1\r\ncontent-length: 50\r\n\r\nabc");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // bad JSON in a well-formed request -> 400 parse error
    let resp = raw_request(
        addr,
        b"POST /v1/merge HTTP/1.1\r\ncontent-length: 9\r\nconnection: close\r\n\r\n{\"src\": }",
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // unknown route -> 404, still structured
    let resp = raw_request(addr, b"GET /v999/nope HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

    // after all that abuse the server still serves real clients
    rc.healthz().unwrap();
    rc.create_branch("alive", MAIN, false).unwrap();
    assert!(rc.list_branches().unwrap().iter().any(|b| b.name == "alive"));
    handle.shutdown();
}

// ------------------------------------------------------------ durability

#[test]
fn run_registry_survives_server_kill_and_restart() {
    let dir = temp_dir("restart");
    let _ = std::fs::remove_dir_all(&dir);

    // first server generation: seed, run, kill
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc = RemoteClient::new(&handle.base_url());
    rc.seed_raw_table(MAIN, 2, 300).unwrap();
    let opts = RemoteRunOpts { run_id: Some("run_wire_1".into()), ..RemoteRunOpts::default() };
    let run = rc.submit_run(PAPER_PIPELINE_TEXT, MAIN, &opts).unwrap();
    assert!(matches!(run.status, RunStatus::Success), "{:?}", run.status);
    let export_before = rc.export().unwrap().to_string();
    handle.shutdown(); // the "kill": no checkpoint, journal is the witness

    // second generation: recover the journaled lake, serve again
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc2 = RemoteClient::new(&handle.base_url());
    // run get resumes from the durable registry
    let resumed = rc2.get_run("run_wire_1").unwrap().expect("record lost across restart");
    assert!(matches!(resumed.status, RunStatus::Success));
    assert_eq!(resumed.pipeline, run.pipeline);
    assert_eq!(resumed.outputs, run.outputs);
    // and the recovered catalog is byte-identical to what the first
    // server was serving when it died
    assert_eq!(rc2.export().unwrap().to_string(), export_before);
    assert!(rc2.get_run("run_never_happened").unwrap().is_none());
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn poisoned_catalog_returns_503_over_the_wire() {
    let dir = temp_dir("poison");
    let _ = std::fs::remove_dir_all(&dir);

    // durable (group-commit) catalog behind the server; keep a clone so
    // the test can inject the fsync failure out-of-band
    let catalog = Catalog::recover(&dir).unwrap();
    let poisoner = catalog.clone();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = handle.addr();
    let rc = RemoteClient::new(&handle.base_url());

    rc.commit(&RemoteCommit::new(MAIN, "before", "x").retrying()).unwrap();
    assert!(!poisoner.is_poisoned());

    // the next group-commit leader's fsync fails: the caller gets an
    // error instead of a durability ack, and the catalog poisons itself
    poisoner.debug_fail_next_group_sync();
    // (the leader's own Io error crosses the wire as code "io", which
    // the client surfaces as a generic error — the *next* callers get
    // the typed Poisoned variant)
    let err = rc.commit(&RemoteCommit::new(MAIN, "doomed", "y")).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("io") || msg.contains("poisoned"),
        "failing commit surfaced as {msg}"
    );
    assert!(poisoner.is_poisoned());

    // every route now 503s — including /healthz, so load balancers drain —
    // and the error decodes back to the Poisoned variant
    let err = rc.commit(&RemoteCommit::new(MAIN, "after", "z")).unwrap_err();
    assert!(matches!(err, BauplanError::Poisoned(_)), "{err}");
    let err = rc.healthz().unwrap_err();
    assert!(matches!(err, BauplanError::Poisoned(_)), "{err}");
    let resp = raw_request(addr, b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
    assert!(resp.contains("\"poisoned\""), "{resp}");

    // only /metrics, the flight ring, and the readiness probe stay
    // readable, for post-mortem scraping and triage
    let metrics = rc.metrics_text().unwrap();
    assert!(metrics.contains("bauplan_server_requests"), "{metrics}");
    let flight = rc.trace_flight().unwrap();
    assert!(flight.get("spans").as_arr().is_some());
    // /v1/status answers 200 even when poisoned — that is its job: it
    // *reports* not-ready instead of becoming unreachable
    let status = rc.status().unwrap();
    assert_eq!(status.get("ok").as_bool(), Some(false), "{status}");
    assert_eq!(status.get("poisoned").as_bool(), Some(true), "{status}");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ error mapping

#[test]
fn error_variants_map_back_across_the_wire() {
    let (handle, rc) = start_mem_server();
    // visibility guardrail (Fig. 4) enforced for remote tenants
    rc.create_txn_branch(MAIN, "r1").unwrap();
    rc.commit(&RemoteCommit::new("txn/r1", "t", "x").retrying()).unwrap();
    rc.set_branch_state("txn/r1", BranchState::Aborted).unwrap();
    let err = rc.create_branch("agent", "txn/r1", false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)), "{err}");
    let err = rc.merge("txn/r1", MAIN, false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)), "{err}");
    // the explicit capability opens the escape hatch, remotely too
    rc.create_branch("agent", "txn/r1", true).unwrap();

    assert!(matches!(rc.branch_info("ghost").unwrap_err(), BauplanError::UnknownRef(_)));
    let err = rc.create_branch("agent", MAIN, false).unwrap_err();
    assert!(matches!(err, BauplanError::RefExists(_)), "{err}");
    let err = rc.get_object("no_such_object").unwrap_err();
    assert!(matches!(err, BauplanError::ObjectNotFound(_)), "{err}");
    // traversal keys are refused at the boundary, not resolved
    let err = rc.get_object("%2e%2e%2fescape").unwrap_err();
    assert!(matches!(err, BauplanError::ObjectNotFound(_)), "{err}");
    handle.shutdown();
}

#[test]
fn table_reads_objects_and_metrics_work_remotely() {
    let (handle, rc) = start_mem_server();
    let out = rc.commit(&RemoteCommit::new(MAIN, "events", "payload-bytes").retrying()).unwrap();
    let table = rc.get_table(MAIN, "events").unwrap();
    assert_eq!(table.get("snapshot_id").as_str(), Some(out.snapshot.as_str()));
    assert_eq!(table.get("row_count").as_f64(), Some(1.0));
    let objects = table.get("objects").as_arr().unwrap().to_vec();
    assert_eq!(objects.len(), 1);
    // round-trip the raw bytes through the object endpoint
    let key = objects[0].as_str().unwrap();
    assert_eq!(rc.get_object(key).unwrap(), b"payload-bytes");
    // /metrics renders the shared registry in Prometheus text format
    let metrics = rc.metrics_text().unwrap();
    assert!(metrics.contains("bauplan_server_requests"), "{metrics}");
    assert!(metrics.contains("bauplan_server_commits 1"), "{metrics}");
    handle.shutdown();
}

// ------------------------------------------------------------ observability

#[test]
fn prometheus_histograms_render_cumulative_buckets() {
    let (handle, rc) = start_mem_server();
    rc.seed_raw_table(MAIN, 2, 300).unwrap();
    let opts = RemoteRunOpts { run_id: Some("run_prom".into()), ..RemoteRunOpts::default() };
    let run = rc.submit_run(PAPER_PIPELINE_TEXT, MAIN, &opts).unwrap();
    assert!(matches!(run.status, RunStatus::Success), "{:?}", run.status);

    let text = rc.metrics_text().unwrap();
    assert!(text.contains("# TYPE bauplan_run_merge_publish histogram"), "{text}");
    let tail = |l: &str| l.rsplit(' ').next().unwrap().parse::<u64>().unwrap();
    // finite buckets are cumulative: counts never decrease along le
    let buckets: Vec<u64> = text
        .lines()
        .filter(|l| l.starts_with("bauplan_run_merge_publish_bucket{le=\""))
        .filter(|l| !l.contains("+Inf"))
        .map(tail)
        .collect();
    assert!(!buckets.is_empty(), "{text}");
    assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "not cumulative: {buckets:?}");
    // the +Inf bucket equals _count (one publish happened)
    let inf = text
        .lines()
        .find(|l| l.starts_with("bauplan_run_merge_publish_bucket{le=\"+Inf\"}"))
        .map(tail)
        .expect("+Inf bucket line");
    let count = text
        .lines()
        .find(|l| l.starts_with("bauplan_run_merge_publish_count "))
        .map(tail)
        .expect("_count line");
    assert_eq!(inf, count, "{text}");
    assert_eq!(count, 1, "{text}");
    assert!(*buckets.last().unwrap() <= inf);
    assert!(
        text.lines().any(|l| l.starts_with("bauplan_run_merge_publish_sum ")),
        "{text}"
    );
    handle.shutdown();
}

#[test]
fn metrics_json_and_flight_ring_answer_remotely() {
    let (handle, rc) = start_mem_server();
    rc.healthz().unwrap();

    // canonical-JSON snapshot (what `bauplan metrics --remote` prints)
    let m = rc.metrics_json().unwrap();
    assert!(m.get("counters").get("server.requests").as_f64().unwrap() >= 1.0);
    assert!(m.get("histograms").as_obj().is_some());

    // the healthz request is in the flight ring, with its wire facts
    let flight = rc.trace_flight().unwrap();
    let hz = flight
        .get("spans")
        .as_arr()
        .unwrap()
        .iter()
        .find(|s| {
            s.get("name").as_str() == Some("server.request")
                && s.get("attrs").get("path").as_str() == Some("/healthz")
        })
        .expect("healthz request recorded in the flight ring");
    assert_eq!(hz.get("attrs").get("method").as_str(), Some("GET"));
    assert_eq!(hz.get("attrs").get("status").as_f64(), Some(200.0));
    assert!(flight.get("cap").as_f64().unwrap() >= 1.0);

    // unknown run ids 404 on the trace route
    assert!(rc.get_trace("run_never_ran").unwrap().is_none());
    handle.shutdown();
}

#[test]
fn status_plane_reports_readiness_and_build_info() {
    let (handle, rc) = start_mem_server();

    // /v1/status wire shape: readiness verdict plus build identity
    let s = rc.status().unwrap();
    assert_eq!(s.get("ok").as_bool(), Some(true), "{s}");
    assert_eq!(s.get("version").as_str(), Some(env!("CARGO_PKG_VERSION")), "{s}");
    assert!(s.get("uptime_seconds").as_f64().is_some(), "{s}");
    assert_eq!(s.get("poisoned").as_bool(), Some(false), "{s}");
    // in-memory sim server: nothing was recovered, nothing is audited
    assert_eq!(s.get("durable").as_bool(), Some(false), "{s}");
    assert!(s.get("recovery").as_obj().is_none(), "{s}");
    assert!(s.get("audit").as_obj().is_none(), "{s}");
    // ...and there is no on-disk lake for the fsck route to walk
    assert!(rc.fsck().is_err());

    // /metrics carries the matching identity gauges in Prometheus text
    let text = rc.metrics_text().unwrap();
    assert!(text.contains("# TYPE bauplan_build_info gauge"), "{text}");
    assert!(
        text.contains(&format!(
            "bauplan_build_info{{version=\"{}\"}} 1",
            env!("CARGO_PKG_VERSION")
        )),
        "{text}"
    );
    assert!(
        text.lines().any(|l| l.strip_prefix("bauplan_uptime_seconds ")
            .is_some_and(|v| v.trim().parse::<u64>().is_ok())),
        "{text}"
    );
    handle.shutdown();
}

#[test]
fn fsck_route_and_status_answer_on_a_durable_lake() {
    let dir = temp_dir("fsck_route");
    let _ = std::fs::remove_dir_all(&dir);

    // seed journaled content *before* serving, so every audit walk below
    // (the background auditor's cycles and the synchronous fallback) sees
    // a quiescent lake — no live-writer races, deterministic verdict
    {
        let cat = Catalog::recover(&dir).unwrap();
        for i in 0..3 {
            let key = cat.store().put(format!("audited payload {i}").into_bytes());
            let snap = Snapshot::new(vec![key], "S", "fp", 1, "rw");
            bauplan::testing::commit_table(&cat, MAIN, &format!("t{i}"), snap, "u", "m", None)
                .unwrap();
        }
    }
    let catalog = Catalog::recover(&dir).unwrap();
    let client = Client::open_sim_with_catalog(catalog).unwrap();
    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc = RemoteClient::new(&handle.base_url());

    let s = rc.status().unwrap();
    assert_eq!(s.get("ok").as_bool(), Some(true), "{s}");
    assert_eq!(s.get("durable").as_bool(), Some(true), "{s}");
    // a durable server recovered from disk and runs the auditor
    assert!(s.get("recovery").get("base_seq").as_f64().is_some(), "{s}");
    assert!(s.get("audit").get("cycles").as_f64().is_some(), "{s}");

    // the admin fsck route serves a full report and the healthy lake is clean
    let report = rc.fsck().unwrap();
    assert_eq!(report.get("clean").as_bool(), Some(true), "{report}");
    assert_eq!(report.get("errors").as_f64(), Some(0.0), "{report}");
    assert!(report.get("findings").as_arr().is_some(), "{report}");
    assert!(report.get("stats").get("bytes_read").as_f64().is_some(), "{report}");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ data plane

#[test]
fn table_data_streams_binary_frames_end_to_end() {
    let (handle, rc) = start_mem_server();
    rc.seed_raw_table(MAIN, 2, 300).unwrap();

    // decoded through RemoteClient: frame 0 metadata + one codec object
    // per later frame reassemble into the committed table
    let t = rc.get_table_data(MAIN, "raw_table").unwrap();
    assert_eq!(t.schema_name, "RawSchema");
    assert_eq!(t.batches.len(), 2);
    assert_eq!(t.row_count(), 600);

    // the JSON comparison path of the same route agrees on the metadata
    let j = rc.get_table_data_json(MAIN, "raw_table").unwrap();
    assert_eq!(j.get("meta").get("rows").as_f64(), Some(600.0));
    assert_eq!(j.get("batches").as_arr().map(|a| a.len()), Some(2));

    // raw socket: the declared content-length must equal the bytes that
    // actually arrive (what the access log records for streamed bodies),
    // and the body must be a well-formed BPW1 frame stream
    let raw = raw_request_bytes(
        handle.addr(),
        b"GET /v1/table/raw_table/data?ref=main HTTP/1.1\r\nconnection: close\r\n\r\n",
    );
    let (head, body) = split_response(&raw);
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("content-type: application/x-bauplan-frames"), "{head}");
    let declared: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("content-length: "))
        .expect("content-length header")
        .trim()
        .parse()
        .unwrap();
    assert_eq!(declared, body.len(), "content-length must match the streamed body");
    assert_eq!(&body[..4], b"BPW1");
    let t2 = decode_table_frames(body).unwrap();
    assert_eq!(t2.row_count(), 600);
    handle.shutdown();
}

#[test]
fn table_data_wire_faults_fail_structured() {
    let (handle, rc) = start_mem_server();
    let addr = handle.addr();
    rc.seed_raw_table(MAIN, 1, 100).unwrap();

    // missing ref param -> 400, structured parse error
    let resp =
        raw_request(addr, b"GET /v1/table/raw_table/data HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("\"code\":\"parse\""), "{resp}");

    // unknown table / unknown ref map back to typed client errors
    let err = rc.get_table_data(MAIN, "ghost").unwrap_err();
    assert!(matches!(err, BauplanError::TableNotFound(_)), "{err}");
    let err = rc.get_table_data("no_such_branch", "raw_table").unwrap_err();
    assert!(matches!(err, BauplanError::UnknownRef(_)), "{err}");

    // truncation and corrupt length prefixes fail decode with structured
    // errors — never a panic, never an implausible allocation
    let raw = raw_request_bytes(
        addr,
        b"GET /v1/table/raw_table/data?ref=main HTTP/1.1\r\nconnection: close\r\n\r\n",
    );
    let (_, body) = split_response(&raw);
    assert!(decode_table_frames(body).is_ok());
    let err = decode_table_frames(&body[..body.len() - 6]).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");
    let mut corrupt = body.to_vec();
    corrupt[4..8].copy_from_slice(&u32::MAX.to_le_bytes()); // frame 0 length prefix
    let err = decode_table_frames(&corrupt).unwrap_err();
    assert!(err.to_string().contains("frame"), "{err}");

    // client hangs up mid-stream: the worker tolerates the write error
    // and the server keeps serving
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /v1/table/raw_table/data?ref=main HTTP/1.1\r\nconnection: close\r\n\r\n")
            .unwrap();
        let mut one = [0u8; 1];
        let _ = s.read_exact(&mut one);
        drop(s);
    }
    rc.healthz().unwrap();
    assert_eq!(rc.get_table_data(MAIN, "raw_table").unwrap().row_count(), 100);
    handle.shutdown();
}

#[test]
fn scan_and_store_metrics_cross_the_wire() {
    // register scan.* counters by driving one fully-pruned scan through
    // the worker that will sit behind the server (one shared registry);
    // the inverted range [1, -1] prunes every batch
    let client = Client::open_sim().unwrap();
    client.seed_raw_table(MAIN, 3, 200).unwrap();
    let node = NodeSpec::new("out", "T", "transform_n")
        .input("raw_table", "RawSchema")
        .with_params(vec![1.0, -1.0, 2.0, 0.5]);
    let state = client.catalog.read_ref(MAIN).unwrap();
    client.worker.execute_node(&node, &state).unwrap();
    assert_eq!(client.worker.metrics.counter("scan.batches_pruned"), 3);

    let handle = Server::start(client, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let rc = RemoteClient::new(&handle.base_url());

    // canonical JSON: the scan.* and store.* namespaces are both present
    let m = rc.metrics_json().unwrap();
    let counters = m.get("counters");
    assert_eq!(counters.get("scan.batches_pruned").as_f64(), Some(3.0));
    assert_eq!(counters.get("scan.rows_scanned").as_f64(), Some(0.0));
    for k in [
        "store.cache_hits",
        "store.cache_misses",
        "store.cache_bytes",
        "store.cache_entries",
        "store.cache_evicted_bytes",
    ] {
        assert!(counters.get(k).as_f64().is_some(), "missing counter {k}: {m}");
    }

    // Prometheus text: same counters plus the synthesized hit-rate gauge
    let text = rc.metrics_text().unwrap();
    assert!(text.contains("bauplan_scan_batches_pruned 3"), "{text}");
    assert!(text.contains("# TYPE bauplan_store_cache_hits counter"), "{text}");
    assert!(text.contains("# TYPE bauplan_store_cache_hit_rate gauge"), "{text}");
    let rate: f64 = text
        .lines()
        .find_map(|l| l.strip_prefix("bauplan_store_cache_hit_rate "))
        .expect("hit-rate gauge line")
        .trim()
        .parse()
        .unwrap();
    assert!((0.0..=1.0).contains(&rate), "{rate}");
    handle.shutdown();
}

// ------------------------------------------------------------ loopback sim

#[test]
fn loopback_simulation_matches_in_process_verdicts() {
    // the PR 4 oracle suite — now including the trace-completeness
    // oracle (every successful run leaves a journaled trace with one
    // commit span per plan table, reproduced byte-identically across
    // recovery) — driven through RemoteClient over real TCP: same
    // seeds, same guardrail, the verdict and the model projection
    // digest must agree with the in-process driver
    for seed in [3u64, 17, 42] {
        let local = simulate(&SimConfig { ops: 25, ..SimConfig::new(seed) }).unwrap();
        let loopback = simulate(&SimConfig { ops: 25, ..SimConfig::loopback(seed) }).unwrap();
        assert!(local.violation.is_none(), "seed {seed} local: {:?}", local.violation);
        assert!(
            loopback.violation.is_none(),
            "seed {seed} loopback: {:?}",
            loopback.violation
        );
        assert_eq!(
            local.model_digest, loopback.model_digest,
            "seed {seed}: wire transport changed the published state"
        );
        assert_eq!(local.applied, loopback.applied, "seed {seed}");
        assert_eq!(local.skipped, loopback.skipped, "seed {seed}");
    }
}
