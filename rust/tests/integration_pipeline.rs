//! End-to-end integration: the full stack (client → control plane →
//! worker → PJRT runtime → object store → catalog) on the paper's
//! running-example pipeline.
//!
//! Requires `artifacts/` (run `make artifacts` first). One PJRT runtime
//! is shared across tests via a lazy singleton — compiling 9 HLO modules
//! per test would dominate the suite.

use std::sync::Arc;

use bauplan::catalog::{BranchState, MAIN};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::error::BauplanError;
use bauplan::runs::{FailurePlan, RunMode, RunStatus, Verifier};
use bauplan::storage::ObjectStore;
use std::sync::OnceLock;

static RUNTIME: OnceLock<Option<Arc<bauplan::runtime::ExecHandle>>> = OnceLock::new();

/// The shared PJRT runtime, or `None` when it cannot start (missing
/// `artifacts/` or the stub `runtime::pjrt` shim): tests skip instead of
/// failing, so the catalog/journal suites stay green without PJRT.
fn runtime() -> Option<Arc<bauplan::runtime::ExecHandle>> {
    RUNTIME
        .get_or_init(|| {
            bauplan::runtime::ExecHandle::start_pool(std::path::Path::new("artifacts"), 2)
                .ok()
                .map(Arc::new)
        })
        .clone()
}

/// Skip the test (early return) when the PJRT runtime is unavailable.
macro_rules! require_client {
    ($c:ident = $e:expr) => {
        let Some($c) = $e else {
            eprintln!("skipping: PJRT runtime unavailable (needs artifacts + xla crate)");
            return;
        };
    };
}

/// Fresh client sharing the singleton runtime.
fn client() -> Option<Client> {
    let rt = runtime()?;
    let catalog = bauplan::catalog::Catalog::new(Arc::new(ObjectStore::new()));
    let registry = bauplan::contracts::schema::SchemaRegistry::with_paper_schemas();
    let worker = bauplan::worker::Worker::new(rt.clone(), catalog.clone(), registry)
        .with_lineage_skipping()
        .unwrap();
    let control_plane = bauplan::control_plane::ControlPlane::new(rt.clone());
    let runner = bauplan::runs::Runner::new(catalog.clone(), worker.clone());
    Some(Client { catalog, runtime: rt, control_plane, runner, worker })
}

fn seeded_client() -> Option<Client> {
    let c = client()?;
    c.seed_raw_table(MAIN, 3, 1200).unwrap();
    Some(c)
}

// ---------------------------------------------------------------- happy path

#[test]
fn paper_pipeline_runs_transactionally() {
    require_client!(c = seeded_client());
    let run = c.run_text(PAPER_PIPELINE_TEXT, MAIN).unwrap();
    assert!(run.is_success(), "{:?}", run.status);
    assert_eq!(run.outputs, vec!["parent_table", "child_table", "grand_child"]);

    // all three tables visible on main, written by this run
    let head = c.catalog.read_ref(MAIN).unwrap();
    for t in ["parent_table", "child_table", "grand_child"] {
        let snap = c.catalog.get_snapshot(&head.tables[t]).unwrap();
        assert_eq!(snap.run_id, run.run_id, "table {t}");
        assert!(snap.row_count > 0, "table {t} empty");
    }

    // txn branch cleaned up
    assert!(c
        .catalog
        .list_branches()
        .iter()
        .all(|b| !b.transactional));
}

#[test]
fn grouped_sums_match_reference() {
    require_client!(c = client());
    // deterministic input: one batch, known groups
    let batches = bauplan::data::raw_table(7, 1, 2048);
    // rust-side reference over the same data
    let b = &batches[0];
    let col1 = b.column("col1").unwrap().data.as_i32().unwrap().to_vec();
    let col3 = b.column("col3").unwrap().data.as_f32().unwrap().to_vec();
    let valid = b.valid.clone();
    let mut expect = vec![0f64; bauplan::data::G];
    for i in 0..col1.len() {
        if valid[i] > 0.0 {
            expect[col1[i] as usize] += col3[i] as f64;
        }
    }
    c.seed_table(MAIN, "raw_table", "RawSchema", batches).unwrap();
    let run = c.run_text(PAPER_PIPELINE_TEXT, MAIN).unwrap();
    assert!(run.is_success());

    let head = c.catalog.read_ref(MAIN).unwrap();
    let parent = c.worker.read_table(&head, "parent_table").unwrap();
    let pb = &parent.batches[0];
    let s = pb.column("_S").unwrap().data.as_f32().unwrap();
    for g in 0..bauplan::data::G {
        assert!(
            (s[g] as f64 - expect[g]).abs() <= 1e-2 + expect[g].abs() * 1e-4,
            "group {g}: kernel {} vs reference {}",
            s[g],
            expect[g]
        );
    }
}

#[test]
fn pipeline_composes_child_and_grand() {
    require_client!(c = seeded_client());
    c.run_text(PAPER_PIPELINE_TEXT, MAIN).unwrap();
    let head = c.catalog.read_ref(MAIN).unwrap();
    let parent = c.worker.read_table(&head, "parent_table").unwrap();
    let grand = c.worker.read_table(&head, "grand_child").unwrap();
    let ps = parent.batches[0].column("_S").unwrap().data.as_f32().unwrap();
    let pv = &parent.batches[0].valid;
    let g4 = grand.batches[0].column("col4").unwrap().data.as_i32().unwrap();
    let gv = &grand.batches[0].valid;
    // grand.col4 == trunc(parent._S * 0.5 + 1.0) wherever valid
    for i in 0..ps.len() {
        if pv[i] > 0.0 && gv[i] > 0.0 {
            assert_eq!(g4[i], (ps[i] * 0.5 + 1.0).trunc() as i32, "row {i}");
        }
    }
}

// ---------------------------------------------------------------- atomicity

#[test]
fn transactional_failure_leaves_target_untouched() {
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let before = c.catalog.resolve(MAIN).unwrap();

    let run = c
        .run_plan(
            &plan,
            MAIN,
            RunMode::Transactional,
            &FailurePlan::crash_after("child_table"),
            &[],
        )
        .unwrap();
    let RunStatus::Aborted { txn_branch, .. } = &run.status else {
        panic!("expected abort, got {:?}", run.status)
    };

    // Fig. 3 bottom: main is exactly where it was
    assert_eq!(c.catalog.resolve(MAIN).unwrap(), before);

    // the aborted branch is retained for triage, with partial state
    let info = c.catalog.branch_info(txn_branch).unwrap();
    assert_eq!(info.state, BranchState::Aborted);
    let aborted_head = c.catalog.read_ref(txn_branch).unwrap();
    assert!(aborted_head.tables.contains_key("parent_table"));
    assert!(aborted_head.tables.contains_key("child_table"));
    assert!(!aborted_head.tables.contains_key("grand_child"));

    // triage: the faulty intermediate asset is queryable
    let t = c.worker.read_table(&aborted_head, "child_table").unwrap();
    assert!(t.row_count() > 0);
}

#[test]
fn direct_write_failure_leaves_partial_state() {
    // Fig. 3 top — the baseline failure mode the protocol eliminates.
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let run = c
        .run_plan(&plan, MAIN, RunMode::DirectWrite, &FailurePlan::crash_after("parent_table"), &[])
        .unwrap();
    let RunStatus::FailedPartial { tables_published, .. } = run.status else {
        panic!("expected partial failure")
    };
    assert_eq!(tables_published, 1);
    let head = c.catalog.read_ref(MAIN).unwrap();
    assert!(head.tables.contains_key("parent_table")); // leaked!
    assert!(!head.tables.contains_key("child_table"));
}

#[test]
fn failed_verifier_blocks_publication() {
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let before = c.catalog.resolve(MAIN).unwrap();
    let run = c
        .run_plan(
            &plan,
            MAIN,
            RunMode::Transactional,
            &FailurePlan::none(),
            &[Verifier::min_rows("grand_child", 10_000_000)], // impossible
        )
        .unwrap();
    assert!(matches!(run.status, RunStatus::Aborted { .. }));
    assert_eq!(c.catalog.resolve(MAIN).unwrap(), before);
}

#[test]
fn verifiers_pass_on_good_run() {
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let run = c
        .run_plan(
            &plan,
            MAIN,
            RunMode::Transactional,
            &FailurePlan::none(),
            &[
                Verifier::min_rows("parent_table", 1),
                Verifier::rows_not_amplified("parent_table", "grand_child"),
            ],
        )
        .unwrap();
    assert!(run.is_success(), "{:?}", run.status);
}

// ---------------------------------------------------------------- Fig 4

#[test]
fn aborted_branch_fork_requires_capability() {
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let run = c
        .run_plan(
            &plan,
            MAIN,
            RunMode::Transactional,
            &FailurePlan::crash_after("parent_table"),
            &[],
        )
        .unwrap();
    let RunStatus::Aborted { txn_branch, .. } = &run.status else { panic!() };

    // the agent's move from Fig. 4 — refused by the guardrail
    let err = c.catalog.create_branch("agent_branch", txn_branch, false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)));
    let err = c.catalog.merge(txn_branch, MAIN, false).unwrap_err();
    assert!(matches!(err, BauplanError::Visibility(_)));

    // the explicit escape hatch (idempotent re-run workflows)
    c.catalog.create_branch("agent_branch", txn_branch, true).unwrap();
}

// ---------------------------------------------------------------- contracts

#[test]
fn m2_schema_drift_fails_before_execution() {
    require_client!(c = seeded_client());
    // ChildSchema expects parent_table as ParentSchema; declare Grand
    let bad = PAPER_PIPELINE_TEXT.replace(
        "node parent_table: ParentSchema <-",
        "node parent_table: Grand <-",
    );
    let err = c.run_text(&bad, MAIN).unwrap_err();
    assert_eq!(err.contract_moment(), Some(2), "{err}");
    // and nothing ran: no new tables on main
    assert_eq!(c.catalog.read_ref(MAIN).unwrap().tables.len(), 1);
}

#[test]
fn m1_unmarked_narrowing_fails_at_parse_of_declarations() {
    require_client!(c = seeded_client());
    let bad = PAPER_PIPELINE_TEXT.replace(
        "col4: int from ChildSchema.col4 cast",
        "col4: int from ChildSchema.col4",
    );
    let err = c.run_text(&bad, MAIN).unwrap_err();
    assert_eq!(err.contract_moment(), Some(1), "{err}");
}

#[test]
fn m3_runtime_violation_blocks_persistence() {
    require_client!(c = client());
    // poisoned data: NaNs in col3 violate RawSchema's implicit no-NaN
    let mut rng = bauplan::testing::Rng::new(3);
    let batches = vec![bauplan::data::poisoned_batch(&mut rng, 500, 5, 0)];
    // seeding itself validates: the seed must fail at M3
    let err = c.seed_table(MAIN, "raw_table", "RawSchema", batches).unwrap_err();
    assert_eq!(err.contract_moment(), Some(3), "{err}");
    // nothing on main
    assert!(c.catalog.read_ref(MAIN).unwrap().tables.is_empty());
}

#[test]
fn m3_bounds_violation_detected() {
    require_client!(c = client());
    let mut rng = bauplan::testing::Rng::new(4);
    let batches = vec![bauplan::data::poisoned_batch(&mut rng, 500, 0, 3)];
    let err = c.seed_table(MAIN, "raw_table", "RawSchema", batches).unwrap_err();
    assert_eq!(err.contract_moment(), Some(3));
    assert!(err.to_string().contains("outside declared"));
}

// ---------------------------------------------------------------- repro

#[test]
fn run_state_supports_reproduction_workflow() {
    require_client!(c = seeded_client());
    let run1 = c.run_text(PAPER_PIPELINE_TEXT, MAIN).unwrap();

    // more writes move main past run1's start
    c.seed_raw_table(MAIN, 1, 900).unwrap();
    c.run_text(PAPER_PIPELINE_TEXT, MAIN).unwrap();

    // Listing 6: reproduce from the stored run state
    let prod = c.get_run(&run1.run_id).unwrap();
    assert_eq!(prod.code_hash, run1.code_hash);
    let debug = c.create_branch("repro", &prod.start_commit).unwrap();
    // the debug branch sees the lake exactly as run1 did
    let debug_head = c.catalog.read_ref(&debug).unwrap();
    assert_eq!(debug_head.id, prod.start_commit);
    // re-running the same code on the same data reproduces the outputs
    let run3 = c.run_text(PAPER_PIPELINE_TEXT, &debug).unwrap();
    assert!(run3.is_success());
    assert_eq!(run3.code_hash, prod.code_hash);
    let d = c.catalog.read_ref(&debug).unwrap();
    let orig_head = c.log(MAIN, 100).unwrap();
    // find run1's published snapshot of grand_child in main's history
    let orig_snap = orig_head
        .iter()
        .filter_map(|commit| commit.tables.get("grand_child"))
        .find(|sid| {
            c.catalog.get_snapshot(sid).map(|s| s.run_id == run1.run_id).unwrap_or(false)
        })
        .cloned()
        .expect("run1 grand_child in history");
    let repro_snap = &d.tables["grand_child"];
    let a = c.catalog.get_snapshot(&orig_snap).unwrap();
    let b = c.catalog.get_snapshot(repro_snap).unwrap();
    // same data objects — bit-identical reproduction
    assert_eq!(a.objects, b.objects);
}

// ---------------------------------------------------------------- PR flow

#[test]
fn feature_branch_pr_flow() {
    require_client!(c = seeded_client());
    let feature = c.create_branch("feature", MAIN).unwrap();
    let run = c.run_text(PAPER_PIPELINE_TEXT, &feature).unwrap();
    assert!(run.is_success());

    // main is untouched pre-merge
    assert_eq!(c.catalog.read_ref(MAIN).unwrap().tables.len(), 1);
    // the PR diff shows the three new tables
    let diff = c.diff(MAIN, &feature).unwrap();
    assert_eq!(diff.len(), 3);
    // land it
    c.merge(&feature, MAIN).unwrap();
    assert_eq!(c.catalog.read_ref(MAIN).unwrap().tables.len(), 4);
}

#[test]
fn concurrent_transactional_runs_on_distinct_branches() {
    require_client!(c = seeded_client());
    let plan = c.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
    let mut handles = vec![];
    for i in 0..4 {
        let c = c.clone();
        let plan = plan.clone();
        let branch = format!("dev{i}");
        c.create_branch(&branch, MAIN).unwrap();
        handles.push(std::thread::spawn(move || {
            let run = c
                .run_plan(&plan, &branch, RunMode::Transactional, &FailurePlan::none(), &[])
                .unwrap();
            assert!(run.is_success(), "{:?}", run.status);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // all four branches published all three tables
    for i in 0..4 {
        let head = c.catalog.read_ref(&format!("dev{i}")).unwrap();
        assert_eq!(head.tables.len(), 4);
    }
}
