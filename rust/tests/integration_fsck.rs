//! Integration tests for the lake doctor (`bauplan fsck`): a clean lake
//! audits clean and is left byte-identical; every seeded-corruption
//! class is detected with its stable finding code *naming the damaged
//! file*; `--deep` catches what the shallow walk deliberately skips; and
//! error findings leave a flight-recorder dump on disk.
//!
//! Check taxonomy and invariant ↔ test map: `doc/FSCK.md`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use bauplan::audit::{fsck_path, FsckReport, Severity};
use bauplan::catalog::{Catalog, CommitRequest, JournalConfig, Snapshot, SyncPolicy};
use bauplan::storage::codec::encode_batch;
use bauplan::storage::{Batch, Column};

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bpl_fsck_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_segments() -> JournalConfig {
    JournalConfig {
        sync: SyncPolicy::EveryAppend,
        segment_bytes: 256,
        compact_after_deltas: u64::MAX,
        sync_latency_micros: 0,
    }
}

/// Commit one stored object (arbitrary bytes) to `table` on main.
fn commit_bytes(cat: &Catalog, table: &str, content: &[u8]) -> String {
    let key = cat.store().put(content.to_vec());
    let snap = Snapshot::new(vec![key.clone()], "S", "fp", 1, "rw");
    cat.commit(CommitRequest::new("main", table, snap)).unwrap();
    key
}

/// Recursive byte snapshot of a directory: path -> contents.
fn dir_digest(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

/// Flip one bit at `offset` (nudged off newline bytes) in `path`.
fn flip_byte(path: &Path, offset: usize) {
    let mut bytes = std::fs::read(path).unwrap();
    let mut i = offset.min(bytes.len() - 1);
    while bytes[i] == b'\n' {
        i += 1;
    }
    bytes[i] ^= 0x01;
    std::fs::write(path, bytes).unwrap();
}

/// The journal segment files, sorted oldest-first.
fn segment_files(dir: &Path) -> Vec<PathBuf> {
    let mut segs: Vec<PathBuf> = std::fs::read_dir(dir.join("journal"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().unwrap().to_string_lossy().starts_with("seg-"))
        .collect();
    segs.sort();
    segs
}

fn errors_naming(report: &FsckReport, file: &str) -> Vec<String> {
    report
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Error && f.file == file)
        .map(|f| f.code.to_string())
        .collect()
}

#[test]
fn clean_lake_audits_clean_and_fsck_is_read_only() {
    let dir = tmp("clean");
    {
        let cat = Catalog::open_durable_cfg(&dir, tiny_segments()).unwrap();
        for i in 0..6 {
            commit_bytes(&cat, &format!("t{i}"), format!("payload {i}").as_bytes());
        }
        cat.create_branch("dev", "main", false).unwrap();
        cat.tag("v1", "main").unwrap();
        cat.checkpoint().unwrap();
        commit_bytes(&cat, "tail", b"post-checkpoint tail");
    }
    let before = dir_digest(&dir);
    let report = fsck_path(&dir, true).unwrap();
    assert!(report.clean(), "fresh lake must audit clean:\n{}", report.render());
    assert!(report.stats.segments > 1, "tiny segments must have rotated");
    assert!(report.stats.objects >= 7);
    // strictly read-only: the deep walk must not have repaired,
    // compacted, or touched a single byte
    assert_eq!(before, dir_digest(&dir), "fsck mutated the lake directory");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_frozen_segment_is_reported_with_its_file() {
    let dir = tmp("seg");
    {
        let cat = Catalog::open_durable_cfg(&dir, tiny_segments()).unwrap();
        for i in 0..10 {
            commit_bytes(&cat, "t", format!("row {i}").as_bytes());
        }
    }
    let segs = segment_files(&dir);
    assert!(segs.len() > 1, "need a frozen segment to corrupt");
    let victim = &segs[0];
    let len = std::fs::metadata(victim).unwrap().len() as usize;
    flip_byte(victim, len / 2);

    let report = fsck_path(&dir, false).unwrap();
    assert!(!report.clean());
    let rel = format!("journal/{}", victim.file_name().unwrap().to_string_lossy());
    let codes = errors_naming(&report, &rel);
    assert!(
        codes.iter().any(|c| c.starts_with("AUDIT_SEGMENT")),
        "expected an AUDIT_SEGMENT_* error naming {rel}, got {codes:?} in:\n{}",
        report.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_delta_snapshot_is_reported_with_its_file() {
    let dir = tmp("delta");
    {
        let cat = Catalog::recover(&dir).unwrap();
        for i in 0..3 {
            commit_bytes(&cat, "t", format!("row {i}").as_bytes());
        }
        cat.checkpoint().unwrap();
    }
    let delta: PathBuf = std::fs::read_dir(dir.join("snapshots"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.file_name().unwrap().to_string_lossy().starts_with("delta-"))
        .expect("checkpoint must have written a delta snapshot");
    flip_byte(&delta, 0);

    let report = fsck_path(&dir, false).unwrap();
    assert!(!report.clean());
    let rel = format!("snapshots/{}", delta.file_name().unwrap().to_string_lossy());
    let codes = errors_naming(&report, &rel);
    assert!(
        codes.contains(&"AUDIT_CHECKPOINT_PARSE".to_string()),
        "expected AUDIT_CHECKPOINT_PARSE naming {rel}, got {codes:?} in:\n{}",
        report.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deep_catches_object_hash_damage_that_shallow_skips() {
    let dir = tmp("hash");
    let key;
    {
        let cat = Catalog::recover(&dir).unwrap();
        key = commit_bytes(&cat, "t", b"plain (non-BPB2) stored object");
    }
    let path = dir.join("objects").join(&key);
    flip_byte(&path, 4);

    // shallow: existence only — the flip goes unnoticed
    let shallow = fsck_path(&dir, false).unwrap();
    assert!(shallow.clean(), "shallow fsck must skip byte-level checks:\n{}", shallow.render());
    // deep: bytes no longer re-hash to the content-addressed key
    let deep = fsck_path(&dir, true).unwrap();
    let rel = format!("objects/{key}");
    let codes = errors_naming(&deep, &rel);
    assert!(
        codes.contains(&"AUDIT_OBJECT_HASH".to_string()),
        "expected AUDIT_OBJECT_HASH naming {rel}, got {codes:?} in:\n{}",
        deep.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deep_cross_checks_zone_map_footers() {
    let dir = tmp("zonemap");
    let key;
    {
        let cat = Catalog::recover(&dir).unwrap();
        let batch = Batch::new(
            vec![Column::f32("x", vec![1.0, 2.0, 3.0]), Column::i32("y", vec![4, 5, 6])],
            vec![1.0, 1.0, 1.0],
        )
        .unwrap();
        let bytes = encode_batch(&batch);
        assert_eq!(&bytes[..4], b"BPB2");
        let k = cat.store().put(bytes);
        let snap = Snapshot::new(vec![k.clone()], "S", "fp", 3, "rw");
        cat.commit(CommitRequest::new("main", "t", snap)).unwrap();
        key = k;
    }
    let path = dir.join("objects").join(&key);
    let len = std::fs::metadata(&path).unwrap().len() as usize;
    // the last byte sits inside the ZMS1 zone-map trailer
    flip_byte(&path, len - 1);

    let shallow = fsck_path(&dir, false).unwrap();
    assert!(shallow.clean(), "shallow fsck must skip zone-map checks:\n{}", shallow.render());
    let deep = fsck_path(&dir, true).unwrap();
    let rel = format!("objects/{key}");
    let codes = errors_naming(&deep, &rel);
    assert!(
        codes.contains(&"AUDIT_ZONEMAP_STATS".to_string()),
        "expected AUDIT_ZONEMAP_STATS naming {rel}, got {codes:?} in:\n{}",
        deep.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The flight-recorder gap fix: an unclean `bauplan fsck` leaves a
/// `flight-*.json` post-mortem in the lake directory naming the finding,
/// exactly like catalog poisoning does.
#[test]
fn unclean_fsck_dumps_the_flight_ring() {
    let dir = tmp("flight");
    {
        let cat = Catalog::open_durable_cfg(&dir, tiny_segments()).unwrap();
        for i in 0..10 {
            commit_bytes(&cat, "t", format!("row {i}").as_bytes());
        }
    }
    let segs = segment_files(&dir);
    let len = std::fs::metadata(&segs[0]).unwrap().len() as usize;
    flip_byte(&segs[0], len / 2);

    let lake = dir.to_string_lossy().into_owned();
    let rc = bauplan::cli::execute(bauplan::cli::Command::Fsck { lake, deep: false });
    assert_eq!(rc, 1, "unclean fsck must exit non-zero");

    let dumps: Vec<PathBuf> = std::fs::read_dir(dir.join("flight"))
        .expect("fsck must have created a flight directory")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().unwrap().to_string_lossy().starts_with("flight-"))
        .collect();
    assert!(!dumps.is_empty(), "no flight dump written");
    let named = dumps.iter().any(|p| {
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        let body = std::fs::read_to_string(p).unwrap_or_default();
        name.contains("fsck") && body.contains("AUDIT_")
    });
    assert!(named, "flight dump must name the fsck finding: {dumps:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
