//! Wavefront-scheduler integration: DAG parallelism, schedule
//! determinism, cancellation/failure injection under concurrency, and
//! the durable run registry.
//!
//! Everything runs on the simulated compute backend (`Client::open_sim`)
//! — no PJRT, no compiled artifacts — so this suite is exercised on
//! every CI run. Spec: `doc/SCHEDULER.md`.

use std::sync::Arc;

use bauplan::bench_util::diamond_pipeline as diamond;
use bauplan::catalog::{BranchState, Catalog, MAIN};
use bauplan::client::Client;
use bauplan::dag::PipelineSpec;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};
use bauplan::storage::ObjectStore;

const T: RunMode = RunMode::Transactional;

/// Fresh sim-backed lakehouse with seeded raw data and the given
/// wavefront width.
fn sim_client(jobs: usize) -> Client {
    let c = Client::open_sim().unwrap();
    c.seed_raw_table(MAIN, 3, 1200).unwrap();
    c.with_jobs(jobs)
}

// ---------------------------------------------------------------- happy path

#[test]
fn paper_pipeline_succeeds_at_jobs_4() {
    let c = sim_client(4);
    let run = c.run_spec(&PipelineSpec::paper_pipeline(), MAIN).unwrap();
    assert!(run.is_success(), "{:?}", run.status);
    // the chain serializes even at jobs=4: outputs in plan order
    assert_eq!(run.outputs, vec!["parent_table", "child_table", "grand_child"]);
    let head = c.catalog.read_ref(MAIN).unwrap();
    assert_eq!(head.tables.len(), 4);
    // txn branch cleaned up
    assert!(c.catalog.list_branches().iter().all(|b| !b.transactional));
}

#[test]
fn diamond_publishes_every_table_and_counts_wavefronts() {
    let c = sim_client(4);
    let plan = diamond(4).plan().unwrap();
    let run = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(run.is_success(), "{:?}", run.status);
    assert_eq!(run.outputs.len(), 5);
    // the join must commit after every parent (completion order)
    assert_eq!(run.outputs.last().map(String::as_str), Some("join"));
    let head = c.catalog.read_ref(MAIN).unwrap();
    for t in ["p0", "p1", "p2", "p3", "join"] {
        assert!(head.tables.contains_key(t), "missing {t}");
    }
    // metrics expose the shape: 2 wavefronts for the diamond
    assert_eq!(c.runner.metrics.counter("run.wavefronts"), 2);
    assert!(c.runner.metrics.histogram("run.parallelism").count() >= 1);
}

// ---------------------------------------------------------------- determinism

#[test]
fn prop_published_state_byte_identical_jobs1_vs_jobs4() {
    // Scheduler-determinism property: same plan, same seed, same pinned
    // run id — the published branch state (tables → snapshot ids) must
    // be byte-identical at every wavefront width.
    for seed in [1u64, 7, 42] {
        let catalog = Catalog::new(Arc::new(ObjectStore::new()));
        let c1 = Client::open_sim_with_catalog(catalog.clone()).unwrap().with_jobs(1);
        let c4 = Client::open_sim_with_catalog(catalog).unwrap().with_jobs(4);
        c1.seed_table(MAIN, "raw_table", "RawSchema", bauplan::data::raw_table(seed, 3, 900))
            .unwrap();
        c1.create_branch("det1", MAIN).unwrap();
        c1.create_branch("det4", MAIN).unwrap();
        let plan = diamond(4).plan().unwrap();
        let run_id = format!("run_det_{seed}");
        // sequentially: the first run's txn branch is merged + deleted
        // before the second starts, so the pinned id is reusable
        let r1 = c1
            .runner
            .run_with_id(&plan, "det1", T, &FailurePlan::none(), &[], &run_id)
            .unwrap();
        let r4 = c4
            .runner
            .run_with_id(&plan, "det4", T, &FailurePlan::none(), &[], &run_id)
            .unwrap();
        assert!(r1.is_success() && r4.is_success());
        let s1 = c1.catalog.read_ref("det1").unwrap();
        let s4 = c4.catalog.read_ref("det4").unwrap();
        assert_eq!(
            s1.tables, s4.tables,
            "seed {seed}: schedule changed the published state"
        );
        // and both runs agree on the code identity
        assert_eq!(r1.code_hash, r4.code_hash);
    }
}

// ---------------------------------------------------------------- concurrency

#[test]
fn stress_concurrent_transactional_runs_on_distinct_branches() {
    // N concurrent transactional runs, each at jobs=4, on one shared
    // catalog: every run publishes atomically on its own branch.
    let c = sim_client(4);
    let plan = Arc::new(diamond(3).plan().unwrap());
    let mut handles = vec![];
    for i in 0..6 {
        let c = c.clone();
        let plan = plan.clone();
        let branch = format!("stress{i}");
        c.create_branch(&branch, MAIN).unwrap();
        handles.push(std::thread::spawn(move || {
            let run = c.run_plan(&plan, &branch, T, &FailurePlan::none(), &[]).unwrap();
            assert!(run.is_success(), "{:?}", run.status);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    for i in 0..6 {
        let head = c.catalog.read_ref(&format!("stress{i}")).unwrap();
        assert_eq!(head.tables.len(), 5, "branch stress{i} incomplete");
    }
    // no transactional branch leaked
    assert!(c.catalog.list_branches().iter().all(|b| !b.transactional));
}

// ---------------------------------------------------------------- failures

#[test]
fn crash_before_join_aborts_with_parents_committed() {
    // deterministic even at jobs=4: the join is dispatched only after
    // every parent committed, so the aborted branch holds exactly the
    // first wavefront
    let c = sim_client(4);
    let plan = diamond(4).plan().unwrap();
    let before = c.catalog.resolve(MAIN).unwrap();
    let run = c
        .run_plan(&plan, MAIN, T, &FailurePlan::crash_before("join"), &[])
        .unwrap();
    let RunStatus::Aborted { txn_branch, cause } = &run.status else {
        panic!("expected abort, got {:?}", run.status)
    };
    assert!(cause.contains("before node"));
    assert_eq!(c.catalog.resolve(MAIN).unwrap(), before, "target untouched");
    let aborted = c.catalog.read_ref(txn_branch).unwrap();
    for t in ["p0", "p1", "p2", "p3"] {
        assert!(aborted.tables.contains_key(t), "wavefront 1 output {t} missing");
    }
    assert!(!aborted.tables.contains_key("join"));
    assert_eq!(
        c.catalog.branch_info(txn_branch).unwrap().state,
        BranchState::Aborted
    );
}

#[test]
fn crash_after_a_middle_node_cancels_the_join() {
    let c = sim_client(4);
    let plan = diamond(4).plan().unwrap();
    let before = c.catalog.resolve(MAIN).unwrap();
    let run = c
        .run_plan(&plan, MAIN, T, &FailurePlan::crash_after("p1"), &[])
        .unwrap();
    let RunStatus::Aborted { txn_branch, .. } = &run.status else {
        panic!("expected abort, got {:?}", run.status)
    };
    assert_eq!(c.catalog.resolve(MAIN).unwrap(), before, "target untouched");
    let aborted = c.catalog.read_ref(txn_branch).unwrap();
    // deterministic per node name: p1 committed (crash fires after its
    // commit), and the join — downstream of the failure — never ran
    assert!(aborted.tables.contains_key("p1"));
    assert!(!aborted.tables.contains_key("join"));
}

#[test]
fn direct_write_partial_failure_counts_committed_tables() {
    let c = sim_client(1);
    let plan = c
        .control_plane
        .plan_from_spec(&PipelineSpec::paper_pipeline())
        .unwrap();
    let run = c
        .run_plan(&plan, MAIN, RunMode::DirectWrite, &FailurePlan::crash_after("parent_table"), &[])
        .unwrap();
    let RunStatus::FailedPartial { tables_published, .. } = run.status else {
        panic!("expected partial failure")
    };
    assert_eq!(tables_published, 1, "the crashed node's commit landed first");
    assert!(c.catalog.read_ref(MAIN).unwrap().tables.contains_key("parent_table"));
}

// ---------------------------------------------------------------- cache

#[test]
fn warm_parallel_rerun_hits_every_node() {
    // concurrent lookups + populate-after-verify under jobs=4
    let mut c = sim_client(4);
    c.attach_run_cache(Arc::new(bauplan::cache::RunCache::in_memory(256 << 20)));
    let plan = diamond(4).plan().unwrap();
    let cold = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(cold.is_success());
    assert_eq!(cold.cache_misses, 5, "cold run executes everything");
    let warm = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(warm.is_success());
    assert_eq!(warm.cache_hits, 5, "warm parallel run must hit every node");
    assert_eq!(warm.cache_misses, 0);
}

// ---------------------------------------------------------------- durability

fn test_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bpl_sched_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn run_registry_survives_process_restart() {
    let dir = test_dir("registry");
    let (ok_id, bad_id);
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let c = Client::open_sim_with_catalog(catalog).unwrap().with_jobs(2);
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let ok = c.run_spec(&PipelineSpec::paper_pipeline(), MAIN).unwrap();
        assert!(ok.is_success());
        ok_id = ok.run_id.clone();
        let plan = c
            .control_plane
            .plan_from_spec(&PipelineSpec::paper_pipeline())
            .unwrap();
        let bad = c
            .run_plan(&plan, MAIN, T, &FailurePlan::crash_after("child_table"), &[])
            .unwrap();
        assert!(matches!(bad.status, RunStatus::Aborted { .. }));
        bad_id = bad.run_id.clone();
        // in-process lookups see both
        assert!(c.get_run(&ok_id).is_some());
        c.catalog.checkpoint().unwrap();
        // process "dies" here
    }
    // a fresh process over the same lake answers get_run for both runs
    let catalog = Catalog::recover(&dir).unwrap();
    let c2 = Client::open_sim_with_catalog(catalog).unwrap();
    let ok = c2.get_run(&ok_id).expect("successful run record lost");
    assert_eq!(ok.status, RunStatus::Success);
    assert_eq!(ok.pipeline, "paper_dag");
    assert_eq!(ok.outputs, vec!["parent_table", "child_table", "grand_child"]);
    let bad = c2.get_run(&bad_id).expect("aborted run record lost");
    let RunStatus::Aborted { txn_branch, .. } = &bad.status else {
        panic!("aborted status lost in the roundtrip")
    };
    // the retained triage branch the record names still resolves
    assert!(c2.catalog.branch_info(txn_branch).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_registry_survives_via_journal_tail_without_checkpoint() {
    let dir = test_dir("registry_tail");
    let run_id;
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let run = c.run_spec(&PipelineSpec::paper_pipeline(), MAIN).unwrap();
        assert!(run.is_success());
        run_id = run.run_id.clone();
        // no checkpoint: the record must recover from the journal alone
    }
    let catalog = Catalog::recover(&dir).unwrap();
    assert!(catalog.get_run_record(&run_id).is_some(), "journal replay lost the record");
    let c2 = Client::open_sim_with_catalog(catalog).unwrap();
    assert_eq!(c2.get_run(&run_id).unwrap().status, RunStatus::Success);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_parallel_run_recovers_to_aborted_orphan() {
    // kill mode at jobs=4: the "process dies" mid-run; recovery aborts
    // the orphaned txn branch and the target is untouched — the
    // concurrent schedule changes none of the durability story
    let dir = test_dir("kill");
    let main_head;
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let c = Client::open_sim_with_catalog(catalog).unwrap().with_jobs(4);
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        main_head = c.catalog.resolve(MAIN).unwrap();
        let plan = diamond(4).plan().unwrap();
        let err = c.run_plan(&plan, MAIN, T, &FailurePlan::kill_after("p1"), &[]);
        assert!(err.is_err(), "kill mode propagates the raw error");
        // no registry entry, no run record — the process "died"
    }
    let r = Catalog::recover(&dir).unwrap();
    assert_eq!(r.resolve(MAIN).unwrap(), main_head, "target untouched");
    let orphan = r
        .list_branches()
        .into_iter()
        .find(|b| b.transactional)
        .expect("orphaned txn branch retained");
    assert_eq!(orphan.state, BranchState::Aborted, "recovery aborts the orphan");
    assert!(r.run_records().is_empty(), "a killed run must leave no record");
    let _ = std::fs::remove_dir_all(&dir);
}
