//! The run cache, end to end on the simulated compute backend: warm
//! re-runs publish memoized snapshots, an edited node re-executes only
//! its downstream cone, entries appear only after verifiers pass, pins
//! keep cached snapshots alive across branch deletion + GC, and the
//! durable index recovers (or is safely discarded) after crashes.
//!
//! Everything here runs without PJRT or compiled artifacts —
//! `Client::open_sim` serves the kernels from the pure-rust reference
//! semantics, so these tests never skip.

use std::sync::Arc;

use bauplan::cache::RunCache;
use bauplan::catalog::{Catalog, MAIN};
use bauplan::client::Client;
use bauplan::dag::PipelineSpec;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};

const T: RunMode = RunMode::Transactional;
const NODES: [&str; 3] = ["parent_table", "child_table", "grand_child"];

fn sim_client() -> Client {
    let c = Client::open_sim().unwrap();
    c.seed_raw_table(MAIN, 3, 1200).unwrap();
    c
}

fn paper_plan(c: &Client) -> bauplan::dag::Plan {
    c.control_plane
        .plan_from_spec(&PipelineSpec::paper_pipeline())
        .unwrap()
}

/// The paper pipeline with `child`'s scale parameter edited.
fn edited_spec() -> PipelineSpec {
    let mut spec = PipelineSpec::paper_pipeline();
    spec.nodes[1].params[2] = 0.75;
    spec
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bpl_icache_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// ---------------------------------------------------------------- warm path

#[test]
fn warm_rerun_hits_every_node() {
    let mut c = sim_client();
    let cache = Arc::new(RunCache::in_memory(u64::MAX));
    c.attach_run_cache(cache.clone());
    let plan = paper_plan(&c);

    let cold = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(cold.is_success(), "{:?}", cold.status);
    assert_eq!((cold.cache_hits, cold.cache_misses), (0, 3));
    let tables_after_cold = c.catalog.read_ref(MAIN).unwrap().tables;

    let warm = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(warm.is_success());
    assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));
    assert!(warm.cache_bytes_saved > 0);

    // a hit republishes the *same* snapshot: the lake state is unchanged
    let tables_after_warm = c.catalog.read_ref(MAIN).unwrap().tables;
    assert_eq!(tables_after_cold, tables_after_warm);

    // the cache.* counter family is surfaced on the runner metrics
    assert_eq!(c.runner.metrics.counter("cache.hits"), 3);
    assert_eq!(c.runner.metrics.counter("cache.misses"), 3);
    assert!(c.runner.metrics.counter("cache.bytes_saved") > 0);
    assert_eq!(c.runner.metrics.counters_prefixed("cache").len(), 3);

    let s = cache.stats();
    assert_eq!((s.entries, s.hits, s.misses, s.populated), (3, 3, 3, 3));
}

#[test]
fn cache_hit_is_byte_identical_to_a_cold_run() {
    let mut cached = sim_client();
    let cache = Arc::new(RunCache::in_memory(u64::MAX));
    cached.attach_run_cache(cache.clone());
    // an uncached client over the SAME catalog: the control experiment
    let uncached = Client::open_sim_with_catalog(cached.catalog.clone()).unwrap();
    let plan = paper_plan(&cached);

    cached.create_branch("populate", MAIN).unwrap();
    cached.run_plan(&plan, "populate", T, &FailurePlan::none(), &[]).unwrap();

    cached.create_branch("warm", MAIN).unwrap();
    let warm = cached.run_plan(&plan, "warm", T, &FailurePlan::none(), &[]).unwrap();
    assert_eq!(warm.cache_hits, 3);

    uncached.create_branch("cold", MAIN).unwrap();
    let cold = uncached.run_plan(&plan, "cold", T, &FailurePlan::none(), &[]).unwrap();
    assert!(cold.is_success());
    assert_eq!(cold.cache_misses, 0, "uncached runner must not touch the cache");

    let warm_head = cached.catalog.read_ref("warm").unwrap();
    let cold_head = cached.catalog.read_ref("cold").unwrap();
    for t in NODES {
        let w = cached.catalog.get_snapshot(&warm_head.tables[t]).unwrap();
        let c2 = cached.catalog.get_snapshot(&cold_head.tables[t]).unwrap();
        // object keys are content hashes: equal keys <=> identical bytes
        assert_eq!(w.objects, c2.objects, "table {t} differs from a cold run");
        assert_eq!(w.row_count, c2.row_count);
    }
}

#[test]
fn edited_node_reexecutes_only_its_downstream_cone() {
    let mut c = sim_client();
    let cache = Arc::new(RunCache::in_memory(u64::MAX));
    c.attach_run_cache(cache.clone());

    let plan = paper_plan(&c);
    c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    let before = c.catalog.read_ref(MAIN).unwrap().tables;

    let plan2 = c.control_plane.plan_from_spec(&edited_spec()).unwrap();
    let run = c.run_plan(&plan2, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(run.is_success(), "{:?}", run.status);
    // parent is upstream of the edit: hit. child + grand_child: the cone.
    assert_eq!((run.cache_hits, run.cache_misses), (1, 2));

    let after = c.catalog.read_ref(MAIN).unwrap().tables;
    assert_eq!(before["parent_table"], after["parent_table"], "hit must republish");
    assert_ne!(before["child_table"], after["child_table"], "edited node must re-run");
    assert_ne!(before["grand_child"], after["grand_child"], "cone must re-run");

    // the cone is memoized too: a third run is all hits
    let again = c.run_plan(&plan2, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert_eq!((again.cache_hits, again.cache_misses), (3, 0));
}

// ------------------------------------------------------- verify-before-populate

#[test]
fn populate_happens_only_after_verifiers_pass() {
    let mut c = sim_client();
    let cache = Arc::new(RunCache::in_memory(u64::MAX));
    c.attach_run_cache(cache.clone());
    let plan = paper_plan(&c);

    // verifier veto: every node executed, nothing becomes reusable
    let vetoed = c
        .run_plan(
            &plan,
            MAIN,
            T,
            &FailurePlan::none(),
            &[bauplan::runs::Verifier::min_rows("grand_child", 10_000_000)],
        )
        .unwrap();
    assert!(matches!(vetoed.status, RunStatus::Aborted { .. }));
    assert_eq!(vetoed.cache_misses, 3);
    assert!(cache.is_empty(), "aborted run must not populate the cache");

    // mid-run crash: ditto
    let crashed = c
        .run_plan(&plan, MAIN, T, &FailurePlan::crash_after("child_table"), &[])
        .unwrap();
    assert!(matches!(crashed.status, RunStatus::Aborted { .. }));
    assert!(cache.is_empty());

    // and a later healthy run gets zero hits — proof nothing leaked
    let healthy = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(healthy.is_success());
    assert_eq!((healthy.cache_hits, healthy.cache_misses), (0, 3));
    assert_eq!(cache.len(), 3);
}

// ---------------------------------------------------------------- pinning

#[test]
fn pinned_entries_survive_branch_deletion_and_gc() {
    let mut c = sim_client();
    let cache = Arc::new(RunCache::in_memory(u64::MAX));
    c.attach_run_cache(cache.clone());
    let plan = paper_plan(&c);

    c.create_branch("feature", MAIN).unwrap();
    c.run_plan(&plan, "feature", T, &FailurePlan::none(), &[]).unwrap();
    assert_eq!(cache.len(), 3);
    for e in cache.entries() {
        assert_eq!(c.catalog.pin_count(&e.snapshot_id), 1);
    }

    // the only branch referencing the outputs goes away...
    c.catalog.delete_branch("feature").unwrap();
    c.catalog.gc().unwrap();
    // ...but every cached snapshot (and its objects) survives the sweep
    for e in cache.entries() {
        let snap = c.catalog.get_snapshot(&e.snapshot_id).unwrap();
        for obj in &snap.objects {
            c.catalog.store().get(obj).unwrap();
        }
    }

    // so a warm run on a fresh branch publishes without executing
    c.create_branch("b2", MAIN).unwrap();
    let warm = c.run_plan(&plan, "b2", T, &FailurePlan::none(), &[]).unwrap();
    assert!(warm.is_success());
    assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));

    // clear releases the pins; once unreachable, GC may finally collect
    c.catalog.delete_branch("b2").unwrap();
    let cleared = cache.clear();
    assert_eq!(cleared.len(), 3);
    for e in &cleared {
        c.catalog.unpin_snapshot(&e.snapshot_id);
    }
    c.catalog.gc().unwrap();
    for e in &cleared {
        assert!(c.catalog.get_snapshot(&e.snapshot_id).is_err(), "unpinned snapshot kept");
    }
}

#[test]
fn eviction_releases_pins() {
    let mut c = sim_client();
    // absurdly small budget: every populate immediately evicts
    let cache = Arc::new(RunCache::in_memory(1));
    c.attach_run_cache(cache.clone());
    let plan = paper_plan(&c);

    let run = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert!(run.is_success());
    assert!(cache.is_empty(), "budget 1 byte keeps nothing");
    assert_eq!(cache.stats().evictions, 3);

    // every pin was released with its eviction
    let head = c.catalog.read_ref(MAIN).unwrap();
    for t in NODES {
        assert_eq!(c.catalog.pin_count(&head.tables[t]), 0, "leaked pin on {t}");
    }

    // nothing cached => the next run re-executes everything
    let rerun = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    assert_eq!((rerun.cache_hits, rerun.cache_misses), (0, 3));
}

// ---------------------------------------------------------------- durability

#[test]
fn durable_index_recovers_after_a_kill_and_discards_unverified_work() {
    let dir = tmpdir("kill");
    let cache_path = dir.join(bauplan::cache::CACHE_INDEX_FILE);
    let plan_spec = PipelineSpec::paper_pipeline();

    // session 1: durable lake + durable cache, one verified run, then a
    // run that dies mid-flight with the edited node half-done
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        c.attach_run_cache(cache.clone());
        let plan = c.control_plane.plan_from_spec(&plan_spec).unwrap();
        let ok = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert!(ok.is_success());
        assert_eq!(cache.len(), 3);

        // the edited child executes, its commit lands on the txn branch,
        // then the "process dies" — its pending cache entry must die too
        let plan2 = c.control_plane.plan_from_spec(&edited_spec()).unwrap();
        let err = c.run_plan(&plan2, MAIN, T, &FailurePlan::kill_after("child_table"), &[]);
        assert!(err.is_err());
    }

    // simulate a torn tail on top of the kill
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&cache_path).unwrap();
        f.write_all(b"{\"crc\":\"torn").unwrap();
    }

    // session 2: everything recovers — catalog via journal replay, cache
    // via the valid index prefix; the killed run contributed nothing
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        assert_eq!(cache.len(), 3, "verified entries must survive the crash");
        c.attach_run_cache(cache.clone());
        assert_eq!(cache.len(), 3, "recovered snapshots must re-pin cleanly");

        let plan = c.control_plane.plan_from_spec(&plan_spec).unwrap();
        let warm = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert!(warm.is_success());
        assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));

        // the killed run's edited node was never verified => miss
        let plan2 = c.control_plane.plan_from_spec(&edited_spec()).unwrap();
        let edited = c.run_plan(&plan2, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert!(edited.is_success());
        assert_eq!((edited.cache_hits, edited.cache_misses), (1, 2));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_index_is_discarded_and_rebuilt() {
    let dir = tmpdir("corrupt");
    let cache_path = dir.join(bauplan::cache::CACHE_INDEX_FILE);
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        c.attach_run_cache(cache.clone());
        let plan = paper_plan(&c);
        c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert_eq!(cache.len(), 3);
    }
    // corrupt the index from byte 0: nothing salvageable
    std::fs::write(&cache_path, b"garbage from another tool\n").unwrap();
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        assert!(cache.is_empty(), "corrupt index must be discarded, not trusted");
        c.attach_run_cache(cache.clone());
        // runs still work and repopulate from scratch
        let plan = paper_plan(&c);
        let run = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert!(run.is_success());
        assert_eq!((run.cache_hits, run.cache_misses), (0, 3));
        assert_eq!(cache.len(), 3);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_durable_entries_are_dropped_on_attach() {
    let dir = tmpdir("stale");
    let cache_path = dir.join(bauplan::cache::CACHE_INDEX_FILE);
    // build a durable index against one lake...
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        c.attach_run_cache(cache.clone());
        let plan = paper_plan(&c);
        c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
    }
    // ...then attach it to a brand-new, empty catalog: every snapshot it
    // names is unknown there, so attach must drop all entries rather
    // than let a run publish snapshots the catalog cannot serve
    {
        let mut c = Client::open_sim().unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        assert_eq!(cache.len(), 3);
        c.attach_run_cache(cache.clone());
        assert!(cache.is_empty(), "stale entries must not survive attach");
        let plan = paper_plan(&c);
        let run = c.run_plan(&plan, MAIN, T, &FailurePlan::none(), &[]).unwrap();
        assert!(run.is_success());
        assert_eq!(run.cache_hits, 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_gc_repins_cached_snapshots_from_the_durable_index() {
    let dir = tmpdir("cligc");
    let cache_path = dir.join(bauplan::cache::CACHE_INDEX_FILE);
    // session 1: run on a feature branch, then delete it — the cached
    // snapshots' only remaining root is the cache itself
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        c.attach_run_cache(cache.clone());
        c.create_branch("feature", MAIN).unwrap();
        let plan = paper_plan(&c);
        c.run_plan(&plan, "feature", T, &FailurePlan::none(), &[]).unwrap();
        assert_eq!(cache.len(), 3);
        c.catalog.delete_branch("feature").unwrap();
    }
    // session 2: a standalone `bauplan gc` — pins are per-process, so it
    // must re-establish them from cache.jsonl before sweeping
    let lake = dir.to_string_lossy().into_owned();
    assert_eq!(bauplan::cli::execute(bauplan::cli::Command::Gc { lake }), 0);
    // session 3: the cache still serves every node
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        let cache = Arc::new(RunCache::open(&cache_path, u64::MAX).unwrap());
        assert_eq!(cache.len(), 3);
        c.attach_run_cache(cache.clone());
        assert_eq!(cache.len(), 3, "gc collected snapshots the cache still memoizes");
        c.create_branch("b2", MAIN).unwrap();
        let plan = paper_plan(&c);
        let warm = c.run_plan(&plan, "b2", T, &FailurePlan::none(), &[]).unwrap();
        assert!(warm.is_success());
        assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- pins across recovery

#[test]
fn journaled_gc_replays_with_its_recorded_pins() {
    let dir = tmpdir("gcpins");
    let snap_ids: Vec<String>;
    {
        let catalog = Catalog::recover(&dir).unwrap();
        let mut c = Client::open_sim_with_catalog(catalog).unwrap();
        c.seed_raw_table(MAIN, 2, 800).unwrap();
        let cache = Arc::new(RunCache::in_memory(u64::MAX));
        c.attach_run_cache(cache.clone());
        let plan = paper_plan(&c);
        c.create_branch("feature", MAIN).unwrap();
        c.run_plan(&plan, "feature", T, &FailurePlan::none(), &[]).unwrap();
        snap_ids = cache.entries().iter().map(|e| e.snapshot_id.clone()).collect();
        c.catalog.delete_branch("feature").unwrap();
        // gc with live pins: journal records the pin roots it used
        c.catalog.gc().unwrap();
        for id in &snap_ids {
            assert!(c.catalog.get_snapshot(id).is_ok());
        }
        // no checkpoint: force the next open to REPLAY the gc record
    }
    {
        let catalog = Catalog::recover(&dir).unwrap();
        // replayed gc must keep exactly what the original kept, even
        // though no pins are live during recovery
        for id in &snap_ids {
            assert!(
                catalog.get_snapshot(id).is_ok(),
                "gc replay diverged from the original sweep"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
