//! §Perf ablations — the design choices DESIGN.md calls out, isolated:
//!
//! 1. executor pool size (1 / 2 / 4 PJRT worker threads);
//! 2. Appendix-A lineage-based validation skipping (on / off);
//! 3. M3 validation entirely on vs off (what fail-fast costs at M3);
//! 4. fused stats kernel vs pure-rust stats loop (L1 fusion payoff).

use std::path::Path;
use std::sync::Arc;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::Catalog;
use bauplan::client::Client;
use bauplan::contracts::schema::SchemaRegistry;
use bauplan::control_plane::ControlPlane;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode, Runner};
use bauplan::runtime::{ExecHandle, TensorArg};
use bauplan::storage::ObjectStore;
use bauplan::worker::Worker;

fn client_with(pool: usize, lineage: bool) -> Client {
    let runtime = Arc::new(ExecHandle::start_pool(Path::new("artifacts"), pool).unwrap());
    let catalog = Catalog::new(Arc::new(ObjectStore::new()));
    let registry = SchemaRegistry::with_paper_schemas();
    let mut worker = Worker::new(runtime.clone(), catalog.clone(), registry);
    if lineage {
        worker = worker.with_lineage_skipping().unwrap();
    }
    let control_plane = ControlPlane::new(runtime.clone());
    let runner = Runner::new(catalog.clone(), worker.clone());
    Client { catalog, runtime, control_plane, runner, worker }
}

fn main() {
    let mut b = Bench::heavy("PERF_ablation");
    b.header();
    b.max_iters = 25;

    // 1. pool size
    for pool in [1usize, 2, 4] {
        let client = client_with(pool, true);
        client.seed_raw_table("main", 4, 1800).unwrap();
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
        b.run(&format!("full txn run, pool={pool}, lineage=on"), || {
            black_box(
                client
                    .run_plan(&plan, "main", RunMode::Transactional, &FailurePlan::none(), &[])
                    .unwrap(),
            );
        });
    }

    // 2. lineage skipping off
    {
        let client = client_with(2, false);
        client.seed_raw_table("main", 4, 1800).unwrap();
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
        b.run("full txn run, pool=2, lineage=off", || {
            black_box(
                client
                    .run_plan(&plan, "main", RunMode::Transactional, &FailurePlan::none(), &[])
                    .unwrap(),
            );
        });
        println!(
            "    validations: done={} skipped={}",
            client.worker.metrics.counter("worker.columns_validated"),
            client.worker.metrics.counter("worker.validation_skipped"),
        );
    }
    {
        let client = client_with(2, true);
        client.seed_raw_table("main", 4, 1800).unwrap();
        let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
        b.run("validate_table only, lineage=on", || {
            let head = client.catalog.read_ref("main").unwrap();
            let t = client.worker.read_table(&head, "raw_table").unwrap();
            black_box(client.worker.validate_table(&t).unwrap());
        });
        println!(
            "    validations: done={} skipped={}",
            client.worker.metrics.counter("worker.columns_validated"),
            client.worker.metrics.counter("worker.validation_skipped"),
        );
    }

    // 4. fused stats kernel vs rust loop (same column, same semantics)
    {
        let rt = ExecHandle::start_pool(Path::new("artifacts"), 1).unwrap();
        let n = rt.manifest().n;
        let x: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let inc = vec![1.0f32; n];
        b.run("stats via fused AOT kernel (PJRT)", || {
            black_box(
                rt.execute("validate_n", &[TensorArg::F32(x.clone()), TensorArg::F32(inc.clone())])
                    .unwrap(),
            );
        });
        b.run("stats via rust scalar loop", || {
            let mut cnt = 0.0f32;
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            let mut sum = 0.0f32;
            for (&v, &i) in x.iter().zip(&inc) {
                if i > 0.0 && !v.is_nan() {
                    cnt += 1.0;
                    mn = mn.min(v);
                    mx = mx.max(v);
                    sum += v;
                }
            }
            black_box((cnt, mn, mx, sum));
        });
    }

    b.report();
}
