//! E7/E8 — the lightweight formal model (paper §4, Fig. 4).
//!
//! Rows: per-scenario states explored, search depth, wall time, and the
//! verdict — mirroring what the paper reports from Alloy: adequacy (the
//! Fig. 3 asymmetry), the Fig. 4 counterexample, and the guardrail fix.
//! A scope-scaling sweep shows the (expected) exponential state growth
//! that motivates "lightweight"/small-scope checking.

use std::time::Instant;

use bauplan::model::{check, Scenario};

fn main() {
    println!("\n=== bench: E7/E8 model checker ===\n");
    println!("{:<32} {:>10} {:>7} {:>10}  verdict", "scenario", "states", "depth", "time");
    for sc in [
        Scenario::direct_writes(),
        Scenario::paper_protocol(),
        Scenario::counterexample(),
        Scenario::counterexample_fixed(),
    ] {
        let t0 = Instant::now();
        let out = check(&sc);
        let dt = t0.elapsed();
        let verdict = match &out.violation {
            Some(t) => format!("VIOLATION in {} ops", t.ops.len()),
            None => "safe (scope exhausted)".to_string(),
        };
        println!(
            "{:<32} {:>10} {:>7} {:>9.1?}  {verdict}",
            out.scenario,
            out.states_explored,
            out.max_depth_reached,
            dt
        );
        println!(
            "BENCH E7_model | {} | states={} depth={} us={} violation={}",
            out.scenario,
            out.states_explored,
            out.max_depth_reached,
            dt.as_micros(),
            out.violation.is_some()
        );
    }

    // adequacy assertions (E8): the expected asymmetry
    assert!(check(&Scenario::direct_writes()).violation.is_some());
    assert!(check(&Scenario::paper_protocol()).violation.is_none());
    assert!(check(&Scenario::counterexample()).violation.is_some());
    assert!(check(&Scenario::counterexample_fixed()).violation.is_none());
    println!("\n  adequacy: Fig.3 asymmetry + Fig.4 counterexample + guardrail all reproduced");

    // scope scaling (why small-scope: states blow up fast)
    println!("\n  scope scaling (paper_protocol, safe scenario):");
    println!("  {:<28} {:>10} {:>10}", "scope", "states", "time");
    for (runs, plan) in [(1u8, 2u8), (1, 3), (2, 2), (2, 3), (3, 2)] {
        let sc = Scenario {
            max_runs: runs,
            plan_len: plan,
            max_states: 10_000_000,
            ..Scenario::paper_protocol()
        };
        let t0 = Instant::now();
        let out = check(&sc);
        println!(
            "  runs={runs} plan_len={plan}{:<12} {:>10} {:>9.1?}",
            "",
            out.states_explored,
            t0.elapsed()
        );
        assert!(out.violation.is_none());
    }
}
