//! E5 — transactional protocol overhead (paper §3.3's trade-off note).
//!
//! The paper: "the transactional branch protocol introduces metadata and
//! coordination overhead relative to direct writes ... acceptable because
//! pipelines are coarse-grained". Rows: end-to-end run latency under
//! DirectWrite vs Transactional across pipeline granularities (data per
//! run), plus the same with simulated S3 latency — the regime where the
//! relative overhead collapses.

use std::sync::Arc;
use std::time::Duration;

use bauplan::bench_util::{black_box, Bench};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode};
use bauplan::storage::ObjectStore;

fn client_with(latency: Duration) -> Client {
    let store = Arc::new(ObjectStore::with_latency(latency));
    Client::open_with_store("artifacts", store).unwrap()
}

fn main() {
    let mut b = Bench::heavy("E5_transactional_overhead");
    b.header();
    b.max_iters = 20;

    let mut results = Vec::new();
    for (label, batches) in
        [("small (1 batch)", 1usize), ("medium (4 batches)", 4), ("large (16 batches)", 16)]
    {
        let mut pair = Vec::new();
        for (mode_label, mode) in
            [("direct", RunMode::DirectWrite), ("txn", RunMode::Transactional)]
        {
            let client = client_with(Duration::ZERO);
            client.seed_raw_table("main", batches, 1800).unwrap();
            let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
            let m = b.run(&format!("{label:<18} {mode_label}"), || {
                black_box(
                    client
                        .run_plan(&plan, "main", mode, &FailurePlan::none(), &[])
                        .unwrap(),
                );
            });
            pair.push(m.mean);
        }
        let overhead = (pair[1].as_secs_f64() / pair[0].as_secs_f64() - 1.0) * 100.0;
        results.push((label, overhead));
    }

    // with simulated object-store latency, compute+I/O dominate
    {
        let mut pair = Vec::new();
        for (mode_label, mode) in
            [("direct", RunMode::DirectWrite), ("txn", RunMode::Transactional)]
        {
            let client = client_with(Duration::from_micros(500));
            client.seed_raw_table("main", 4, 1800).unwrap();
            let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();
            let m = b.run(&format!("{:<18} {mode_label}", "remote-store 500us"), || {
                black_box(
                    client
                        .run_plan(&plan, "main", mode, &FailurePlan::none(), &[])
                        .unwrap(),
                );
            });
            pair.push(m.mean);
        }
        let overhead = (pair[1].as_secs_f64() / pair[0].as_secs_f64() - 1.0) * 100.0;
        results.push(("remote-store 500us", overhead));
    }

    println!("\n  transactional overhead vs direct writes:");
    for (label, o) in &results {
        println!("    {label:<20} {o:+.1}%");
    }
    println!("  expected shape (paper §3.3): overhead shrinks as pipelines get");
    println!("  coarser / storage gets slower — metadata ops are not the bottleneck.");

    b.report();
}
