//! E1/E9 — end-to-end run lifecycle cost breakdown (paper Fig. 1).
//!
//! Rows: full run latency through the three-layer stack, plus the
//! per-phase breakdown (plan / compute+validate / publish) that shows
//! where time goes — the coordinator (L3) must not be the bottleneck;
//! compute + storage I/O should dominate (paper §3.3's premise).

use bauplan::bench_util::{black_box, Bench};
use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode};
use bauplan::runtime::TensorArg;

fn main() {
    let mut b = Bench::heavy("E1_e2e_lifecycle");
    b.header();
    b.max_iters = 30;

    let client = Client::open("artifacts").unwrap();
    client.seed_raw_table("main", 4, 1800).unwrap();
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();

    // phase: control plane only
    b.run("plan (parse + M1 + M2 + physical)", || {
        black_box(client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap());
    });

    // phase: raw kernel execution (L1 via PJRT, no coordinator)
    let n = client.runtime.manifest().n;
    let col1 = vec![1i32; n];
    let colf = vec![1.0f32; n];
    b.run("PJRT execute: parent kernel (1 batch)", || {
        black_box(
            client
                .runtime
                .execute(
                    "parent",
                    &[
                        TensorArg::I32(col1.clone()),
                        TensorArg::F32(colf.clone()),
                        TensorArg::F32(colf.clone()),
                        TensorArg::F32(colf.clone()),
                    ],
                )
                .unwrap(),
        );
    });
    b.run("PJRT execute: validate_n kernel", || {
        black_box(
            client
                .runtime
                .execute(
                    "validate_n",
                    &[TensorArg::F32(colf.clone()), TensorArg::F32(colf.clone())],
                )
                .unwrap(),
        );
    });

    // phase: full transactional run (4 batches through all 3 nodes)
    b.run("full transactional run (4x1800 rows)", || {
        black_box(
            client
                .run_plan(&plan, "main", RunMode::Transactional, &FailurePlan::none(), &[])
                .unwrap(),
        );
    });
    b.run("full direct-write run (4x1800 rows)", || {
        black_box(
            client
                .run_plan(&plan, "main", RunMode::DirectWrite, &FailurePlan::none(), &[])
                .unwrap(),
        );
    });

    // where the time goes, from the engine's own metrics
    println!("\n  coordinator-internal timings (shared histograms):");
    print!("{}", client.runner.metrics.render());
    print!("{}", client.worker.metrics.render());

    let (puts, gets, bput, bget, dedup) = client.catalog.store().stats.snapshot();
    println!("  object store: puts={puts} gets={gets} bytes_put={bput} bytes_get={bget} dedup_hits={dedup}");

    b.report();
}
