//! E2 — Git-for-data costs (paper §3.2, Fig. 2).
//!
//! The claim: branch creation and merge are *logical* operations — cost
//! independent of table count and data volume, and no data is copied.
//! Rows: branch-create and merge latency as the lake grows 1 → 256
//! tables, plus commit/log/diff costs; a PASS line checks zero bytes
//! moved per branch.

use std::sync::Arc;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{Catalog, Snapshot, MAIN};
use bauplan::storage::ObjectStore;
use bauplan::testing::commit_table;

fn catalog_with_tables(n_tables: usize, rows_of_bytes: usize) -> Catalog {
    let store = Arc::new(ObjectStore::new());
    let c = Catalog::new(store.clone());
    for i in 0..n_tables {
        let key = store.put(vec![i as u8; rows_of_bytes]);
        commit_table(
            &c,
            MAIN,
            &format!("t{i}"),
            Snapshot::new(vec![key], "S", "fp", 1, "seed"),
            "u",
            "m",
            None,
        )
        .unwrap();
    }
    c
}

fn main() {
    let mut b = Bench::new("E2_branch_ops");
    b.header();

    for n_tables in [1usize, 16, 64, 256] {
        let c = catalog_with_tables(n_tables, 4096);
        let mut i = 0;
        b.run(&format!("branch create ({n_tables} tables in lake)"), || {
            i += 1;
            black_box(c.create_branch(&format!("b{i}"), MAIN, false).unwrap());
        });
    }

    for n_tables in [1usize, 64, 256] {
        let c = catalog_with_tables(n_tables, 4096);
        let store = c.store().clone();
        let mut i = 0;
        // pre-create source branches with one change each
        let bytes_before = store.stored_bytes();
        b.run(&format!("merge w/ 1 change ({n_tables} tables)"), || {
            i += 1;
            let name = format!("m{i}");
            c.create_branch(&name, MAIN, false).unwrap();
            commit_table(
                &c,
                &name,
                "t0",
                Snapshot::new(vec![format!("fresh{i}")], "S", "fp", 1, "r"),
                "u",
                "m",
                None,
            )
            .unwrap();
            // merge back is the measured op dominated path
            black_box(c.merge(&name, MAIN, false).unwrap());
        });
        assert_eq!(store.stored_bytes(), bytes_before, "merge moved data bytes!");
    }

    {
        let c = catalog_with_tables(64, 4096);
        let mut i = 0;
        b.run("commit_table (64-table lake)", || {
            i += 1;
            black_box(
                commit_table(
                    &c,
                    MAIN,
                    "hot",
                    Snapshot::new(vec![format!("o{i}")], "S", "fp", 1, "r"),
                    "u",
                    "m",
                    None,
                )
                .unwrap(),
            );
        });
        b.run("log(100) after many commits", || {
            black_box(c.log(MAIN, 100).unwrap());
        });
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(
            &c,
            "dev",
            "x",
            Snapshot::new(vec!["d".into()], "S", "fp", 1, "r"),
            "u",
            "m",
            None,
        )
        .unwrap();
        b.run("diff main..dev (64 tables)", || {
            black_box(c.diff(MAIN, "dev").unwrap());
        });
    }

    // zero-copy witness
    let c = catalog_with_tables(128, 16384);
    let bytes_before = c.store().stored_bytes();
    for i in 0..100 {
        c.create_branch(&format!("zc{i}"), MAIN, false).unwrap();
    }
    let delta = c.store().stored_bytes() - bytes_before;
    println!("\n  zero-copy check: 100 branches over a 128-table lake added {delta} data bytes");
    assert_eq!(delta, 0);
    println!("  PASS: branching is zero-copy (paper §3.2)");

    b.report();
}
