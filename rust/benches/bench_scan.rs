//! E9 — the fast data plane: zone-map pruned scans, the block cache,
//! and the binary wire format (doc/DATA_PLANE.md).
//!
//! Three claims, three lakes:
//!
//! - **claim 1** (predicate pushdown): over a multi-million-row table
//!   whose batches carry disjoint value ranges, a selective `[lo, hi]`
//!   range scan with zone maps skips decode + kernel dispatch for every
//!   batch the predicate can't touch. Rows: selective scan pruned /
//!   unpruned / full scan; `BENCH_SCAN_MIN_SPEEDUP` turns the
//!   pruned-vs-full ratio into a hard assertion (CI gates at 10x).
//! - **claim 2** (block cache): with a 2 ms injected object-store
//!   latency (the S3 round trip), a warm content-addressed cache takes
//!   that latency off every re-read; a zero-budget cache pays it each
//!   time. Rows: cold vs warm scan over the same table.
//! - **claim 3** (wire format): reading a table over loopback as a
//!   binary frame stream vs the JSON comparison path of the same route.
//!   The hard binary-vs-JSON assertion lives in `bench_server`; here the
//!   two throughputs land in the artifact.
//!
//! Besides the `BENCH` rows the run writes a machine-readable
//! **`BENCH_scan.json`** (override the path with `BENCH_SCAN_OUT`).

use std::sync::Arc;
use std::time::Duration;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{Catalog, Snapshot, MAIN};
use bauplan::client::remote::RemoteClient;
use bauplan::client::Client;
use bauplan::dag::NodeSpec;
use bauplan::runtime::sim::SIM_N;
use bauplan::server::{Server, ServerConfig};
use bauplan::storage::codec::encode_batch;
use bauplan::storage::{Batch, Column, ObjectStore};
use bauplan::testing::commit_table;
use bauplan::util::json::Json;
use bauplan::worker::Worker;

/// Batches in the big scan table; rows = `BATCHES * SIM_N` (~2.1M).
const BATCHES: usize = 1024;

/// Columns per batch. The transform kernel reads only the first column,
/// so extra columns model realistic decode cost that pruning skips.
const COLS: usize = 5;

/// Injected per-op object-store latency for the cache rows.
const STORE_LATENCY: Duration = Duration::from_millis(2);

/// Batches in the cache-rows table (cold scan = `CACHE_BATCHES` paid
/// round trips, so keep the table small enough to iterate).
const CACHE_BATCHES: usize = 64;

/// One batch whose first column covers `[base, base + SIM_N)` — batch
/// ranges are disjoint, so a narrow predicate isolates one batch.
fn batch_at(base: f32) -> Batch {
    let x: Vec<f32> = (0..SIM_N).map(|i| base + i as f32).collect();
    let mut cols = vec![Column::f32("x", x)];
    for c in 1..COLS {
        cols.push(Column::f32(&format!("pad{c}"), vec![c as f32; SIM_N]));
    }
    Batch::new(cols, vec![1.0; SIM_N]).unwrap()
}

/// Seed `table` on `main` with `batches` disjoint-range batches.
fn seed(client: &Client, table: &str, batches: usize) {
    let store = client.catalog.store();
    let mut keys = Vec::with_capacity(batches);
    for bi in 0..batches {
        keys.push(store.put(encode_batch(&batch_at((bi * SIM_N) as f32))));
    }
    let rows = (batches * SIM_N) as u64;
    let snap = Snapshot::new(keys, "RawSchema", "fp_scan", rows, "bench");
    commit_table(&client.catalog, MAIN, table, snap, "bench", "seed", None).unwrap();
}

/// One range scan `[lo, hi]` over `table` through the worker's lazy
/// scan path; returns the output batch count.
fn scan(worker: &Worker, catalog: &Catalog, table: &str, lo: f32, hi: f32) -> usize {
    let node = NodeSpec::new("out", "T", "transform_n")
        .input(table, "RawSchema")
        .with_params(vec![lo, hi, 2.0, 0.5]);
    let state = catalog.read_ref(MAIN).unwrap();
    let t = worker.execute_node(&node, &state).unwrap();
    black_box(t.batches.len())
}

fn main() {
    let mut b = Bench::heavy("E9_scan");
    b.header();

    // ---- claim 1: zone-map pruned vs full scans --------------------------
    let client = Client::open_sim().unwrap();
    seed(&client, "big", BATCHES);
    let rows_total = (BATCHES * SIM_N) as f64;
    // a predicate inside batch 3's range: every other batch prunes
    let (sel_lo, sel_hi) = ((3 * SIM_N) as f32 + 10.0, (3 * SIM_N) as f32 + 200.0);
    let unpruned = client.worker.clone().with_pruning(false);

    let m_sel = b.run("selective scan, zone maps on (2.1M rows)", || {
        scan(&client.worker, &client.catalog, "big", sel_lo, sel_hi);
    });
    let m_sel_off = b.run("selective scan, zone maps off", || {
        scan(&unpruned, &client.catalog, "big", sel_lo, sel_hi);
    });
    let m_full = b.run("full scan (predicate matches everything)", || {
        scan(&client.worker, &client.catalog, "big", -1.0, rows_total as f32 + 1.0);
    });
    let speedup = m_full.p50.as_secs_f64() / m_sel.p50.as_secs_f64();
    let pruned_ctr = client.worker.metrics.counter("scan.batches_pruned");
    let scanned_ctr = client.worker.metrics.counter("scan.rows_scanned");
    assert!(pruned_ctr > 0, "selective scans must prune batches");
    println!(
        "  pruning: selective p50 {:?} (off: {:?}), full p50 {:?} -> {speedup:.1}x; \
         counters pruned={pruned_ctr} rows_scanned={scanned_ctr}",
        m_sel.p50, m_sel_off.p50, m_full.p50
    );

    // ---- claim 2: cold vs warm block cache -------------------------------
    let cold_store = Arc::new(ObjectStore::with_latency(STORE_LATENCY).with_cache_budget(0));
    let cold = Client::open_sim_with_catalog(Catalog::new(cold_store)).unwrap();
    seed(&cold, "cached", CACHE_BATCHES);
    let warm_store = Arc::new(ObjectStore::with_latency(STORE_LATENCY));
    let warm = Client::open_sim_with_catalog(Catalog::new(warm_store)).unwrap();
    seed(&warm, "cached", CACHE_BATCHES);
    let span = (CACHE_BATCHES * SIM_N) as f32;

    let m_cold = b.run("scan, cold cache (2ms store latency, budget 0)", || {
        scan(&cold.worker, &cold.catalog, "cached", -1.0, span + 1.0);
    });
    let m_warm = b.run("scan, warm cache (2ms store latency)", || {
        scan(&warm.worker, &warm.catalog, "cached", -1.0, span + 1.0);
    });
    let cache = warm.catalog.store().cache_stats();
    let cache_speedup = m_cold.p50.as_secs_f64() / m_warm.p50.as_secs_f64();
    assert!(cache.hits > 0, "warm scans must hit the cache");
    println!(
        "  cache: cold p50 {:?} vs warm p50 {:?} ({cache_speedup:.1}x); \
         hits={} misses={} hit_rate={:.3}",
        m_cold.p50, m_warm.p50, cache.hits, cache.misses, cache.hit_rate()
    );

    // ---- claim 3: binary frame stream vs JSON over loopback --------------
    let wire_client = Client::open_sim().unwrap();
    seed(&wire_client, "wire", 32);
    let wire_bytes: u64 = {
        let head = wire_client.catalog.read_ref(MAIN).unwrap();
        let snap_id = head.tables.get("wire").unwrap().clone();
        let snap = wire_client.catalog.get_snapshot(&snap_id).unwrap();
        snap.objects
            .iter()
            .filter_map(|o| wire_client.catalog.store().object_size(o))
            .sum()
    };
    let handle = Server::start(
        wire_client,
        "127.0.0.1:0",
        ServerConfig { threads: 4, ..ServerConfig::default() },
    )
    .unwrap();
    let rc = RemoteClient::new(&handle.base_url());
    let m_bin = b.run("read table over the wire, binary frames", || {
        let t = rc.get_table_data(MAIN, "wire").unwrap();
        black_box(t.row_count());
    });
    let m_json = b.run("read table over the wire, JSON", || {
        let j = rc.get_table_data_json(MAIN, "wire").unwrap();
        black_box(j.get("batches").as_arr().map(|a| a.len()));
    });
    handle.shutdown();
    let mbps = |d: Duration| wire_bytes as f64 / 1e6 / d.as_secs_f64();
    let (bin_mbps, json_mbps) = (mbps(m_bin.p50), mbps(m_json.p50));
    println!(
        "  wire: {wire_bytes} payload bytes; binary {bin_mbps:.0} MB/s vs JSON \
         {json_mbps:.0} MB/s ({:.1}x)",
        bin_mbps / json_mbps
    );

    // ---- machine-readable artifact ---------------------------------------
    let ms = |d: Duration| (d.as_secs_f64() * 1e6).round() / 1e3;
    let out = std::env::var("BENCH_SCAN_OUT").unwrap_or_else(|_| "BENCH_scan.json".into());
    let doc = Json::obj(vec![
        ("bench", Json::str("E9_scan")),
        ("version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        (
            "table",
            Json::obj(vec![
                ("batches", Json::num(BATCHES as f64)),
                ("rows_per_batch", Json::num(SIM_N as f64)),
                ("rows", Json::num(rows_total)),
                ("columns", Json::num(COLS as f64)),
            ]),
        ),
        (
            "scan_ms",
            Json::obj(vec![
                ("selective_pruned", Json::num(ms(m_sel.p50))),
                ("selective_unpruned", Json::num(ms(m_sel_off.p50))),
                ("full", Json::num(ms(m_full.p50))),
            ]),
        ),
        (
            "speedup_selective_vs_full",
            Json::num((speedup * 100.0).round() / 100.0),
        ),
        (
            "cache",
            Json::obj(vec![
                ("store_latency_ms", Json::num(STORE_LATENCY.as_millis() as f64)),
                ("cold_ms", Json::num(ms(m_cold.p50))),
                ("warm_ms", Json::num(ms(m_warm.p50))),
                ("speedup", Json::num((cache_speedup * 100.0).round() / 100.0)),
                ("hit_rate", Json::num((cache.hit_rate() * 1000.0).round() / 1000.0)),
            ]),
        ),
        (
            "wire",
            Json::obj(vec![
                ("payload_bytes", Json::num(wire_bytes as f64)),
                ("binary_mb_per_s", Json::num(bin_mbps.round())),
                ("json_mb_per_s", Json::num(json_mbps.round())),
            ]),
        ),
        (
            "provenance",
            Json::obj(vec![
                ("source", Json::str("cargo bench --bench bench_scan")),
                ("os", Json::str(std::env::consts::OS)),
                ("arch", Json::str(std::env::consts::ARCH)),
            ]),
        ),
    ]);
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_scan.json");
    println!("  wrote {out}");

    // CI smoke: BENCH_SCAN_MIN_SPEEDUP turns the pushdown claim into a
    // hard assertion.
    if let Ok(min) = std::env::var("BENCH_SCAN_MIN_SPEEDUP") {
        let min: f64 = min.parse().expect("BENCH_SCAN_MIN_SPEEDUP must be a number");
        assert!(
            speedup >= min,
            "selective scan speedup is {speedup:.1}x, below the {min}x floor"
        );
        println!("  PASS selective-scan speedup {speedup:.1}x >= {min}x");
    }

    b.report();
}
