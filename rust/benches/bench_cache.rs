//! E10 — the run cache's incremental-compute win: a warm transactional
//! re-run publishes memoized nodes without executing them, and editing
//! one node re-executes only that node's downstream cone.
//!
//! Runs on the simulated compute backend (`Client::open_sim`), so this
//! bench works everywhere — no PJRT, no compiled artifacts — and CI
//! invokes it as a smoke test: the `assert!`s below pin the hit/miss
//! behaviour (cache hits for every untouched node, misses only for the
//! edited cone), not the timings.

use std::sync::Arc;

use bauplan::bench_util::{black_box, Bench};
use bauplan::cache::RunCache;
use bauplan::client::Client;
use bauplan::dag::PipelineSpec;
use bauplan::runs::{FailurePlan, RunMode};

fn main() {
    let mut b = Bench::heavy("E10_run_cache");
    b.header();
    b.max_iters = 30;

    let mut client = Client::open_sim().unwrap();
    client.seed_raw_table("main", 4, 1500).unwrap();
    let cache = Arc::new(RunCache::in_memory(256 << 20));
    client.attach_run_cache(cache.clone());
    // control: an uncached runner over the same catalog
    let cold_client = Client::open_sim_with_catalog(client.catalog.clone()).unwrap();

    let plan = cold_client
        .control_plane
        .plan_from_spec(&PipelineSpec::paper_pipeline())
        .unwrap();
    let none = FailurePlan::none();

    b.run("cold transactional run (3 nodes execute)", || {
        black_box(
            cold_client
                .run_plan(&plan, "main", RunMode::Transactional, &none, &[])
                .unwrap(),
        );
    });

    // prime, then measure the all-hit warm path
    let prime = client
        .run_plan(&plan, "main", RunMode::Transactional, &none, &[])
        .unwrap();
    assert!(prime.is_success());
    assert_eq!(prime.cache_misses, 3, "first cached run must execute everything");

    b.run("warm transactional run (3 cache hits, 0 executes)", || {
        let r = client
            .run_plan(&plan, "main", RunMode::Transactional, &none, &[])
            .unwrap();
        assert_eq!(r.cache_hits, 3, "warm run must hit every node");
        assert_eq!(r.cache_misses, 0);
        black_box(r);
    });

    // the headline scenario: edit ONE node, re-run the whole DAG — only
    // the edited node's downstream cone executes
    let mut spec = PipelineSpec::paper_pipeline();
    spec.nodes[1].params[2] = 0.75; // edit `child`'s scale
    let plan2 = client.control_plane.plan_from_spec(&spec).unwrap();

    let h0 = client.runner.metrics.counter("cache.hits");
    let m0 = client.runner.metrics.counter("cache.misses");
    let edited = client
        .run_plan(&plan2, "main", RunMode::Transactional, &none, &[])
        .unwrap();
    assert!(edited.is_success());
    assert_eq!(edited.cache_hits, 1, "parent (upstream of the edit) must hit");
    assert_eq!(edited.cache_misses, 2, "only child + grand_child may execute");
    assert_eq!(client.runner.metrics.counter("cache.hits") - h0, 1);
    assert_eq!(client.runner.metrics.counter("cache.misses") - m0, 2);
    println!(
        "\n  edited-node re-run: {} hit / {} executed — only the edited cone ran",
        edited.cache_hits, edited.cache_misses
    );

    b.run("warm re-run of the edited plan (cone now cached)", || {
        let r = client
            .run_plan(&plan2, "main", RunMode::Transactional, &none, &[])
            .unwrap();
        assert_eq!(r.cache_hits, 3);
        black_box(r);
    });

    let s = cache.stats();
    println!(
        "\n  cache: {} entries, {} bytes held, {} hits / {} misses, {} bytes saved, {} evictions",
        s.entries, s.total_bytes, s.hits, s.misses, s.bytes_saved, s.evictions
    );
    print!("{}", client.runner.metrics.render());
    b.report();
}
