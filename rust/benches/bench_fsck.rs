//! E10 — commit-path overhead of the online integrity auditor.
//!
//! The auditor (`doc/FSCK.md` §Online budget model) promises *bounded
//! interference*: its read-throttled background cycles must not tax the
//! write path. This bench drives the same durable commit workload twice —
//! with an auditor cycling far more aggressively than production (5 ms
//! idle between cycles vs the 5 s default, same 8 MiB/s read budget) and
//! with auditing disabled — and compares commit p50s.
//!
//! Besides the human-readable `BENCH` rows the run writes a
//! machine-readable **`BENCH_fsck.json`** (override the path with
//! `BENCH_FSCK_OUT`). `BENCH_FSCK_MAX_OVERHEAD` turns the claim into a
//! hard assertion: CI gates at `0.10` (10%).

use std::sync::Arc;
use std::time::Duration;

use bauplan::audit::online::{AuditConfig, AuditorHandle};
use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{Catalog, CommitRequest, JournalConfig, Snapshot, SyncPolicy, MAIN};
use bauplan::metrics::Metrics;
use bauplan::trace::FlightRecorder;
use bauplan::util::json::Json;

/// One real committed write: a content-addressed object in the store and
/// a journaled, fsynced catalog commit referencing it.
fn commit_one(cat: &Catalog, tag: &str) {
    let key = cat.store().put(format!("bench fsck payload {tag}").into_bytes());
    let snap = Snapshot::new(vec![key], "S", "fp", 1, "rw");
    cat.commit(CommitRequest::new(MAIN, &format!("t_{tag}"), snap)).unwrap();
}

/// p50 microseconds of a durable commit under `audit` (None = auditor
/// off). Each mode gets its own lake directory, pre-populated so the
/// auditor has real segments, snapshots, and objects to walk.
fn measure(b: &mut Bench, tag: &str, label: &str, audit: Option<AuditConfig>) -> f64 {
    let dir = std::env::temp_dir().join(format!(
        "bpl_bench_fsck_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let config = JournalConfig {
        sync: SyncPolicy::EveryAppend,
        segment_bytes: 256 * 1024,
        compact_after_deltas: u64::MAX,
        sync_latency_micros: 0,
    };
    let cat = Catalog::open_durable_cfg(&dir, config).unwrap();
    for i in 0..50 {
        commit_one(&cat, &format!("seed{i}"));
    }

    let auditor = audit.map(|cfg| {
        AuditorHandle::spawn(dir.clone(), cfg, Arc::new(Metrics::new()), FlightRecorder::new(64))
    });

    let mut i = 0u64;
    let m = b.run(label, || {
        i += 1;
        commit_one(&cat, &format!("{tag}{i}"));
        black_box(i);
    });

    if let Some(mut a) = auditor {
        assert!(a.shared().cycles() > 0, "auditor never cycled during the bench");
        a.stop();
    }
    drop(cat);
    let _ = std::fs::remove_dir_all(&dir);
    m.p50.as_secs_f64() * 1e6
}

fn main() {
    let mut b = Bench::heavy("E10_fsck");
    b.header();

    let off_p50 = measure(&mut b, "off", "durable commit, auditor disabled", None);
    let on_p50 = measure(
        &mut b,
        "on",
        "durable commit, auditor cycling every 5ms",
        Some(AuditConfig { interval: Duration::from_millis(5), ..AuditConfig::default() }),
    );
    let overhead = on_p50 / off_p50 - 1.0;
    println!(
        "  audit overhead: audited p50 {on_p50:.0}us vs disabled {off_p50:.0}us -> {:+.2}%",
        overhead * 100.0
    );

    // ---- machine-readable artifact ---------------------------------------
    let out = std::env::var("BENCH_FSCK_OUT").unwrap_or_else(|_| "BENCH_fsck.json".into());
    let doc = Json::obj(vec![
        ("bench", Json::str("E10_fsck")),
        ("version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        (
            "workload",
            Json::str("durable fsynced commits vs background auditor at 5ms cadence"),
        ),
        ("disabled_p50_us", Json::num(off_p50.round())),
        ("audited_p50_us", Json::num(on_p50.round())),
        ("overhead_fraction", Json::num((overhead * 10_000.0).round() / 10_000.0)),
        (
            "provenance",
            Json::obj(vec![
                ("source", Json::str("cargo bench --bench bench_fsck")),
                ("os", Json::str(std::env::consts::OS)),
                ("arch", Json::str(std::env::consts::ARCH)),
            ]),
        ),
    ]);
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_fsck.json");
    println!("  wrote {out}");

    // CI smoke: BENCH_FSCK_MAX_OVERHEAD turns the bounded-interference
    // claim into a hard assertion.
    if let Ok(max) = std::env::var("BENCH_FSCK_MAX_OVERHEAD") {
        let max: f64 = max.parse().expect("BENCH_FSCK_MAX_OVERHEAD must be a number");
        assert!(
            overhead <= max,
            "auditor overhead is {:.2}%, above the {:.2}% ceiling",
            overhead * 100.0,
            max * 100.0
        );
        println!(
            "  PASS auditor overhead {:.2}% <= {:.2}%",
            overhead * 100.0,
            max * 100.0
        );
    }

    b.report();
}
