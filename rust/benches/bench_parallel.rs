//! E11 — the wavefront scheduler's DAG-parallelism win: independent
//! nodes of a wide/diamond pipeline execute concurrently at `--jobs N`,
//! overlapping the object-store round trips that dominate real runs.
//!
//! Runs on the simulated compute backend with injected per-op
//! object-store latency (the E5 technique), so the measured speedup is
//! the scheduler overlapping I/O — deterministic enough for CI, which
//! invokes this bench as a smoke test. The `assert!`s pin:
//!
//! - jobs=4 beats jobs=1 by ≥ 2x on the 4-wide wavefront pipeline;
//! - jobs=4 beats jobs=1 on the diamond (wide middle + join);
//! - the published branch state (tables → snapshot ids) is byte-identical
//!   for jobs=1 vs jobs=4 on the same plan and pinned run id — commit
//!   order may vary, the state may not.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bauplan::bench_util::{black_box, diamond_pipeline, wide_pipeline, Bench};
use bauplan::catalog::Catalog;
use bauplan::client::Client;
use bauplan::dag::PipelineSpec;
use bauplan::runs::{FailurePlan, RunMode};
use bauplan::storage::ObjectStore;

/// Simulated object-store round-trip latency per op.
const LATENCY: Duration = Duration::from_millis(3);
/// Width of the independent wavefront.
const WIDTH: usize = 4;
/// Timed iterations per configuration.
const ITERS: usize = 5;

/// Fresh lakehouse on the sim backend over a latency-injected store.
fn fresh_client(jobs: usize) -> Client {
    let store = Arc::new(ObjectStore::with_latency(LATENCY));
    let client = Client::open_sim_with_catalog(Catalog::new(store)).unwrap();
    client.seed_raw_table("main", 4, 1500).unwrap();
    client.with_jobs(jobs)
}

/// Mean wall-clock of `ITERS` transactional runs of `spec`, each on a
/// fresh branch off the seeded main.
fn time_runs(client: &Client, spec: &PipelineSpec, tag: &str) -> Duration {
    // dag-level plan: M1/M2 checks; the diamond's multi-input join is a
    // scheduling shape, so it is planned below the control plane's
    // physical arity gate (op `child` reads its first input)
    let plan = spec.plan().unwrap();
    let mut total = Duration::ZERO;
    for i in 0..ITERS {
        let branch = format!("b_{tag}_{i}");
        client.create_branch(&branch, "main").unwrap();
        let t0 = Instant::now();
        let run = client
            .run_plan(&plan, &branch, RunMode::Transactional, &FailurePlan::none(), &[])
            .unwrap();
        total += t0.elapsed();
        assert!(run.is_success(), "{:?}", run.status);
        black_box(run);
    }
    total / ITERS as u32
}

fn main() {
    let mut b = Bench::heavy("E11_wavefront_scheduler");
    b.header();

    // ---- speedup: wide wavefront ------------------------------------
    let seq = fresh_client(1);
    let par = fresh_client(4);
    let wide_spec = wide_pipeline(WIDTH);
    let t_seq = time_runs(&seq, &wide_spec, "wide_j1");
    let t_par = time_runs(&par, &wide_spec, "wide_j4");
    let wide_speedup = t_seq.as_secs_f64() / t_par.as_secs_f64();
    println!(
        "  wide x{WIDTH}:    jobs=1 {t_seq:>10.2?}  jobs=4 {t_par:>10.2?}  speedup {wide_speedup:.2}x"
    );

    // ---- speedup: diamond (wide middle + join) ----------------------
    let dia_spec = diamond_pipeline(WIDTH);
    let t_seq_d = time_runs(&seq, &dia_spec, "dia_j1");
    let t_par_d = time_runs(&par, &dia_spec, "dia_j4");
    let dia_speedup = t_seq_d.as_secs_f64() / t_par_d.as_secs_f64();
    println!(
        "  diamond x{WIDTH}: jobs=1 {t_seq_d:>10.2?}  jobs=4 {t_par_d:>10.2?}  speedup {dia_speedup:.2}x"
    );

    // scheduler behaviour surfaced through metrics
    let h = par.runner.metrics.histogram("run.parallelism");
    println!(
        "  jobs=4 client: run.wavefronts={} run.parallelism p99<={}",
        par.runner.metrics.counter("run.wavefronts"),
        h.quantile_us(0.99),
    );

    // CI asserts: the wavefront must actually buy wall-clock
    assert!(
        wide_speedup >= 2.0,
        "jobs=4 must be ≥ 2x faster than jobs=1 on the {WIDTH}-wide \
         wavefront with {LATENCY:?} store latency (got {wide_speedup:.2}x)"
    );
    assert!(
        dia_speedup > 1.0,
        "jobs=4 must beat jobs=1 on the diamond (got {dia_speedup:.2}x)"
    );

    // ---- determinism: jobs=1 and jobs=4 publish identical states ----
    // Snapshot ids derive from (content, run id); pinning the run id
    // makes the two schedules comparable byte for byte.
    let catalog = {
        let store = Arc::new(ObjectStore::new()); // no latency needed here
        Catalog::new(store)
    };
    let c1 = Client::open_sim_with_catalog(catalog.clone()).unwrap().with_jobs(1);
    let c4 = Client::open_sim_with_catalog(catalog).unwrap().with_jobs(4);
    c1.seed_raw_table("main", 4, 1500).unwrap();
    c1.create_branch("det1", "main").unwrap();
    c1.create_branch("det4", "main").unwrap();
    let plan = diamond_pipeline(WIDTH).plan().unwrap();
    // same pinned run id for both schedules (the first run's txn branch
    // is merged + deleted before the second starts, so the name is free)
    let r1 = c1
        .runner
        .run_with_id(&plan, "det1", RunMode::Transactional, &FailurePlan::none(), &[], "run_det")
        .unwrap();
    let r4 = c4
        .runner
        .run_with_id(&plan, "det4", RunMode::Transactional, &FailurePlan::none(), &[], "run_det")
        .unwrap();
    assert!(r1.is_success() && r4.is_success());
    // byte-identical published state: tables → snapshot ids
    let s1 = c1.catalog.read_ref("det1").unwrap();
    let s4 = c4.catalog.read_ref("det4").unwrap();
    assert_eq!(
        s1.tables, s4.tables,
        "jobs=1 and jobs=4 must publish byte-identical branch states"
    );
    println!("  determinism: jobs=1 and jobs=4 published byte-identical states");

    let dia_plan = dia_spec.plan().unwrap();
    let mut i1 = 0usize;
    b.run("diamond x4, jobs=1 (sequential baseline)", || {
        i1 += 1;
        let branch = format!("m1_{i1}");
        seq.create_branch(&branch, "main").unwrap();
        black_box(
            seq.run_plan(&dia_plan, &branch, RunMode::Transactional, &FailurePlan::none(), &[])
                .unwrap(),
        );
    });
    let mut i4 = 0usize;
    b.run("diamond x4, jobs=4 (wavefront)", || {
        i4 += 1;
        let branch = format!("m4_{i4}");
        par.create_branch(&branch, "main").unwrap();
        black_box(
            par.run_plan(&dia_plan, &branch, RunMode::Transactional, &FailurePlan::none(), &[])
                .unwrap(),
        );
    });
    b.report();
}
