//! E6 — fail-fast moments (paper §3.1).
//!
//! "We should never fail at a later moment if we could have failed at a
//! previous one." We inject a corpus of schema bugs (type shifts, dropped
//! columns, unmarked narrowings, nullability violations, data-level
//! poison) and report at which moment each class is caught — plus the
//! cost of checking, which is what makes fail-fast free at plan time.

use bauplan::bench_util::{black_box, Bench};
use bauplan::client::Client;
use bauplan::contracts::checker::{check_local, check_plan};
use bauplan::contracts::schema::SchemaRegistry;
use bauplan::dag::parser::{parse_pipeline, PAPER_PIPELINE_TEXT};
use bauplan::testing::Rng;

struct InjectedBug {
    name: &'static str,
    mutate: fn(&str) -> String,
    expected_moment: u8,
}

const BUGS: &[InjectedBug] = &[
    InjectedBug {
        name: "unmarked float->int narrowing",
        mutate: |t| {
            t.replace("col4: int from ChildSchema.col4 cast", "col4: int from ChildSchema.col4")
        },
        expected_moment: 1,
    },
    InjectedBug {
        name: "incompatible inherited type (str->timestamp)",
        mutate: |t| {
            t.replace("col2: timestamp from ParentSchema.col2", "col2: str from ParentSchema.col2")
        },
        expected_moment: 1,
    },
    InjectedBug {
        name: "node output schema swapped",
        mutate: |t| t.replace("node parent_table: ParentSchema <-", "node parent_table: Grand <-"),
        expected_moment: 2,
    },
    InjectedBug {
        // dropping the column is visible from declarations alone: the
        // downstream schema inherits ParentSchema.col2, so M1 catches it
        // — one moment EARLIER than a system that only checks wiring.
        name: "upstream column dropped",
        mutate: |t| t.replace("  col2: timestamp from RawSchema.col2\n  _S: float", "  _S: float"),
        expected_moment: 1,
    },
    InjectedBug {
        // schemas all locally fine; only the DAG wiring is wrong — the
        // earliest possible detection is the control plane (M2).
        name: "node input annotation mismatched",
        mutate: |t| {
            t.replace(
                "child_table: ChildSchema <- parent_table(ParentSchema)",
                "child_table: ChildSchema <- parent_table(Grand)",
            )
        },
        expected_moment: 2,
    },
];

fn main() {
    println!("\n=== bench: E6 fail-fast moments ===\n");
    let client = Client::open("artifacts").unwrap();
    client.seed_raw_table("main", 1, 800).unwrap();

    println!("{:<44} {:>8} {:>10}", "injected bug class", "moment", "expected");
    let mut all_ok = true;
    for bug in BUGS {
        let text = (bug.mutate)(PAPER_PIPELINE_TEXT);
        assert_ne!(text, PAPER_PIPELINE_TEXT, "mutation was a no-op: {}", bug.name);
        let moment = match client.run_text(&text, "main") {
            Err(e) => e.contract_moment().unwrap_or(0),
            Ok(_) => 0,
        };
        let ok = moment == bug.expected_moment;
        all_ok &= ok;
        println!(
            "{:<44} {:>8} {:>10} {}",
            bug.name,
            moment,
            bug.expected_moment,
            if ok { "PASS" } else { "FAIL" }
        );
        println!(
            "BENCH E6_moments | {} | moment={moment} expected={}",
            bug.name,
            bug.expected_moment
        );
    }

    // data-level poison: only detectable at M3 (worker, physical data)
    {
        let mut rng = Rng::new(5);
        let batches = vec![bauplan::data::poisoned_batch(&mut rng, 600, 4, 0)];
        let moment = match client.seed_table("main", "raw_poisoned", "RawSchema", batches) {
            Err(e) => e.contract_moment().unwrap_or(0),
            Ok(_) => 0,
        };
        let ok = moment == 3;
        all_ok &= ok;
        println!(
            "{:<44} {:>8} {:>10} {}",
            "NaN poison in physical data",
            moment,
            3,
            if ok { "PASS" } else { "FAIL" }
        );
        println!("BENCH E6_moments | nan_poison | moment={moment} expected=3");
    }
    assert!(all_ok, "some bug class was caught at the wrong moment");

    // cost of the checks (why fail-fast is free)
    let mut b = Bench::new("E6_check_cost");
    b.header();
    let registry = SchemaRegistry::with_paper_schemas();
    b.run("M1 check_local x5 schemas", || {
        for name in ["RawSchema", "ParentSchema", "ChildSchema", "Grand", "FriendSchema"] {
            black_box(check_local(registry.get(name).unwrap(), &registry).unwrap());
        }
    });
    b.run("M2 check_plan (one boundary)", || {
        black_box(check_plan(
            registry.get("ParentSchema").unwrap(),
            registry.get("ChildSchema").unwrap(),
        )
        .unwrap());
    });
    b.run("parse + full plan (M1+M2) of paper pipeline", || {
        black_box(parse_pipeline(PAPER_PIPELINE_TEXT).unwrap().plan().unwrap());
    });
    b.report();
}
