//! E10 — per-branch OCC commits under the redesigned commit API
//! (doc/CONCURRENCY.md).
//!
//! The seed's commit path held one catalog-wide lock across
//! read-validate-write, so two tenants committing to *different*
//! branches still serialized. The OCC redesign prepares every commit
//! outside the locks, validates under a short per-branch critical
//! section, and awaits durability outside the locks — so disjoint-branch
//! commits overlap and share one group-commit fsync batch. Rows:
//!
//! - commit latency through [`Catalog::commit`] on an in-memory lake
//!   (the pure API overhead, no durability);
//! - **claim 1** (disjoint writers scale): aggregate commits/sec at 1
//!   and 8 writers, one branch per writer, group commit on a simulated
//!   disk with a stable 2 ms sync cost
//!   (`JournalConfig::sync_latency_micros`) — overlapping commits must
//!   share fsync batches, so 8 writers beat 1 by ~the batch width;
//! - **claim 2** (informed rebase converges): 8 writers racing *one*
//!   branch under `RetryPolicy::rebase()` — every commit lands, and the
//!   validation failure hands back the live head, so rebase rounds stay
//!   near one per conflict instead of spinning.
//!
//! Besides the `BENCH` rows the run writes a machine-readable
//! **`BENCH_occ.json`** (override the path with `BENCH_OCC_OUT`).
//! `BENCH_OCC_MIN_SPEEDUP` turns claim 1 into a hard assertion: the
//! documented local target is `4.0`; CI gates at `2.0` because shared
//! runners add scheduler noise to the 8-writer timing (see
//! `.github/workflows/ci.yml`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{
    Catalog, CommitRequest, JournalConfig, RetryPolicy, Snapshot, SyncPolicy, MAIN,
};
use bauplan::storage::ObjectStore;
use bauplan::util::json::Json;

static DIR_N: AtomicU64 = AtomicU64::new(0);

fn scratch(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "bpl_bench_occ_{name}_{}_{}",
        std::process::id(),
        DIR_N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn snap(i: u64) -> Snapshot {
    Snapshot::new(vec![format!("obj_{i}")], "S", "fp", 1, "bench")
}

/// The simulated disk: every data fsync costs this long, so commit
/// latency is dominated by sync cost (like a real disk) and the
/// overlap shows up on any hardware.
const SYNC_LATENCY_MICROS: u64 = 2_000;

fn durable(name: &str) -> (std::path::PathBuf, Catalog) {
    let dir = scratch(name);
    let config = JournalConfig {
        sync: SyncPolicy::GroupCommit,
        sync_latency_micros: SYNC_LATENCY_MICROS,
        ..JournalConfig::default()
    };
    let c = Catalog::open_durable_cfg(&dir, config).unwrap();
    (dir, c)
}

/// Aggregate commits/sec with `writers` committers, **one branch per
/// writer** — the disjoint multi-tenant shape OCC is for.
fn measure_disjoint(writers: u64, per_writer: u64) -> f64 {
    let (dir, c) = durable("disjoint");
    // warm the lake and pre-create the tenant branches outside the window
    let warm = CommitRequest::new(MAIN, "warm", snap(0)).author("bench").message("warmup");
    c.commit(warm).unwrap();
    for w in 0..writers {
        c.create_branch(&format!("w{w}"), MAIN, false).unwrap();
    }

    let start = Instant::now();
    let mut handles = vec![];
    for w in 0..writers {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let branch = format!("w{w}");
            for i in 0..per_writer {
                let req = CommitRequest::new(&branch, "t", snap(1_000_000 + w * 100_000 + i))
                    .author("bench")
                    .message("occ");
                c.commit(req).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    drop(c);
    let _ = std::fs::remove_dir_all(&dir);
    (writers * per_writer) as f64 / secs
}

/// Commits/sec and total rebase rounds with `writers` committers all
/// racing `main` under the informed-rebase policy.
fn measure_contended(writers: u64, per_writer: u64) -> (f64, u64) {
    let (dir, c) = durable("contended");
    let warm = CommitRequest::new(MAIN, "warm", snap(0)).author("bench").message("warmup");
    c.commit(warm).unwrap();

    let rounds = Arc::new(AtomicU64::new(0));
    let start = Instant::now();
    let mut handles = vec![];
    for w in 0..writers {
        let c = c.clone();
        let rounds = rounds.clone();
        handles.push(std::thread::spawn(move || {
            let table = format!("w{w}");
            for i in 0..per_writer {
                let req = CommitRequest::new(MAIN, &table, snap(2_000_000 + w * 100_000 + i))
                    .author("bench")
                    .message("occ contended")
                    .retry(RetryPolicy::rebase());
                let out = c.commit(req).unwrap();
                rounds.fetch_add(out.retries, Ordering::Relaxed);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    let expected = writers * per_writer + 1; // + init commit + warmup
    let history = c.log(MAIN, usize::MAX).unwrap().len() as u64;
    assert_eq!(history, expected + 1, "every contended commit must land exactly once");
    drop(c);
    let _ = std::fs::remove_dir_all(&dir);
    ((writers * per_writer) as f64 / secs, rounds.load(Ordering::Relaxed))
}

fn main() {
    let mut b = Bench::new("E10_occ");
    b.header();

    // ---- API overhead: the OCC loop on an in-memory lake -----------------
    {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        let mut i = 0u64;
        b.run("Catalog::commit, in-memory (no durability)", || {
            i += 1;
            let req = CommitRequest::new(MAIN, "hot", snap(i)).author("bench").message("m");
            black_box(c.commit(req).unwrap());
        });
    }

    // ---- claim 1: disjoint writers scale ---------------------------------
    const PER_WRITER: u64 = 40;
    let disjoint_1w = measure_disjoint(1, PER_WRITER * 2);
    let disjoint_8w = measure_disjoint(8, PER_WRITER);
    let speedup_8w = disjoint_8w / disjoint_1w;
    println!(
        "  disjoint branches (sync_latency={SYNC_LATENCY_MICROS}us, group commit): \
         1 writer {disjoint_1w:.0}/s, 8 writers {disjoint_8w:.0}/s ({speedup_8w:.2}x)"
    );

    // ---- claim 2: informed rebase on one contended branch ----------------
    let (contended_8w, rebase_rounds) = measure_contended(8, PER_WRITER);
    println!(
        "  contended main: 8 writers {contended_8w:.0}/s, \
         {rebase_rounds} rebase rounds over {} commits",
        8 * PER_WRITER
    );

    // ---- machine-readable artifact ---------------------------------------
    let out = std::env::var("BENCH_OCC_OUT").unwrap_or_else(|_| "BENCH_occ.json".into());
    let doc = Json::obj(vec![
        ("bench", Json::str("E10_occ")),
        ("version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("sync_latency_micros", Json::num(SYNC_LATENCY_MICROS as f64)),
        (
            "commits_per_sec",
            Json::obj(vec![
                (
                    "disjoint_branches",
                    Json::obj(vec![
                        ("writers_1", Json::num(disjoint_1w.round())),
                        ("writers_8", Json::num(disjoint_8w.round())),
                    ]),
                ),
                (
                    "contended_main",
                    Json::obj(vec![("writers_8", Json::num(contended_8w.round()))]),
                ),
            ]),
        ),
        ("speedup_8w_vs_1w", Json::num((speedup_8w * 100.0).round() / 100.0)),
        ("contended_rebase_rounds", Json::num(rebase_rounds as f64)),
        (
            "provenance",
            Json::obj(vec![
                ("source", Json::str("cargo bench --bench bench_occ")),
                ("os", Json::str(std::env::consts::OS)),
                ("arch", Json::str(std::env::consts::ARCH)),
            ]),
        ),
    ]);
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_occ.json");
    println!("  wrote {out}");

    // CI smoke: BENCH_OCC_MIN_SPEEDUP turns the disjoint-writers claim
    // into a hard assertion.
    if let Ok(min) = std::env::var("BENCH_OCC_MIN_SPEEDUP") {
        let min: f64 = min.parse().expect("BENCH_OCC_MIN_SPEEDUP must be a number");
        assert!(
            speedup_8w >= min,
            "disjoint-writer speedup at 8 writers is {speedup_8w:.2}x, below the {min}x floor"
        );
        println!("  PASS disjoint-writer speedup {speedup_8w:.2}x >= {min}x");
    }

    b.report();
}
