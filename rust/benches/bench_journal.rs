//! E7 — durability costs of the segmented commit journal.
//!
//! The seed's only durability story was `save(dir)`: a full canonical
//! export, O(total history) per call. The segmented journal appends one
//! O(tables) record per mutation instead, group commit amortizes the
//! fsync across concurrent committers, and delta checkpoints keep
//! recovery tail-bounded. Rows:
//!
//! - commit latency: in-memory / fsync-per-append / group commit /
//!   batched fsync / full-export-per-commit;
//! - **claim 1** (group commit): commits/sec at 1 and 8 writers, per-commit
//!   fsync vs group commit, on a simulated disk with a stable 2 ms sync
//!   cost (`JournalConfig::sync_latency_micros`) so the amortization is
//!   measurable deterministically on any machine;
//! - **claim 2** (tail-bounded recovery): recovery ms vs history length
//!   (1k / 4k / 10k commits, each with a fresh delta checkpoint) — the
//!   curve must stay flat;
//! - concurrent `commit_table_cas` writers racing on one branch, with a
//!   PASS line checking every write survived recovery.
//!
//! Besides the human-readable `BENCH` rows the run writes a
//! machine-readable **`BENCH_journal.json`** (override the path with
//! `BENCH_JOURNAL_OUT`). `BENCH_JOURNAL_MIN_SPEEDUP` turns claim 1
//! into a hard assertion: the documented local target is `3.0`; CI
//! gates at `2.0` because shared runners add scheduler noise to the
//! 8-writer timing (see `.github/workflows/ci.yml`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{Catalog, JournalConfig, Snapshot, SyncPolicy, MAIN};
use bauplan::error::BauplanError;
use bauplan::storage::ObjectStore;
use bauplan::testing::{commit_table, commit_table_cas};
use bauplan::util::json::Json;

static DIR_N: AtomicU64 = AtomicU64::new(0);

fn scratch(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "bpl_bench_journal_{name}_{}_{}",
        std::process::id(),
        DIR_N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn snap(i: u64) -> Snapshot {
    Snapshot::new(vec![format!("obj_{i}")], "S", "fp", 1, "bench")
}

/// Seed `n` tables so commit records and exports have realistic width.
fn seed_tables(c: &Catalog, n: usize) {
    for i in 0..n {
        commit_table(c, MAIN, &format!("t{i}"), snap(i as u64), "u", "seed", None)
            .unwrap();
    }
}

/// The simulated disk for the group-commit rows: every data fsync costs
/// this long, so the per-commit-fsync baseline is dominated by sync cost
/// (like a real disk) and the amortization shows up on any hardware.
const SYNC_LATENCY_MICROS: u64 = 2_000;

/// Commits/sec with `writers` concurrent committers, each appending
/// `per_writer` commits to its own table on `main`.
fn measure_throughput(sync: SyncPolicy, writers: u64, per_writer: u64) -> f64 {
    let dir = scratch("tput");
    let config = JournalConfig {
        sync,
        sync_latency_micros: SYNC_LATENCY_MICROS,
        ..JournalConfig::default()
    };
    let c = Catalog::open_durable_cfg(&dir, config).unwrap();
    // warm the lake (first segment, branch bookkeeping) outside the window
    commit_table(&c, MAIN, "warm", snap(0), "u", "warmup", None).unwrap();

    let start = Instant::now();
    let mut handles = vec![];
    for w in 0..writers {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_writer {
                commit_table(
                    &c,
                    MAIN,
                    &format!("w{w}"),
                    snap(7_000_000 + w * 100_000 + i),
                    "u",
                    "tput",
                    None,
                )
                .unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    drop(c);
    let _ = std::fs::remove_dir_all(&dir);
    (writers * per_writer) as f64 / secs
}

/// Build a lake with `history` commits, a fresh delta checkpoint and a
/// 3-commit tail, then time `Catalog::open_durable_cfg` (= recovery).
/// Returns (recovery_ms, bytes_scanned, records_replayed, journal_bytes).
fn measure_recovery(history: u64) -> (f64, u64, u64, u64) {
    let dir = scratch("sweep");
    let config = JournalConfig {
        sync: SyncPolicy::Batch(1024),
        segment_bytes: 64 * 1024,
        compact_after_deltas: u64::MAX, // exercise the delta path, not compaction
        sync_latency_micros: 0,
    };
    let journal_bytes;
    {
        let c = Catalog::open_durable_cfg(&dir, config).unwrap();
        for i in 0..history {
            commit_table(&c, MAIN, "t", snap(8_000_000 + i), "u", "hist", None).unwrap();
        }
        c.checkpoint().unwrap();
        for i in 0..3u64 {
            commit_table(&c, MAIN, "tail", snap(9_000_000 + i), "u", "tail", None).unwrap();
        }
        c.journal_sync().unwrap();
        journal_bytes = c.journal_stats().unwrap().bytes_written;
    }
    let start = Instant::now();
    let c = Catalog::open_durable_cfg(&dir, config).unwrap();
    let ms = start.elapsed().as_secs_f64() * 1e3;
    let stats = c.recovery_stats().unwrap();
    black_box(c.resolve(MAIN).unwrap());
    drop(c);
    let _ = std::fs::remove_dir_all(&dir);
    (ms, stats.bytes_scanned, stats.records_replayed, journal_bytes)
}

fn main() {
    let mut b = Bench::new("E7_journal");
    b.header();

    const LAKE_TABLES: usize = 64;

    // ---- commit latency across durability modes --------------------------
    {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table, in-memory (baseline)", || {
            i += 1;
            black_box(commit_table(&c, MAIN, "hot", snap(1_000_000 + i), "u", "m", None).unwrap());
        });
    }
    for (label, policy) in [
        ("commit_table, journal fsync-per-append", SyncPolicy::EveryAppend),
        ("commit_table, journal group commit", SyncPolicy::GroupCommit),
        ("commit_table, journal batched fsync(64)", SyncPolicy::Batch(64)),
    ] {
        let dir = scratch("latency");
        let c = Catalog::open_durable(&dir, policy).unwrap();
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run(label, || {
            i += 1;
            black_box(commit_table(&c, MAIN, "hot", snap(2_000_000 + i), "u", "m", None).unwrap());
        });
        c.journal_sync().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
    {
        // the pre-journal durability story: full export after every commit
        let dir = scratch("export");
        std::fs::create_dir_all(&dir).unwrap();
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table + full save() (seed durability)", || {
            i += 1;
            commit_table(&c, MAIN, "hot", snap(4_000_000 + i), "u", "m", None).unwrap();
            c.save(&dir).unwrap();
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- claim 1: group commit vs per-commit fsync, 1 and 8 writers ------
    const PER_WRITER: u64 = 40;
    let every_1w = measure_throughput(SyncPolicy::EveryAppend, 1, PER_WRITER * 2);
    let every_8w = measure_throughput(SyncPolicy::EveryAppend, 8, PER_WRITER);
    let group_1w = measure_throughput(SyncPolicy::GroupCommit, 1, PER_WRITER * 2);
    let group_8w = measure_throughput(SyncPolicy::GroupCommit, 8, PER_WRITER);
    let speedup_8w = group_8w / every_8w;
    println!(
        "  throughput (sync_latency={SYNC_LATENCY_MICROS}us): \
         fsync-per-commit 1w={every_1w:.0}/s 8w={every_8w:.0}/s | \
         group-commit 1w={group_1w:.0}/s 8w={group_8w:.0}/s | speedup_8w={speedup_8w:.2}x"
    );

    // ---- claim 2: recovery ms vs history length --------------------------
    let mut recovery_rows = Vec::new();
    for history in [1_000u64, 4_000, 10_000] {
        let (ms, bytes_scanned, replayed, journal_bytes) = measure_recovery(history);
        println!(
            "  recovery: history={history} -> {ms:.2} ms \
             (scanned {bytes_scanned} of {journal_bytes} journal bytes, replayed {replayed})"
        );
        recovery_rows.push(Json::obj(vec![
            ("history_commits", Json::num(history as f64)),
            ("recovery_ms", Json::num((ms * 1000.0).round() / 1000.0)),
            ("journal_bytes", Json::num(journal_bytes as f64)),
            ("bytes_scanned", Json::num(bytes_scanned as f64)),
            ("records_replayed", Json::num(replayed as f64)),
        ]));
    }

    // ---- concurrent CAS writers -----------------------------------------
    for (label, policy) in [
        ("4 CAS writers x 16, journal fsync-per-append", SyncPolicy::EveryAppend),
        ("4 CAS writers x 16, journal group commit", SyncPolicy::GroupCommit),
    ] {
        let dir = scratch("cas");
        let c = Catalog::open_durable(&dir, policy).unwrap();
        seed_tables(&c, 8);
        let written = Arc::new(AtomicU64::new(0));
        let mut hb = Bench::heavy("E7_journal_cas");
        hb.run(label, || {
            let mut handles = vec![];
            for t in 0..4u64 {
                let c = c.clone();
                let written = written.clone();
                handles.push(std::thread::spawn(move || {
                    for k in 0..16u64 {
                        // optimistic retry loop: read head, CAS, retry on conflict
                        loop {
                            let head = c.resolve(MAIN).unwrap();
                            let n = written.load(Ordering::Relaxed);
                            match commit_table_cas(
                                &c,
                                MAIN,
                                &head,
                                &format!("w{t}"),
                                snap(6_000_000 + t * 1_000 + k * 17 + n),
                                "u",
                                "cas",
                                None,
                            ) {
                                Ok(_) => {
                                    written.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                Err(BauplanError::CasConflict { .. }) => continue,
                                Err(e) => panic!("unexpected: {e}"),
                            }
                        }
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        c.journal_sync().unwrap();
        let total = written.load(Ordering::Relaxed);
        let pre = c.export().to_string();
        drop(c);
        let r = Catalog::recover(&dir).unwrap();
        assert_eq!(r.export().to_string(), pre, "every CAS write recovered");
        println!("  PASS: {total} CAS commits, recovery byte-identical");
        for m in hb.results {
            b.results.push(m);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- machine-readable artifact ---------------------------------------
    let out = std::env::var("BENCH_JOURNAL_OUT").unwrap_or_else(|_| "BENCH_journal.json".into());
    let doc = Json::obj(vec![
        ("bench", Json::str("E7_journal")),
        ("version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("sync_latency_micros", Json::num(SYNC_LATENCY_MICROS as f64)),
        (
            "commits_per_sec",
            Json::obj(vec![
                (
                    "fsync_per_commit",
                    Json::obj(vec![
                        ("writers_1", Json::num(every_1w.round())),
                        ("writers_8", Json::num(every_8w.round())),
                    ]),
                ),
                (
                    "group_commit",
                    Json::obj(vec![
                        ("writers_1", Json::num(group_1w.round())),
                        ("writers_8", Json::num(group_8w.round())),
                    ]),
                ),
            ]),
        ),
        (
            "speedup_8w_group_vs_fsync",
            Json::num((speedup_8w * 100.0).round() / 100.0),
        ),
        ("recovery_vs_history", Json::Arr(recovery_rows)),
        (
            "provenance",
            Json::obj(vec![
                ("source", Json::str("cargo bench --bench bench_journal")),
                ("os", Json::str(std::env::consts::OS)),
                ("arch", Json::str(std::env::consts::ARCH)),
            ]),
        ),
    ]);
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_journal.json");
    println!("  wrote {out}");

    // CI smoke: BENCH_JOURNAL_MIN_SPEEDUP turns the amortization claim
    // into a hard assertion.
    if let Ok(min) = std::env::var("BENCH_JOURNAL_MIN_SPEEDUP") {
        let min: f64 = min.parse().expect("BENCH_JOURNAL_MIN_SPEEDUP must be a number");
        assert!(
            speedup_8w >= min,
            "group commit speedup at 8 writers is {speedup_8w:.2}x, below the {min}x floor"
        );
        println!("  PASS group-commit speedup {speedup_8w:.2}x >= {min}x");
    }

    b.report();
}
