//! E7 — durability costs (the commit journal vs whole-state export).
//!
//! The seed's only durability story was `save(dir)`: a full canonical
//! export, O(total history) per call. The commit journal appends one
//! O(tables) record per mutation instead. Rows:
//!
//! - commit latency: in-memory / journaled (fsync-per-append) /
//!   journaled (batched fsync) / full-export-per-commit;
//! - recovery latency: `Catalog::recover` over a journal tail vs a
//!   checkpoint;
//! - concurrent `commit_table_cas` writers racing on one branch, with a
//!   PASS line checking every write survived recovery.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use bauplan::bench_util::{black_box, Bench};
use bauplan::catalog::{Catalog, Snapshot, SyncPolicy, MAIN};
use bauplan::error::BauplanError;
use bauplan::storage::ObjectStore;

static DIR_N: AtomicU64 = AtomicU64::new(0);

fn scratch(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "bpl_bench_journal_{name}_{}_{}",
        std::process::id(),
        DIR_N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn snap(i: u64) -> Snapshot {
    Snapshot::new(vec![format!("obj_{i}")], "S", "fp", 1, "bench")
}

/// Seed `n` tables so commit records and exports have realistic width.
fn seed_tables(c: &Catalog, n: usize) {
    for i in 0..n {
        c.commit_table(MAIN, &format!("t{i}"), snap(i as u64), "u", "seed", None)
            .unwrap();
    }
}

fn main() {
    let mut b = Bench::new("E7_journal");
    b.header();

    const LAKE_TABLES: usize = 64;

    // ---- commit latency across durability modes --------------------------
    {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table, in-memory (baseline)", || {
            i += 1;
            black_box(c.commit_table(MAIN, "hot", snap(1_000_000 + i), "u", "m", None).unwrap());
        });
    }
    {
        let dir = scratch("every");
        let c = Catalog::recover(&dir).unwrap();
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table, journal fsync-per-append", || {
            i += 1;
            black_box(c.commit_table(MAIN, "hot", snap(2_000_000 + i), "u", "m", None).unwrap());
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
    {
        let dir = scratch("batch");
        let c = Catalog::open_durable(&dir, SyncPolicy::Batch(64)).unwrap();
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table, journal batched fsync(64)", || {
            i += 1;
            black_box(c.commit_table(MAIN, "hot", snap(3_000_000 + i), "u", "m", None).unwrap());
        });
        c.journal_sync().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
    {
        // the pre-journal durability story: full export after every commit
        let dir = scratch("export");
        std::fs::create_dir_all(&dir).unwrap();
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        seed_tables(&c, LAKE_TABLES);
        let mut i = 0u64;
        b.run("commit_table + full save() (seed durability)", || {
            i += 1;
            c.commit_table(MAIN, "hot", snap(4_000_000 + i), "u", "m", None).unwrap();
            c.save(&dir).unwrap();
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- recovery latency ------------------------------------------------
    {
        let dir = scratch("recover_tail");
        {
            let c = Catalog::recover(&dir).unwrap();
            seed_tables(&c, LAKE_TABLES);
            for i in 0..256u64 {
                c.commit_table(MAIN, "hot", snap(5_000_000 + i), "u", "m", None).unwrap();
            }
        }
        let mut hb = Bench::heavy("E7_journal_recovery");
        hb.run("recover: 320-record journal, no checkpoint", || {
            black_box(Catalog::recover(&dir).unwrap());
        });
        {
            let c = Catalog::recover(&dir).unwrap();
            c.checkpoint().unwrap();
        }
        hb.run("recover: checkpoint + empty tail", || {
            black_box(Catalog::recover(&dir).unwrap());
        });
        for m in hb.results {
            b.results.push(m);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- concurrent CAS writers -----------------------------------------
    for (label, policy) in [
        ("4 CAS writers x 16, journal fsync-per-append", SyncPolicy::EveryAppend),
        ("4 CAS writers x 16, journal batched fsync(64)", SyncPolicy::Batch(64)),
    ] {
        let dir = scratch("cas");
        let c = Catalog::open_durable(&dir, policy).unwrap();
        seed_tables(&c, 8);
        let written = Arc::new(AtomicU64::new(0));
        let mut hb = Bench::heavy("E7_journal_cas");
        hb.run(label, || {
            let mut handles = vec![];
            for t in 0..4u64 {
                let c = c.clone();
                let written = written.clone();
                handles.push(std::thread::spawn(move || {
                    for k in 0..16u64 {
                        // optimistic retry loop: read head, CAS, retry on conflict
                        loop {
                            let head = c.resolve(MAIN).unwrap();
                            let n = written.load(Ordering::Relaxed);
                            match c.commit_table_cas(
                                MAIN,
                                &head,
                                &format!("w{t}"),
                                snap(6_000_000 + t * 1_000 + k * 17 + n),
                                "u",
                                "cas",
                                None,
                            ) {
                                Ok(_) => {
                                    written.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                Err(BauplanError::CasConflict { .. }) => continue,
                                Err(e) => panic!("unexpected: {e}"),
                            }
                        }
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        c.journal_sync().unwrap();
        let total = written.load(Ordering::Relaxed);
        let pre = c.export().to_string();
        drop(c);
        let r = Catalog::recover(&dir).unwrap();
        assert_eq!(r.export().to_string(), pre, "every CAS write recovered");
        println!("  PASS: {total} CAS commits, recovery byte-identical");
        for m in hb.results {
            b.results.push(m);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    b.report();
}
