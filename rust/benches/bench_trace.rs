//! E8 — span-recording overhead of the tracing subsystem.
//!
//! Tracing is on by default, so its cost rides every run. This bench
//! runs the same transactional workload (an 8-wide wavefront on the sim
//! compute backend, in-memory catalog so journal fsyncs don't drown the
//! signal) twice: with the default [`TraceConfig`] and with
//! [`TraceConfig::disabled`], and compares run p50s. The claim
//! (`doc/OBSERVABILITY.md`): span recording is a few allocations and a
//! mutex push per span — well under 5% of a run that computes and
//! commits 8 tables.
//!
//! Besides the human-readable `BENCH` rows the run writes a
//! machine-readable **`BENCH_trace.json`** (override the path with
//! `BENCH_TRACE_OUT`). `BENCH_TRACE_MAX_OVERHEAD` turns the claim into
//! a hard assertion: CI gates at `0.05` (5%).

use bauplan::bench_util::{black_box, wide_pipeline, Bench};
use bauplan::catalog::MAIN;
use bauplan::client::Client;
use bauplan::runs::{FailurePlan, RunMode, RunStatus};
use bauplan::trace::TraceConfig;
use bauplan::util::json::Json;

const WIDTH: usize = 8;

/// p50 microseconds of a transactional wavefront run under `config`.
/// `tag` keeps run ids (and the snapshot ids derived from them) unique
/// across the two modes.
fn measure(b: &mut Bench, tag: &str, label: &str, config: TraceConfig) -> f64 {
    let client = Client::open_sim().unwrap();
    client.seed_raw_table(MAIN, 2, 400).unwrap();
    let plan = wide_pipeline(WIDTH).plan().unwrap();
    let runner = client.runner.clone().with_trace_config(config);
    let mut i = 0u64;
    let m = b.run(label, || {
        i += 1;
        let state = runner
            .run_with_id(
                &plan,
                MAIN,
                RunMode::Transactional,
                &FailurePlan::none(),
                &[],
                &format!("bench_trace_{tag}_{i}"),
            )
            .unwrap();
        assert!(matches!(state.status, RunStatus::Success), "{:?}", state.status);
        black_box(state);
    });
    m.p50.as_secs_f64() * 1e6
}

fn main() {
    let mut b = Bench::heavy("E8_trace");
    b.header();

    let disabled_p50 =
        measure(&mut b, "off", "transactional run, tracing disabled", TraceConfig::disabled());
    let traced_p50 =
        measure(&mut b, "on", "transactional run, traced (default)", TraceConfig::default());
    let overhead = traced_p50 / disabled_p50 - 1.0;
    println!(
        "  trace overhead: traced p50 {traced_p50:.0}us vs disabled {disabled_p50:.0}us \
         -> {:+.2}%",
        overhead * 100.0
    );

    // ---- machine-readable artifact ---------------------------------------
    let out = std::env::var("BENCH_TRACE_OUT").unwrap_or_else(|_| "BENCH_trace.json".into());
    let doc = Json::obj(vec![
        ("bench", Json::str("E8_trace")),
        ("version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("workload", Json::str("transactional wavefront run, 8 nodes, sim backend")),
        ("disabled_p50_us", Json::num(disabled_p50.round())),
        ("traced_p50_us", Json::num(traced_p50.round())),
        ("overhead_fraction", Json::num((overhead * 10_000.0).round() / 10_000.0)),
        (
            "provenance",
            Json::obj(vec![
                ("source", Json::str("cargo bench --bench bench_trace")),
                ("os", Json::str(std::env::consts::OS)),
                ("arch", Json::str(std::env::consts::ARCH)),
            ]),
        ),
    ]);
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_trace.json");
    println!("  wrote {out}");

    // CI smoke: BENCH_TRACE_MAX_OVERHEAD turns the overhead claim into a
    // hard assertion.
    if let Ok(max) = std::env::var("BENCH_TRACE_MAX_OVERHEAD") {
        let max: f64 = max.parse().expect("BENCH_TRACE_MAX_OVERHEAD must be a number");
        assert!(
            overhead <= max,
            "tracing overhead is {:.2}%, above the {:.2}% ceiling",
            overhead * 100.0,
            max * 100.0
        );
        println!(
            "  PASS tracing overhead {:.2}% <= {:.2}%",
            overhead * 100.0,
            max * 100.0
        );
    }

    b.report();
}
