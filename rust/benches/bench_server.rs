//! Loopback API-server throughput and latency (CI smoke).
//!
//! The server's value claim is concurrency: one catalog, many tenants,
//! optimistic-concurrency commits. With a 2ms injected object-store
//! latency (modelling remote storage), a single client serializes that
//! latency per commit while 8 concurrent clients overlap it across the
//! worker pool — the bench *asserts* that 8 clients at least double the
//! aggregate commit throughput of 1. It also measures single-commit
//! keep-alive latency and drives a full remote transactional run over a
//! `bench_util` wide pipeline end to end.
//!
//! Run: `cargo bench --bench bench_server`

use std::sync::Arc;
use std::time::{Duration, Instant};

use bauplan::bench_util::{self, Bench};
use bauplan::catalog::{Catalog, MAIN};
use bauplan::client::remote::{RemoteClient, RemoteCommit, RemoteRunOpts};
use bauplan::client::Client;
use bauplan::server::{Server, ServerConfig, ServerHandle};
use bauplan::storage::ObjectStore;

/// Injected per-op object-store latency (the S3 round trip).
const STORE_LATENCY: Duration = Duration::from_millis(2);

/// Commits each client issues in the throughput comparison.
const COMMITS_PER_CLIENT: usize = 25;

fn start_server() -> ServerHandle {
    let store = Arc::new(ObjectStore::with_latency(STORE_LATENCY));
    let client = Client::open_sim_with_catalog(Catalog::new(store)).unwrap();
    let config = ServerConfig { threads: 16, ..ServerConfig::default() };
    Server::start(client, "127.0.0.1:0", config).unwrap()
}

fn drive_commits(url: &str, branch: &str, n: usize) {
    let rc = RemoteClient::new(url);
    rc.create_branch(branch, MAIN, false).unwrap();
    for i in 0..n {
        let table = format!("t{i}");
        let content = format!("{branch}:{i}");
        let out = rc.commit(&RemoteCommit::new(branch, &table, &content).retrying()).unwrap();
        bench_util::black_box(out.commit);
    }
}

/// Aggregate commits/second for `clients` concurrent connections, each
/// committing to its own branch (the multi-tenant shape).
fn aggregate_throughput(url: &str, clients: usize, generation: u32) -> f64 {
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let url = url.to_string();
        let branch = format!("g{generation}_c{c}");
        joins.push(std::thread::spawn(move || drive_commits(&url, &branch, COMMITS_PER_CLIENT)));
    }
    for j in joins {
        j.join().unwrap();
    }
    (clients * COMMITS_PER_CLIENT) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let handle = start_server();
    let url = handle.base_url();
    let mut b = Bench::heavy("server");
    b.header();

    // measured: single-commit latency over one keep-alive connection
    let rc = RemoteClient::new(&url);
    rc.create_branch("lat", MAIN, false).unwrap();
    let mut seq = 0u64;
    b.run("remote commit (1 client, keep-alive)", || {
        seq += 1;
        let table = format!("lat{seq}");
        let content = format!("lat:{seq}");
        let out = rc.commit(&RemoteCommit::new("lat", &table, &content).retrying()).unwrap();
        bench_util::black_box(out.commit);
    });

    // asserted: aggregate commit throughput scales with concurrency
    let t1 = aggregate_throughput(&url, 1, 0);
    let t8 = aggregate_throughput(&url, 8, 1);
    println!(
        "aggregate commit throughput: 1 client {t1:.0}/s, 8 clients {t8:.0}/s ({:.2}x)",
        t8 / t1
    );
    assert!(
        t8 >= 2.0 * t1,
        "8 concurrent clients must at least double aggregate commit \
         throughput: {t1:.0}/s -> {t8:.0}/s"
    );

    // end-to-end: remote transactional runs over a bench_util pipeline
    rc.seed_raw_table(MAIN, 2, 400).unwrap();
    let project = bench_util::wide_pipeline_text(4);
    let mut runs = 0u64;
    b.run("remote transactional run (wide x4, jobs=4)", || {
        runs += 1;
        let branch = format!("runb{runs}");
        rc.create_branch(&branch, MAIN, false).unwrap();
        let opts = RemoteRunOpts { jobs: 4, ..RemoteRunOpts::default() };
        let state = rc.submit_run(&project, &branch, &opts).unwrap();
        assert!(state.is_success(), "remote run failed: {:?}", state.status);
    });

    // asserted: the binary frame stream beats the JSON comparison path
    // for the same table read (doc/DATA_PLANE.md). Both paths hit the
    // same route; `format=json` decodes every batch server-side and
    // re-encodes it as JSON number arrays, while the frame stream ships
    // the stored codec objects verbatim.
    rc.create_branch("wire", MAIN, false).unwrap();
    rc.seed_raw_table("wire", 16, 2048).unwrap();
    let m_bin = b.run("read raw_table (16x2048), binary frames", || {
        let t = rc.get_table_data("wire", "raw_table").unwrap();
        bench_util::black_box(t.row_count());
    });
    let m_json = b.run("read raw_table (16x2048), JSON wire", || {
        let j = rc.get_table_data_json("wire", "raw_table").unwrap();
        bench_util::black_box(j.get("batches").as_arr().map(|a| a.len()));
    });
    let wire_ratio = m_json.p50.as_secs_f64() / m_bin.p50.as_secs_f64();
    println!("wire format: binary is {wire_ratio:.1}x the JSON read throughput");
    assert!(
        wire_ratio >= 2.0,
        "binary frame reads must at least double JSON read throughput: \
         binary p50 {:?}, JSON p50 {:?}",
        m_bin.p50,
        m_json.p50
    );

    b.report();
    handle.shutdown();
}
