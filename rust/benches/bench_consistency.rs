//! E3/E4 — the consistency experiment (paper Fig. 3, quantified).
//!
//! Identical run streams with identical injected mid-run crashes under
//! both publication modes, with concurrent readers snapshotting `main`.
//! Reported rows: inconsistent-read fraction, inconsistent-state dwell
//! time, and per-mode run throughput — the "who wins" shape is the
//! paper's core claim: DirectWrite > 0% inconsistent, Transactional = 0%.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bauplan::client::Client;
use bauplan::dag::parser::PAPER_PIPELINE_TEXT;
use bauplan::runs::{FailurePlan, RunMode};
use bauplan::testing::Rng;

const RUNS: usize = 40;
const FAILURE_RATE: f64 = 0.5;
const READERS: usize = 4;

fn consistent(client: &Client) -> bool {
    let head = client.catalog.read_ref("main").unwrap();
    let mut writers = std::collections::BTreeSet::new();
    let mut seen = 0;
    for t in ["parent_table", "child_table", "grand_child"] {
        if let Some(s) = head.tables.get(t) {
            writers.insert(client.catalog.get_snapshot(s).unwrap().run_id);
            seen += 1;
        }
    }
    seen == 0 || (seen == 3 && writers.len() == 1)
}

struct Outcome {
    inconsistent_reads: u64,
    total_reads: u64,
    failed_runs: usize,
    runs_per_s: f64,
}

fn experiment(mode: RunMode, seed: u64) -> Outcome {
    let client = Client::open("artifacts").unwrap();
    client.seed_raw_table("main", 2, 1500).unwrap();
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let reads = Arc::new(AtomicU64::new(0));
    let bad = Arc::new(AtomicU64::new(0));
    let mut readers = Vec::new();
    for _ in 0..READERS {
        let (c, s, r, b) = (client.clone(), stop.clone(), reads.clone(), bad.clone());
        readers.push(std::thread::spawn(move || {
            while !s.load(Ordering::Relaxed) {
                r.fetch_add(1, Ordering::Relaxed);
                if !consistent(&c) {
                    b.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::yield_now();
            }
        }));
    }

    let mut rng = Rng::new(seed);
    let mut failed = 0;
    let t0 = Instant::now();
    for _ in 0..RUNS {
        let failure = if rng.bool(FAILURE_RATE) {
            failed += 1;
            let node = *rng.pick(&["parent_table", "child_table", "grand_child"]);
            FailurePlan::crash_after(node)
        } else {
            FailurePlan::none()
        };
        client.run_plan(&plan, "main", mode, &failure, &[]).unwrap();
    }
    let wall = t0.elapsed();
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }
    Outcome {
        inconsistent_reads: bad.load(Ordering::Relaxed),
        total_reads: reads.load(Ordering::Relaxed),
        failed_runs: failed,
        runs_per_s: RUNS as f64 / wall.as_secs_f64(),
    }
}

fn main() {
    println!("\n=== bench: E3/E4 consistency under failures ===");
    println!(
        "{RUNS} runs, {:.0}% crash rate, {READERS} concurrent readers of main\n",
        FAILURE_RATE * 100.0
    );
    println!(
        "{:<16} {:>12} {:>14} {:>12} {:>10}",
        "mode",
        "failed runs",
        "reads",
        "inconsistent",
        "runs/s"
    );
    let mut frac = Vec::new();
    for (label, mode) in
        [("direct-write", RunMode::DirectWrite), ("transactional", RunMode::Transactional)]
    {
        let o = experiment(mode, 99);
        let pct = 100.0 * o.inconsistent_reads as f64 / o.total_reads.max(1) as f64;
        println!(
            "{:<16} {:>12} {:>14} {:>9} ({pct:>4.1}%) {:>10.2}",
            label,
            o.failed_runs,
            o.total_reads,
            o.inconsistent_reads,
            o.runs_per_s
        );
        frac.push(pct);
        println!(
            "BENCH E3E4_consistency | {label} | inconsistent_pct={pct:.3} runs_per_s={:.3}",
            o.runs_per_s
        );
    }
    println!("\n  paper shape: baseline exposes partial states to readers; the");
    println!("  transactional protocol exposes none. measured: {:.1}% vs {:.1}%", frac[0], frac[1]);
    assert_eq!(frac[1], 0.0, "transactional mode must never expose partial state");
    assert!(frac[0] > 0.0, "baseline should expose partial states at 50% crash rate");
}
