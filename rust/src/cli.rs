//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! ```text
//! bauplan demo [--artifacts DIR]           end-to-end walkthrough
//! bauplan run <project.bpln> [--branch B]  plan + transactional run
//! bauplan check <project.bpln>             parse + M1/M2 only
//! bauplan model [scenario]                 run the bounded model checker
//! bauplan branch <name> [--from R]         create a branch
//! bauplan log [ref]                        show history (demo lake)
//! bauplan cache stats|clear                inspect / reset the run cache
//! bauplan serve [--lake DIR] [--addr A]    host the HTTP API server
//! ```
//!
//! `--remote URL` (anywhere on the command line) routes a lake
//! subcommand to a `bauplan serve` endpoint through
//! [`RemoteClient`](crate::client::remote::RemoteClient) instead of a
//! local `--lake` directory — same commands, same output, remote state.
//!
//! `--artifacts sim` selects the pure-rust simulated compute backend
//! ([`crate::runtime::sim`]) — the demo and runs work offline, without
//! PJRT or a compiled artifacts directory.
//!
//! The CLI holds state only for the duration of the process (the demo
//! lake is in-memory); it exists to exercise the full public API surface
//! the way Listing 6 does.

use crate::client::Client;
use crate::dag::parser::PAPER_PIPELINE_TEXT;
use crate::error::{BauplanError, Result};
use crate::model::{check, Scenario};
use crate::runs::{FailurePlan, RunMode, Verifier};

/// Default run-cache byte budget for `bauplan run --lake` (LRU evicts
/// past this; override not yet surfaced — edit here).
const DEFAULT_CACHE_BUDGET: u64 = 256 << 20;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Demo { artifacts: String },
    Run {
        project: String,
        branch: String,
        artifacts: String,
        lake: Option<String>,
        /// `--no-cache`: escape hatch — execute every node even when a
        /// verified cache entry exists.
        no_cache: bool,
        /// `--jobs N`: wavefront width — how many ready DAG nodes the
        /// scheduler executes concurrently (default 1).
        jobs: usize,
    },
    /// Look up a terminal run record from a journaled lake
    /// (`bauplan run get <run_id>`): works across process restarts.
    RunGet { lake: String, run_id: String },
    Check { project: String },
    Model { scenario: Option<String> },
    /// Machine-readable bounded model checking: one canonical-JSON
    /// outcome per scenario (`bauplan model-check [scenario]`).
    ModelCheck { scenario: Option<String> },
    /// Deterministic simulator (`bauplan simulate`): randomized
    /// multi-agent workloads checked against the Alloy-style model.
    Simulate {
        /// First seed to run.
        seed: u64,
        /// How many consecutive seeds to run.
        seeds: u64,
        /// Approximate generated trace length.
        ops: usize,
        /// Disable the paper's protocol + visibility guardrail (the
        /// counterexample mode: the oracles must find violations).
        no_guardrail: bool,
        /// Expected violation kind: exit 0 iff a violation of this kind
        /// is found (inverts the default exit-code convention).
        expect: Option<String>,
        /// With `expect`: additionally require the shrunken trace to be
        /// at most this many ops.
        max_shrunk: Option<usize>,
        /// Replay a saved trace file instead of generating.
        ops_file: Option<String>,
        /// Write each failing seed's shrunken trace JSON into this
        /// directory (CI artifact upload).
        out_dir: Option<String>,
        /// Drive the real stack through `RemoteClient` against an
        /// in-process API server over real TCP loopback connections.
        remote_loopback: bool,
        /// Interleave real two-threaded strict-CAS committer bursts on
        /// disjoint branches with every trace (the OCC schedule
        /// oracle).
        concurrent_committers: bool,
    },
    /// Initialize a persisted lake directory.
    Init { lake: String },
    /// Branch / log / diff / tag / gc over a persisted lake.
    Branch { lake: String, name: String, from: String },
    Branches { lake: String },
    Log { lake: String, reference: String },
    Diff { lake: String, from: String, to: String },
    Tag { lake: String, name: String, target: String },
    Gc { lake: String },
    /// Fold the snapshot delta chain into a base and retire covered
    /// journal segments (`bauplan compact`).
    Compact { lake: String },
    /// Offline integrity audit (`bauplan fsck [--deep]`): walk the lake
    /// read-only and report findings; exit 1 when errors or warnings
    /// are found. `--deep` re-hashes object bytes and cross-checks
    /// zone-map footers. With `--remote`, serves the server-side report.
    Fsck { lake: String, deep: bool },
    /// Readiness snapshot (`bauplan status`): build version plus a
    /// shallow integrity summary locally; the server's `/v1/status`
    /// document with `--remote`.
    Status { lake: String },
    /// Inspect the persisted run-cache index.
    CacheStats { lake: String },
    /// Drop every run-cache entry.
    CacheClear { lake: String },
    /// Fetch a run's journaled trace (`bauplan trace <run_id>`):
    /// canonical trace JSON by default, Chrome `trace_event` JSON with
    /// `--chrome` (load in `chrome://tracing` / Perfetto).
    Trace { lake: String, run_id: String, chrome: bool, out: Option<String> },
    /// Snapshot the metrics registry as canonical JSON — counters plus
    /// per-histogram count/mean/p50/p99. Meaningful numbers come from
    /// `--remote` against a live server; locally it shows this (fresh)
    /// process's registry.
    Metrics,
    /// Host the zero-dep HTTP API server (`bauplan serve`): a journaled
    /// lake when `--lake` is given, else an in-memory demo lake.
    Serve {
        lake: Option<String>,
        addr: String,
        artifacts: String,
        threads: usize,
        /// `--access-log`: one canonical-JSON line per request on stdout.
        access_log: bool,
    },
    /// A lake subcommand executed against a `bauplan serve` endpoint
    /// (`--remote URL`) instead of a local lake directory.
    Remote { url: String, inner: Box<Command> },
    Help,
}

/// Parse argv (minus program name). `--remote URL` may appear anywhere
/// and wraps the parsed command in [`Command::Remote`].
pub fn parse_args(args: &[String]) -> Result<Command> {
    let mut remote: Option<String> = None;
    let mut filtered: Vec<String> = Vec::with_capacity(args.len());
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--remote" {
            let url = args
                .get(i + 1)
                .ok_or_else(|| BauplanError::Parse("--remote: missing URL".into()))?;
            remote = Some(url.clone());
            i += 2;
        } else {
            filtered.push(args[i].clone());
            i += 1;
        }
    }
    let cmd = parse_command(&filtered)?;
    Ok(match remote {
        Some(url) => Command::Remote { url, inner: Box::new(cmd) },
        None => cmd,
    })
}

fn parse_command(args: &[String]) -> Result<Command> {
    let mut it = args.iter();
    let cmd = match it.next().map(|s| s.as_str()) {
        None | Some("help") | Some("--help") | Some("-h") => return Ok(Command::Help),
        Some(c) => c,
    };
    let rest: Vec<&String> = it.collect();
    let flag = |name: &str, default: &str| -> String {
        rest.iter()
            .position(|a| a.as_str() == name)
            .and_then(|i| rest.get(i + 1))
            .map(|s| s.to_string())
            .unwrap_or_else(|| default.to_string())
    };
    // boolean flags take no value: the arg after them is positional
    let takes_value = |a: &str| {
        a.starts_with("--")
            && a != "--no-cache"
            && a != "--no-guardrail"
            && a != "--remote-loopback"
            && a != "--concurrent-committers"
            && a != "--access-log"
            && a != "--chrome"
            && a != "--deep"
    };
    let positionals = || -> Vec<String> {
        rest.iter()
            .enumerate()
            .filter(|(i, a)| {
                !a.starts_with("--") && (*i == 0 || !takes_value(&rest[*i - 1]))
            })
            .map(|(_, a)| a.to_string())
            .collect()
    };
    let positional = || positionals().into_iter().next();
    let lake_flag = || flag("--lake", ".bauplan");
    match cmd {
        "demo" => Ok(Command::Demo { artifacts: flag("--artifacts", "artifacts") }),
        "run" => {
            // `run get <run_id>` is the registry lookup, not an execution
            let positionals = positionals();
            if positionals.first().map(|s| s.as_str()) == Some("get") {
                return Ok(Command::RunGet {
                    lake: lake_flag(),
                    run_id: positionals.get(1).cloned().ok_or_else(|| {
                        BauplanError::Parse("run get: missing <run_id>".into())
                    })?,
                });
            }
            let jobs_s = flag("--jobs", "1");
            let jobs: usize = jobs_s.parse().map_err(|_| {
                BauplanError::Parse(format!("run: bad --jobs value '{jobs_s}'"))
            })?;
            Ok(Command::Run {
                project: positionals.first().cloned().ok_or_else(|| {
                    BauplanError::Parse("run: missing <project.bpln>".into())
                })?,
                branch: flag("--branch", "main"),
                artifacts: flag("--artifacts", "artifacts"),
                lake: rest
                    .iter()
                    .position(|a| a.as_str() == "--lake")
                    .and_then(|i| rest.get(i + 1))
                    .map(|s| s.to_string()),
                no_cache: rest.iter().any(|a| a.as_str() == "--no-cache"),
                jobs,
            })
        }
        "check" => Ok(Command::Check {
            project: positional().ok_or_else(|| {
                BauplanError::Parse("check: missing <project.bpln>".into())
            })?,
        }),
        "model" => Ok(Command::Model { scenario: positional() }),
        "model-check" => Ok(Command::ModelCheck { scenario: positional() }),
        "simulate" => {
            let parse_u64 = |name: &str, default: &str| -> Result<u64> {
                let s = flag(name, default);
                s.parse().map_err(|_| {
                    BauplanError::Parse(format!("simulate: bad {name} value '{s}'"))
                })
            };
            let opt_flag = |name: &str| -> Option<String> {
                rest.iter()
                    .position(|a| a.as_str() == name)
                    .and_then(|i| rest.get(i + 1))
                    .map(|s| s.to_string())
            };
            let max_shrunk = match opt_flag("--max-shrunk") {
                None => None,
                Some(s) => Some(s.parse().map_err(|_| {
                    BauplanError::Parse(format!("simulate: bad --max-shrunk value '{s}'"))
                })?),
            };
            Ok(Command::Simulate {
                seed: parse_u64("--seed", "1")?,
                seeds: parse_u64("--seeds", "1")?.max(1),
                ops: parse_u64("--ops", "40")? as usize,
                no_guardrail: rest.iter().any(|a| a.as_str() == "--no-guardrail"),
                expect: opt_flag("--expect"),
                max_shrunk,
                ops_file: opt_flag("--ops-file"),
                out_dir: opt_flag("--out"),
                remote_loopback: rest.iter().any(|a| a.as_str() == "--remote-loopback"),
                concurrent_committers: rest
                    .iter()
                    .any(|a| a.as_str() == "--concurrent-committers"),
            })
        }
        "serve" => {
            let threads_s = flag("--threads", "8");
            let threads: usize = threads_s.parse().map_err(|_| {
                BauplanError::Parse(format!("serve: bad --threads value '{threads_s}'"))
            })?;
            Ok(Command::Serve {
                lake: rest
                    .iter()
                    .position(|a| a.as_str() == "--lake")
                    .and_then(|i| rest.get(i + 1))
                    .map(|s| s.to_string()),
                addr: flag("--addr", "127.0.0.1:8787"),
                artifacts: flag("--artifacts", "sim"),
                threads,
                access_log: rest.iter().any(|a| a.as_str() == "--access-log"),
            })
        }
        "init" => Ok(Command::Init { lake: lake_flag() }),
        "branch" => Ok(Command::Branch {
            lake: lake_flag(),
            name: positional().ok_or_else(|| {
                BauplanError::Parse("branch: missing <name>".into())
            })?,
            from: flag("--from", "main"),
        }),
        "branches" => Ok(Command::Branches { lake: lake_flag() }),
        "log" => Ok(Command::Log {
            lake: lake_flag(),
            reference: positional().unwrap_or_else(|| "main".into()),
        }),
        "diff" => {
            let pos: Vec<String> = rest
                .iter()
                .enumerate()
                .filter(|(i, a)| {
                    !a.starts_with("--") && (*i == 0 || !rest[*i - 1].starts_with("--"))
                })
                .map(|(_, a)| a.to_string())
                .collect();
            if pos.len() != 2 {
                return Err(BauplanError::Parse("diff: need <from> <to>".into()));
            }
            Ok(Command::Diff { lake: lake_flag(), from: pos[0].clone(), to: pos[1].clone() })
        }
        "tag" => Ok(Command::Tag {
            lake: lake_flag(),
            name: positional().ok_or_else(|| BauplanError::Parse("tag: missing <name>".into()))?,
            target: flag("--at", "main"),
        }),
        "gc" => Ok(Command::Gc { lake: lake_flag() }),
        "compact" => Ok(Command::Compact { lake: lake_flag() }),
        "fsck" => Ok(Command::Fsck {
            lake: lake_flag(),
            deep: rest.iter().any(|a| a.as_str() == "--deep"),
        }),
        "status" => Ok(Command::Status { lake: lake_flag() }),
        "cache" => match positional().as_deref() {
            Some("stats") => Ok(Command::CacheStats { lake: lake_flag() }),
            Some("clear") => Ok(Command::CacheClear { lake: lake_flag() }),
            _ => Err(BauplanError::Parse("cache: need <stats|clear>".into())),
        },
        "trace" => Ok(Command::Trace {
            lake: lake_flag(),
            run_id: positional()
                .ok_or_else(|| BauplanError::Parse("trace: missing <run_id>".into()))?,
            chrome: rest.iter().any(|a| a.as_str() == "--chrome"),
            out: rest
                .iter()
                .position(|a| a.as_str() == "--out")
                .and_then(|i| rest.get(i + 1))
                .map(|s| s.to_string()),
        }),
        "metrics" => Ok(Command::Metrics),
        other => Err(BauplanError::Parse(format!("unknown command '{other}'"))),
    }
}

pub const HELP: &str = "\
bauplan — correct-by-design lakehouse (paper reproduction)

USAGE:
  bauplan demo [--artifacts DIR]            end-to-end walkthrough on demo data
  bauplan run <project.bpln> [--branch B] [--artifacts DIR] [--lake DIR]
              [--no-cache] [--jobs N]
  bauplan run get <run_id> [--lake DIR]     terminal run record (survives restarts)
  bauplan check <project.bpln>              parse + contract checks only (M1/M2)
  bauplan model [fig3|fig4|guardrail|all]   bounded model checker (paper §4)
  bauplan model-check [fig3|fig4|guardrail] model checker, canonical-JSON output
  bauplan simulate [--seed N] [--seeds K] [--ops N] [--no-guardrail]
                   [--expect KIND [--max-shrunk M]] [--ops-file trace.json]
                   [--out DIR] [--remote-loopback] [--concurrent-committers]
                                            deterministic lakehouse simulator
  bauplan serve [--lake DIR] [--addr HOST:PORT] [--artifacts DIR] [--threads N]
                [--access-log]              host the zero-dep HTTP API server
                                            (--access-log: one canonical-JSON
                                            line per request on stdout)

  --artifacts sim selects the pure-rust simulated compute backend
  (no PJRT / compiled artifacts needed).
  simulate executes seeded multi-agent op traces twice — through the
  bounded model and through the real catalog/runner stack — and checks
  refinement, Fig. 3 main consistency, the Fig. 4 visibility guardrail,
  and recovery idempotence after every op; failing seeds delta-debug to
  a minimal trace (doc/SIMULATION.md).
  --jobs N runs up to N independent DAG nodes concurrently (wavefront
  scheduling, doc/SCHEDULER.md); the published state is identical for
  every N.

persisted-lake commands (default --lake .bauplan):
  bauplan init [--lake DIR]                 create a durable lake
  bauplan branch <name> [--from REF]        create a branch
  bauplan branches                          list branches (+ txn state)
  bauplan log [REF]                         history
  bauplan diff <from> <to>                  table-level diff
  bauplan tag <name> [--at REF]             immutable tag
  bauplan gc                                drop unreachable commits/objects
  bauplan compact                           fold deltas into a base snapshot,
                                            retire covered journal segments
  bauplan fsck [--deep]                     read-only integrity audit: journal
                                            CRCs/seals, snapshot chain, refs,
                                            objects, cache index (doc/FSCK.md);
                                            --deep re-hashes object bytes and
                                            cross-checks zone-map footers;
                                            exit 1 on errors or warnings
  bauplan status                            build version + shallow integrity
                                            summary (server readiness document
                                            with --remote)
  bauplan cache stats                       run-cache entries + sizes
  bauplan cache clear                       drop every run-cache entry
  bauplan trace <run_id> [--chrome] [--out FILE]
                                            a run's journaled trace (survives
                                            restarts); --chrome exports Chrome
                                            trace_event JSON for chrome://tracing
  bauplan metrics                           metrics snapshot as canonical JSON
                                            (counters + histogram p50/p99; use
                                            --remote for a live server's numbers)
  bauplan help

runs against a --lake use the content-addressed run cache by default
(doc/RUN_CACHE.md); --no-cache forces every node to execute.

remote operation (doc/SERVER.md):
  every lake subcommand above (branch, branches, log, diff, tag, gc,
  compact, fsck, status, run, run get, cache stats, trace, metrics) also accepts
  --remote URL to execute against a bauplan serve endpoint instead of a
  local --lake directory.
  CAS conflicts cross the wire as retryable 409s; simulate
  --remote-loopback drives the full oracle suite through RemoteClient
  over a real TCP loopback connection, and --concurrent-committers
  interleaves two-threaded strict-CAS committer bursts on disjoint
  branches (doc/CONCURRENCY.md) with every trace.
";

/// Execute a parsed command; returns the process exit code.
pub fn execute(cmd: Command) -> i32 {
    match run_command(cmd) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run_command(cmd: Command) -> Result<()> {
    match cmd {
        Command::Help => {
            print!("{HELP}");
            Ok(())
        }
        Command::Check { project } => {
            let text = std::fs::read_to_string(&project)?;
            let spec = crate::dag::parser::parse_pipeline(&text)?;
            let plan = spec.plan()?;
            println!("OK: pipeline '{}' plans; write order: {:?}", plan.pipeline, plan.outputs());
            Ok(())
        }
        Command::Model { scenario } => {
            let scenarios: Vec<Scenario> = match scenario.as_deref() {
                Some("fig3") => vec![Scenario::direct_writes(), Scenario::paper_protocol()],
                Some("fig4") => vec![Scenario::counterexample()],
                Some("guardrail") => vec![Scenario::counterexample_fixed()],
                _ => vec![
                    Scenario::direct_writes(),
                    Scenario::paper_protocol(),
                    Scenario::counterexample(),
                    Scenario::counterexample_fixed(),
                ],
            };
            for sc in scenarios {
                let out = check(&sc);
                println!(
                    "scenario {:<28} states={:<8} depth={}",
                    out.scenario,
                    out.states_explored,
                    out.max_depth_reached
                );
                match out.violation {
                    Some(t) => println!("  VIOLATION (shortest trace):\n{}", t.render()),
                    None => println!("  no violation within scope"),
                }
            }
            Ok(())
        }
        Command::ModelCheck { scenario } => {
            let scenarios: Vec<Scenario> = match scenario.as_deref() {
                Some("fig3") => vec![Scenario::direct_writes(), Scenario::paper_protocol()],
                Some("fig4") => vec![Scenario::counterexample()],
                Some("guardrail") => vec![Scenario::counterexample_fixed()],
                _ => vec![
                    Scenario::direct_writes(),
                    Scenario::paper_protocol(),
                    Scenario::counterexample(),
                    Scenario::counterexample_fixed(),
                ],
            };
            // one canonical-JSON outcome per line — tooling parses this
            for sc in scenarios {
                println!("{}", check(&sc).to_json());
            }
            Ok(())
        }
        Command::Simulate {
            seed,
            seeds,
            ops,
            no_guardrail,
            expect,
            max_shrunk,
            ops_file,
            out_dir,
            remote_loopback,
            concurrent_committers,
        } => run_simulate(
            seed,
            seeds,
            ops,
            no_guardrail,
            expect,
            max_shrunk,
            ops_file,
            out_dir,
            remote_loopback,
            concurrent_committers,
        ),
        Command::Serve { lake, addr, artifacts, threads, access_log } => {
            serve(lake, &addr, &artifacts, threads, access_log)
        }
        Command::Remote { url, inner } => run_remote(&url, *inner),
        Command::Run { project, branch, artifacts, lake, no_cache, jobs } => {
            let text = std::fs::read_to_string(&project)?;
            let mut client = match &lake {
                Some(dir) => {
                    // journaled open: replays any tail past the checkpoint
                    let catalog = crate::catalog::Catalog::recover(std::path::Path::new(dir))?;
                    open_client_with_catalog(&artifacts, catalog)?
                }
                None => open_client(&artifacts)?,
            };
            if let (Some(dir), false) = (&lake, no_cache) {
                // durable run cache lives beside the journal
                let path =
                    std::path::Path::new(dir).join(crate::cache::CACHE_INDEX_FILE);
                let cache = crate::cache::RunCache::open(&path, DEFAULT_CACHE_BUDGET)?;
                client.attach_run_cache(std::sync::Arc::new(cache));
            }
            let client = client.with_jobs(jobs);
            if branch != "main" && client.catalog.branch_info(&branch).is_err() {
                client.create_branch(&branch, "main")?;
            }
            if client.catalog.read_ref(&branch)?.tables.is_empty() {
                client.seed_raw_table(&branch, 4, 1500)?;
            }
            let run = client.run_text(&text, &branch)?;
            println!("run {} on '{}': {:?}", run.run_id, branch, run.status);
            if run.cache_hits + run.cache_misses > 0 {
                // run summary: the cache.* counter family
                println!(
                    "cache: {} hits, {} misses, {} bytes saved",
                    run.cache_hits, run.cache_misses, run.cache_bytes_saved
                );
            }
            if let Some(dir) = &lake {
                // every mutation is already journaled; the checkpoint just
                // bounds the next open's replay
                let seq = client.catalog.checkpoint()?;
                println!("lake checkpointed at {dir} (journal seq {seq})");
            }
            Ok(())
        }
        Command::RunGet { lake, run_id } => with_lake(&lake, false, |catalog| {
            let Some(record) = catalog.get_run_record(&run_id) else {
                return Err(BauplanError::Other(format!(
                    "no run record for '{run_id}' in lake {lake}"
                )));
            };
            match crate::runs::run_state_from_json(&run_id, &record) {
                Some(s) => print_run_state(&run_id, &s),
                // a newer writer's format: show the raw record
                None => println!("run {run_id} (raw record): {record}"),
            }
            Ok(())
        }),
        Command::Init { lake } => {
            let dir = std::path::Path::new(&lake);
            let catalog = crate::catalog::Catalog::recover(dir)?;
            catalog.checkpoint()?;
            println!("initialized journaled lake at {lake}");
            Ok(())
        }
        Command::Branch { lake, name, from } => {
            with_lake(&lake, true, |c| {
                c.create_branch(&name, &from, false)?;
                println!("created branch '{name}' from '{from}'");
                Ok(())
            })
        }
        Command::Branches { lake } => with_lake(&lake, false, |c| {
            for b in c.list_branches() {
                println!(
                    "{:<32} {:<12} {:?}{}",
                    b.name,
                    &b.head[..12],
                    b.state,
                    if b.transactional { " [txn]" } else { "" }
                );
            }
            Ok(())
        }),
        Command::Log { lake, reference } => with_lake(&lake, false, |c| {
            for commit in c.log(&reference, 50)? {
                println!(
                    "{}  {:<32} {}",
                    &commit.id[..12],
                    commit.message,
                    commit.run_id.as_deref().unwrap_or("-")
                );
            }
            Ok(())
        }),
        Command::Diff { lake, from, to } => with_lake(&lake, false, |c| {
            for d in c.diff(&from, &to)? {
                println!("{d:?}");
            }
            Ok(())
        }),
        Command::Tag { lake, name, target } => with_lake(&lake, true, |c| {
            let id = c.tag(&name, &target)?;
            println!("tagged {name} -> {}", &id[..12]);
            Ok(())
        }),
        Command::Gc { lake } => {
            let cache_path = std::path::Path::new(&lake).join(crate::cache::CACHE_INDEX_FILE);
            with_lake(&lake, true, |c| {
                // Pins are per-process state: re-establish them from the
                // durable cache index before sweeping, or a standalone gc
                // would collect every snapshot the cache still memoizes.
                // Entries whose snapshot is already gone are dropped from
                // the index here (the one mutating maintenance command).
                if cache_path.exists() {
                    let cache = crate::cache::RunCache::open(&cache_path, u64::MAX)?;
                    for e in cache.entries() {
                        if c.pin_snapshot(&e.snapshot_id).is_err() {
                            let _ = cache.remove(&e.key);
                        }
                    }
                }
                let (commits, snaps, objects, bytes) = c.gc()?;
                println!("gc: dropped {commits} commits, {snaps} snapshots, {objects} objects ({bytes} bytes)");
                Ok(())
            })
        }
        Command::Compact { lake } => with_lake(&lake, false, |c| {
            // compact writes its own base snapshot; no trailing
            // checkpoint needed (hence mutates: false)
            let seq = c.compact()?;
            println!("compacted lake at {lake}: base snapshot covers journal seq {seq}");
            Ok(())
        }),
        Command::Fsck { lake, deep } => {
            let dir = std::path::Path::new(&lake);
            // Deliberately NOT with_lake: fsck must never open/recover
            // the catalog (recovery repairs; the auditor only observes).
            let report = crate::audit::fsck_path(dir, deep)?;
            print!("{}", report.render());
            if let Some((code, detail)) = crate::audit::worst_finding(&report) {
                // Unclean reports leave a post-mortem on disk, exactly
                // like the server's background auditor does.
                let flight = crate::trace::FlightRecorder::new(8);
                let mut span = flight.begin("fsck");
                span.fail(detail);
                span.finish();
                if let Ok(path) = flight.dump(dir, &format!("fsck {code}")) {
                    println!("flight dump: {}", path.display());
                }
            }
            if report.clean() {
                Ok(())
            } else {
                Err(BauplanError::Other(format!(
                    "fsck: lake {lake} is not clean ({} error(s), {} warning(s))",
                    report.count(crate::audit::Severity::Error),
                    report.count(crate::audit::Severity::Warn),
                )))
            }
        }
        Command::Status { lake } => {
            // The local twin of GET /v1/status: build identity plus a
            // shallow read-only integrity summary of the lake directory.
            let dir = std::path::Path::new(&lake);
            println!("bauplan {}", env!("CARGO_PKG_VERSION"));
            if !dir.is_dir() {
                println!("lake: {lake} (not initialized)");
                return Ok(());
            }
            let report = crate::audit::fsck_path(dir, false)?;
            println!("lake: {lake}");
            println!(
                "integrity: {} ({} error(s), {} warning(s), {} info)",
                if report.clean() { "clean" } else { "NOT CLEAN" },
                report.count(crate::audit::Severity::Error),
                report.count(crate::audit::Severity::Warn),
                report.count(crate::audit::Severity::Info),
            );
            Ok(())
        }
        Command::CacheStats { lake } => {
            let path = std::path::Path::new(&lake).join(crate::cache::CACHE_INDEX_FILE);
            if !path.exists() {
                println!("no run-cache index at {}", path.display());
                return Ok(());
            }
            // read-only parse: stats must never repair/compact the index
            // (a concurrent run may hold it open for appending)
            let cache = crate::cache::RunCache::open_read_only(&path, u64::MAX)?;
            let s = cache.stats();
            println!(
                "run cache at {}: {} entries, {} bytes",
                path.display(),
                s.entries,
                s.total_bytes
            );
            for e in cache.entries() {
                println!(
                    "  {}  -> snapshot {}  ({} bytes, last hit @{})",
                    &e.key[..12.min(e.key.len())],
                    &e.snapshot_id[..12.min(e.snapshot_id.len())],
                    e.bytes,
                    e.last_hit
                );
            }
            Ok(())
        }
        Command::CacheClear { lake } => {
            let path = std::path::Path::new(&lake).join(crate::cache::CACHE_INDEX_FILE);
            if !path.exists() {
                println!("no run-cache index at {}", path.display());
                return Ok(());
            }
            let cache = crate::cache::RunCache::open(&path, u64::MAX)?;
            let dropped = cache.clear().len();
            println!("run cache cleared: {dropped} entries dropped");
            Ok(())
        }
        Command::Trace { lake, run_id, chrome, out } => with_lake(&lake, false, |c| {
            let Some(trace) = c.get_run_trace(&run_id) else {
                return Err(BauplanError::Other(format!(
                    "no trace for run '{run_id}' in lake {lake} \
                     (traces journal alongside terminal run records)"
                )));
            };
            emit_trace(&trace, chrome, out.as_deref())
        }),
        Command::Metrics => {
            // The registry is per-process, so a fresh CLI invocation is
            // near-empty; `--remote` reads a live server's numbers.
            let client = open_client("sim")?;
            let cache = client.catalog.store().cache_stats();
            let m = &client.runner.metrics;
            m.set("store.cache_hits", cache.hits);
            m.set("store.cache_misses", cache.misses);
            m.set("store.cache_evicted_bytes", cache.evicted_bytes);
            m.set("store.cache_bytes", cache.cached_bytes);
            m.set("store.cache_entries", cache.entries);
            println!("{}", m.snapshot_json());
            if cache.hits + cache.misses > 0 {
                println!("block cache hit rate: {:.3}", cache.hit_rate());
            }
            Ok(())
        }
        Command::Demo { artifacts } => demo(&artifacts),
    }
}

/// Print (or write) one stored run trace, optionally converted to
/// Chrome `trace_event` JSON.
fn emit_trace(trace: &crate::util::json::Json, chrome: bool, out: Option<&str>) -> Result<()> {
    let rendered = if chrome {
        crate::trace::chrome_trace_events(trace).to_string()
    } else {
        trace.to_string()
    };
    match out {
        Some(path) => {
            std::fs::write(path, &rendered)?;
            println!("wrote trace to {path}");
        }
        None => println!("{rendered}"),
    }
    Ok(())
}

/// `bauplan simulate`: run the deterministic simulator over a seed
/// range (or a saved trace), shrink failures, and map the outcome to an
/// exit code. Default convention: exit 0 iff **no** violation; with
/// `--expect KIND` the convention inverts (exit 0 iff a violation of
/// that kind was found — and, with `--max-shrunk M`, shrank to ≤ M ops).
fn run_simulate(
    seed: u64,
    seeds: u64,
    ops: usize,
    no_guardrail: bool,
    expect: Option<String>,
    max_shrunk: Option<usize>,
    ops_file: Option<String>,
    out_dir: Option<String>,
    remote_loopback: bool,
    concurrent_committers: bool,
) -> Result<()> {
    use crate::sim::{
        replay, shrink, simulate, trace_from_json, trace_to_json, SimConfig, ViolationKind,
    };
    let expect_kind = match &expect {
        None => None,
        Some(s) => Some(ViolationKind::parse(s).ok_or_else(|| {
            BauplanError::Parse(format!("simulate: unknown --expect kind '{s}'"))
        })?),
    };
    let guardrail = !no_guardrail;
    let config =
        |seed: u64| SimConfig { seed, ops, guardrail, remote_loopback, concurrent_committers };

    // (seed, kind, shrunk length) per failing seed
    let mut violations: Vec<(u64, ViolationKind, usize)> = Vec::new();

    let mut effective_guardrail = guardrail;
    if let Some(path) = &ops_file {
        // replay an explicit trace: either a bare JSON op array or a
        // `--out` artifact ({"seed":.., "guardrail":.., "ops":[..]}) —
        // artifacts carry their guardrail setting, so replay honours it
        let text = std::fs::read_to_string(path)?;
        let parsed = crate::util::json::Json::parse(&text)?;
        let trace_json = if parsed.as_arr().is_some() {
            &parsed
        } else {
            parsed.get("ops")
        };
        if let Some(g) = parsed.get("guardrail").as_bool() {
            effective_guardrail = g;
        }
        let trace = trace_from_json(trace_json).ok_or_else(|| {
            BauplanError::Parse(format!("simulate: malformed trace file {path}"))
        })?;
        let file_seed = parsed.get("seed").as_f64().map(|s| s as u64).unwrap_or(seed);
        let file_config = SimConfig {
            seed: file_seed,
            ops,
            guardrail: effective_guardrail,
            remote_loopback,
            concurrent_committers,
        };
        let report = replay(&trace, &file_config)?;
        println!("{}", report.to_json());
        if let Some(v) = &report.violation {
            // same semantics as the sweep path: shrink the violating
            // prefix so --expect/--max-shrunk behave identically for
            // generated and file-replayed traces
            let end = (v.at_op + 1).min(trace.len());
            let shrunk = shrink(&trace[..end], &file_config, v.kind);
            println!("replay: shrunk {} ops -> {} ops", trace.len(), shrunk.len());
            println!("{}", trace_to_json(&shrunk));
            violations.push((file_seed, v.kind, shrunk.len()));
        }
    } else {
        for s in seed..seed.saturating_add(seeds) {
            let report = simulate(&config(s))?;
            let Some(v) = &report.violation else {
                if seeds >= 500 && (s - seed + 1) % 500 == 0 {
                    eprintln!("simulate: {} / {seeds} seeds clean so far", s - seed + 1);
                }
                continue;
            };
            println!(
                "seed {s}: VIOLATION {} at op {} — {}",
                v.kind.as_str(),
                v.at_op,
                v.detail
            );
            // ops past the violation never executed — shrink the prefix
            let end = (v.at_op + 1).min(report.trace.len());
            let shrunk = shrink(&report.trace[..end], &config(s), v.kind);
            println!("seed {s}: shrunk {} ops -> {} ops", report.trace.len(), shrunk.len());
            println!("{}", trace_to_json(&shrunk));
            if let Some(dir) = &out_dir {
                std::fs::create_dir_all(dir)?;
                let body = crate::util::json::Json::obj(vec![
                    ("seed", crate::util::json::Json::num(s as f64)),
                    ("guardrail", crate::util::json::Json::Bool(guardrail)),
                    ("kind", crate::util::json::Json::str(v.kind.as_str())),
                    ("ops", trace_to_json(&shrunk)),
                ]);
                std::fs::write(
                    std::path::Path::new(dir).join(format!("seed_{s}.json")),
                    body.to_string(),
                )?;
            }
            violations.push((s, v.kind, shrunk.len()));
        }
    }

    let label = if effective_guardrail { "on" } else { "off" };
    let wire = if remote_loopback { "remote-loopback" } else { "in-process" };
    println!(
        "simulate: {} trace(s), guardrail={label}, wire={wire}, {} violation(s)",
        if ops_file.is_some() { 1 } else { seeds },
        violations.len()
    );
    match expect_kind {
        None => {
            if violations.is_empty() {
                Ok(())
            } else {
                Err(BauplanError::Other(format!(
                    "simulate: {} violation(s) found with guardrail={label}",
                    violations.len()
                )))
            }
        }
        Some(kind) => {
            let hit = violations
                .iter()
                .find(|(_, k, len)| *k == kind && max_shrunk.map(|m| *len <= m).unwrap_or(true));
            match hit {
                Some((s, _, len)) => {
                    println!(
                        "simulate: expectation met — seed {s} reproduces {} in {len} ops",
                        kind.as_str()
                    );
                    Ok(())
                }
                None => Err(BauplanError::Other(format!(
                    "simulate: expected a {} violation{} but found none",
                    kind.as_str(),
                    max_shrunk
                        .map(|m| format!(" shrinkable to <= {m} ops"))
                        .unwrap_or_default()
                ))),
            }
        }
    }
}

/// `Client::open`, routing `--artifacts sim` to the simulated backend.
fn open_client(artifacts: &str) -> Result<Client> {
    if artifacts == "sim" {
        Client::open_sim()
    } else {
        Client::open(artifacts)
    }
}

/// [`open_client`] against an existing (journaled) catalog.
fn open_client_with_catalog(
    artifacts: &str,
    catalog: crate::catalog::Catalog,
) -> Result<Client> {
    if artifacts == "sim" {
        Client::open_sim_with_catalog(catalog)
    } else {
        Client::open_with_catalog(artifacts, catalog)
    }
}

/// Open a journaled lake (recovering any journal tail), run `f`. Every
/// mutation `f` performs is write-ahead journaled, so durability never
/// depends on the exit path; `mutates` only controls whether a fresh
/// checkpoint bounds the next open's replay. Read-only commands skip
/// the checkpoint write entirely — a `branches`/`log`/`diff` must not
/// touch the snapshot chain.
fn with_lake(
    lake: &str,
    mutates: bool,
    f: impl FnOnce(&crate::catalog::Catalog) -> Result<()>,
) -> Result<()> {
    let dir = std::path::Path::new(lake);
    let catalog = crate::catalog::Catalog::recover(dir)?;
    f(&catalog)?;
    if mutates {
        catalog.checkpoint()?;
    }
    Ok(())
}

/// Print one terminal run record (`run get`, local or remote).
fn print_run_state(run_id: &str, s: &crate::runs::RunState) {
    println!("run {run_id}");
    println!("  pipeline:     {}", s.pipeline);
    println!("  target:       {}", s.target);
    println!("  start_commit: {}", s.start_commit);
    println!("  code_hash:    {}", s.code_hash);
    println!("  mode:         {:?}", s.mode);
    println!("  status:       {:?}", s.status);
    println!("  outputs:      {:?}", s.outputs);
    if s.cache_hits + s.cache_misses > 0 {
        println!(
            "  cache:        {} hits, {} misses, {} bytes saved",
            s.cache_hits, s.cache_misses, s.cache_bytes_saved
        );
    }
}

/// `bauplan serve`: host the API server in the foreground until the
/// process is killed. With `--lake` the catalog is journaled (every
/// mutation write-ahead logged, so a kill is always recoverable);
/// without, an in-memory demo lake with `raw_table` pre-seeded.
fn serve(
    lake: Option<String>,
    addr: &str,
    artifacts: &str,
    threads: usize,
    access_log: bool,
) -> Result<()> {
    let mut client = match &lake {
        Some(dir) => {
            let catalog = crate::catalog::Catalog::recover(std::path::Path::new(dir))?;
            open_client_with_catalog(artifacts, catalog)?
        }
        None => open_client(artifacts)?,
    };
    if let Some(dir) = &lake {
        let path = std::path::Path::new(dir).join(crate::cache::CACHE_INDEX_FILE);
        let cache = crate::cache::RunCache::open(&path, DEFAULT_CACHE_BUDGET)?;
        client.attach_run_cache(std::sync::Arc::new(cache));
    } else if client.catalog.read_ref("main")?.tables.is_empty() {
        client.seed_raw_table("main", 4, 1500)?;
    }
    let config = crate::server::ServerConfig {
        threads,
        access_log,
        ..crate::server::ServerConfig::default()
    };
    let handle = crate::server::Server::start(client, addr, config)?;
    println!("bauplan API server listening on {}", handle.base_url());
    println!("  lake: {}", lake.as_deref().unwrap_or("(in-memory)"));
    println!("  wire protocol: doc/SERVER.md");
    handle.join();
    Ok(())
}

/// Execute a lake subcommand against a remote `bauplan serve` endpoint.
/// Output mirrors the local variants; commands that only make sense
/// against local state (init, simulate, model, check, demo) refuse.
fn run_remote(url: &str, cmd: Command) -> Result<()> {
    use crate::client::remote::{RemoteClient, RemoteRunOpts};
    let rc = RemoteClient::new(url);
    match cmd {
        Command::Branch { name, from, .. } => {
            rc.create_branch(&name, &from, false)?;
            println!("created branch '{name}' from '{from}' on {}", rc.addr());
            Ok(())
        }
        Command::Branches { .. } => {
            for b in rc.list_branches()? {
                println!(
                    "{:<32} {:<12} {:?}{}",
                    b.name,
                    &b.head[..12],
                    b.state,
                    if b.transactional { " [txn]" } else { "" }
                );
            }
            Ok(())
        }
        Command::Log { reference, .. } => {
            for commit in rc.log(&reference, 50)? {
                println!(
                    "{}  {:<32} {}",
                    &commit.id[..12],
                    commit.message,
                    commit.run_id.as_deref().unwrap_or("-")
                );
            }
            Ok(())
        }
        Command::Diff { from, to, .. } => {
            for d in rc.diff(&from, &to)? {
                println!("{d:?}");
            }
            Ok(())
        }
        Command::Tag { name, target, .. } => {
            let id = rc.tag(&name, &target)?;
            println!("tagged {name} -> {}", &id[..12]);
            Ok(())
        }
        Command::Gc { .. } => {
            let (commits, snaps, objects, bytes) = rc.gc()?;
            println!("gc: dropped {commits} commits, {snaps} snapshots, {objects} objects ({bytes} bytes)");
            Ok(())
        }
        Command::Compact { .. } => {
            let seq = rc.compact()?;
            println!("compacted lake on {}: base snapshot covers journal seq {seq}", rc.addr());
            Ok(())
        }
        Command::CacheStats { .. } => {
            println!("{}", rc.cache_stats()?);
            Ok(())
        }
        Command::Status { .. } => {
            println!("{}", rc.status()?);
            Ok(())
        }
        Command::Fsck { .. } => {
            let report = rc.fsck()?;
            println!("{report}");
            if report.get("clean").as_bool() == Some(false) {
                return Err(BauplanError::Other(format!(
                    "fsck: lake on {} is not clean",
                    rc.addr()
                )));
            }
            Ok(())
        }
        Command::Trace { run_id, chrome, out, .. } => match rc.get_trace(&run_id)? {
            Some(trace) => emit_trace(&trace, chrome, out.as_deref()),
            None => Err(BauplanError::Other(format!(
                "no trace for run '{run_id}' on {}",
                rc.addr()
            ))),
        },
        Command::Metrics => {
            let j = rc.metrics_json()?;
            println!("{j}");
            // The server syncs `store.cache_*` into the snapshot, so the
            // hit rate is computable client-side.
            let hits = j.get("counters").get("store.cache_hits").as_f64().unwrap_or(0.0);
            let misses = j.get("counters").get("store.cache_misses").as_f64().unwrap_or(0.0);
            if hits + misses > 0.0 {
                println!("block cache hit rate: {:.3}", hits / (hits + misses));
            }
            Ok(())
        }
        Command::RunGet { run_id, .. } => match rc.get_run(&run_id)? {
            Some(s) => {
                print_run_state(&run_id, &s);
                Ok(())
            }
            None => Err(BauplanError::Other(format!(
                "no run record for '{run_id}' on {}",
                rc.addr()
            ))),
        },
        Command::Run { project, branch, jobs, no_cache, .. } => {
            // --artifacts is a server-side choice and is ignored here;
            // --no-cache rides the wire so the server executes every node
            let text = std::fs::read_to_string(&project)?;
            if branch != "main" && rc.branch_info(&branch).is_err() {
                rc.create_branch(&branch, "main", false)?;
            }
            if rc.read_ref(&branch)?.tables.is_empty() {
                rc.seed_raw_table(&branch, 4, 1500)?;
            }
            let opts = RemoteRunOpts { jobs, no_cache, ..RemoteRunOpts::default() };
            let run = rc.submit_run(&text, &branch, &opts)?;
            println!("run {} on '{}': {:?}", run.run_id, branch, run.status);
            Ok(())
        }
        Command::Help => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(BauplanError::Parse(format!(
            "--remote does not support this command: {other:?}"
        ))),
    }
}

/// The end-to-end walkthrough: Listing 6's workflow narrated.
fn demo(artifacts: &str) -> Result<()> {
    println!("== bauplan demo: correct-by-design lakehouse ==");
    let client = open_client(artifacts)?;
    client.seed_raw_table("main", 4, 1500)?;
    println!("seeded raw_table on main (4 batches x 1500 rows)");

    let feature = client.create_branch("feature", "main")?;
    let run = client.run_text(PAPER_PIPELINE_TEXT, &feature)?;
    println!("run {} on '{feature}': {:?}", run.run_id, run.status);

    let diff = client.diff("main", &feature)?;
    println!("PR diff vs main: {} tables changed", diff.len());
    client.merge(&feature, "main")?;
    println!("merged '{feature}' into main");

    // failure path: injected crash leaves main intact
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;
    let before = client.catalog.resolve("main")?;
    let failed = client.run_plan(
        &plan,
        "main",
        RunMode::Transactional,
        &FailurePlan::crash_after("parent_table"),
        &[Verifier::min_rows("grand_child", 1)],
    )?;
    let after = client.catalog.resolve("main")?;
    println!("injected failure run: {:?}", failed.status);
    println!("main untouched: {}", before == after);

    println!("{}", client.runner.metrics.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_commands() {
        assert_eq!(parse_args(&s(&[])).unwrap(), Command::Help);
        assert_eq!(
            parse_args(&s(&["demo"])).unwrap(),
            Command::Demo { artifacts: "artifacts".into() }
        );
        assert_eq!(
            parse_args(&s(&["run", "p.bpln", "--branch", "dev"])).unwrap(),
            Command::Run {
                project: "p.bpln".into(),
                branch: "dev".into(),
                artifacts: "artifacts".into(),
                lake: None,
                no_cache: false,
                jobs: 1,
            }
        );
        assert_eq!(
            parse_args(&s(&["run", "--no-cache", "p.bpln", "--jobs", "4"])).unwrap(),
            Command::Run {
                project: "p.bpln".into(),
                branch: "main".into(),
                artifacts: "artifacts".into(),
                lake: None,
                no_cache: true,
                jobs: 4,
            }
        );
        assert!(parse_args(&s(&["run", "p.bpln", "--jobs", "many"])).is_err());
        assert_eq!(
            parse_args(&s(&["run", "get", "run_123", "--lake", "/tmp/l"])).unwrap(),
            Command::RunGet { lake: "/tmp/l".into(), run_id: "run_123".into() }
        );
        assert!(parse_args(&s(&["run", "get"])).is_err());
        assert_eq!(
            parse_args(&s(&["branch", "f1", "--from", "dev", "--lake", "/tmp/l"])).unwrap(),
            Command::Branch { lake: "/tmp/l".into(), name: "f1".into(), from: "dev".into() }
        );
        assert_eq!(
            parse_args(&s(&["diff", "main", "dev"])).unwrap(),
            Command::Diff { lake: ".bauplan".into(), from: "main".into(), to: "dev".into() }
        );
        assert!(parse_args(&s(&["diff", "main"])).is_err());
        assert_eq!(parse_args(&s(&["gc"])).unwrap(), Command::Gc { lake: ".bauplan".into() });
        assert_eq!(
            parse_args(&s(&["compact", "--lake", "/tmp/l"])).unwrap(),
            Command::Compact { lake: "/tmp/l".into() }
        );
        assert_eq!(
            parse_args(&s(&["fsck"])).unwrap(),
            Command::Fsck { lake: ".bauplan".into(), deep: false }
        );
        // --deep is boolean: the flag after it still takes its value
        assert_eq!(
            parse_args(&s(&["fsck", "--deep", "--lake", "/tmp/l"])).unwrap(),
            Command::Fsck { lake: "/tmp/l".into(), deep: true }
        );
        assert_eq!(
            parse_args(&s(&["status", "--lake", "/tmp/l"])).unwrap(),
            Command::Status { lake: "/tmp/l".into() }
        );
        assert_eq!(
            parse_args(&s(&["status", "--remote", "h:1"])).unwrap(),
            Command::Remote {
                url: "h:1".into(),
                inner: Box::new(Command::Status { lake: ".bauplan".into() })
            }
        );
        assert_eq!(
            parse_args(&s(&["cache", "stats"])).unwrap(),
            Command::CacheStats { lake: ".bauplan".into() }
        );
        assert_eq!(
            parse_args(&s(&["cache", "clear", "--lake", "/tmp/l"])).unwrap(),
            Command::CacheClear { lake: "/tmp/l".into() }
        );
        assert!(parse_args(&s(&["cache"])).is_err());
        assert!(parse_args(&s(&["cache", "frob"])).is_err());
        assert_eq!(
            parse_args(&s(&["model", "fig4"])).unwrap(),
            Command::Model { scenario: Some("fig4".into()) }
        );
        assert_eq!(
            parse_args(&s(&["model-check", "fig4"])).unwrap(),
            Command::ModelCheck { scenario: Some("fig4".into()) }
        );
        assert_eq!(
            parse_args(&s(&[
                "simulate",
                "--seed",
                "7",
                "--no-guardrail",
                "--expect",
                "fig4_aborted_branch_merge",
                "--max-shrunk",
                "8",
            ]))
            .unwrap(),
            Command::Simulate {
                seed: 7,
                seeds: 1,
                ops: 40,
                no_guardrail: true,
                expect: Some("fig4_aborted_branch_merge".into()),
                max_shrunk: Some(8),
                ops_file: None,
                out_dir: None,
                remote_loopback: false,
                concurrent_committers: false,
            }
        );
        assert_eq!(
            parse_args(&s(&["simulate", "--seeds", "200", "--out", "failures"])).unwrap(),
            Command::Simulate {
                seed: 1,
                seeds: 200,
                ops: 40,
                no_guardrail: false,
                expect: None,
                max_shrunk: None,
                ops_file: None,
                out_dir: Some("failures".into()),
                remote_loopback: false,
                concurrent_committers: false,
            }
        );
        assert!(parse_args(&s(&["simulate", "--seeds", "many"])).is_err());
        // --remote-loopback is boolean: the next token stays positional
        match parse_args(&s(&["simulate", "--remote-loopback", "--seeds", "50"])).unwrap() {
            Command::Simulate { seeds, remote_loopback, .. } => {
                assert_eq!(seeds, 50);
                assert!(remote_loopback);
            }
            other => panic!("wrong parse: {other:?}"),
        }
        // --concurrent-committers is boolean too, and composes
        match parse_args(&s(&["simulate", "--concurrent-committers", "--seeds", "50"])).unwrap() {
            Command::Simulate { seeds, concurrent_committers, remote_loopback, .. } => {
                assert_eq!(seeds, 50);
                assert!(concurrent_committers);
                assert!(!remote_loopback);
            }
            other => panic!("wrong parse: {other:?}"),
        }
        assert_eq!(
            parse_args(&s(&["serve", "--lake", "/tmp/l", "--addr", "0.0.0.0:9000"])).unwrap(),
            Command::Serve {
                lake: Some("/tmp/l".into()),
                addr: "0.0.0.0:9000".into(),
                artifacts: "sim".into(),
                threads: 8,
                access_log: false,
            }
        );
        assert_eq!(
            parse_args(&s(&["serve", "--threads", "4", "--access-log"])).unwrap(),
            Command::Serve {
                lake: None,
                addr: "127.0.0.1:8787".into(),
                artifacts: "sim".into(),
                threads: 4,
                access_log: true,
            }
        );
        assert!(parse_args(&s(&["serve", "--threads", "many"])).is_err());
        // --chrome is boolean: the run id after it stays positional
        assert_eq!(
            parse_args(&s(&["trace", "--chrome", "run_42", "--out", "t.json"])).unwrap(),
            Command::Trace {
                lake: ".bauplan".into(),
                run_id: "run_42".into(),
                chrome: true,
                out: Some("t.json".into()),
            }
        );
        assert_eq!(
            parse_args(&s(&["trace", "run_42", "--lake", "/tmp/l"])).unwrap(),
            Command::Trace {
                lake: "/tmp/l".into(),
                run_id: "run_42".into(),
                chrome: false,
                out: None,
            }
        );
        assert!(parse_args(&s(&["trace"])).is_err());
        assert_eq!(parse_args(&s(&["metrics"])).unwrap(), Command::Metrics);
        assert_eq!(
            parse_args(&s(&["metrics", "--remote", "h:1"])).unwrap(),
            Command::Remote { url: "h:1".into(), inner: Box::new(Command::Metrics) }
        );
        // --remote wraps any lake subcommand, wherever the flag appears
        assert_eq!(
            parse_args(&s(&["branches", "--remote", "127.0.0.1:8787"])).unwrap(),
            Command::Remote {
                url: "127.0.0.1:8787".into(),
                inner: Box::new(Command::Branches { lake: ".bauplan".into() }),
            }
        );
        assert_eq!(
            parse_args(&s(&["--remote", "h:1", "run", "get", "run_9"])).unwrap(),
            Command::Remote {
                url: "h:1".into(),
                inner: Box::new(Command::RunGet {
                    lake: ".bauplan".into(),
                    run_id: "run_9".into(),
                }),
            }
        );
        assert!(parse_args(&s(&["branches", "--remote"])).is_err());
        assert!(parse_args(&s(&["run"])).is_err());
        assert!(parse_args(&s(&["frobnicate"])).is_err());
    }
}
