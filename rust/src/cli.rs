//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! ```text
//! bauplan demo [--artifacts DIR]           end-to-end walkthrough
//! bauplan run <project.bpln> [--branch B]  plan + transactional run
//! bauplan check <project.bpln>             parse + M1/M2 only
//! bauplan model [scenario]                 run the bounded model checker
//! bauplan branch <name> [--from R]         create a branch
//! bauplan log [ref]                        show history (demo lake)
//! ```
//!
//! The CLI holds state only for the duration of the process (the demo
//! lake is in-memory); it exists to exercise the full public API surface
//! the way Listing 6 does.

use crate::client::Client;
use crate::dag::parser::PAPER_PIPELINE_TEXT;
use crate::error::{BauplanError, Result};
use crate::model::{check, Scenario};
use crate::runs::{FailurePlan, RunMode, Verifier};

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Demo { artifacts: String },
    Run { project: String, branch: String, artifacts: String, lake: Option<String> },
    Check { project: String },
    Model { scenario: Option<String> },
    /// Initialize a persisted lake directory.
    Init { lake: String },
    /// Branch / log / diff / tag / gc over a persisted lake.
    Branch { lake: String, name: String, from: String },
    Branches { lake: String },
    Log { lake: String, reference: String },
    Diff { lake: String, from: String, to: String },
    Tag { lake: String, name: String, target: String },
    Gc { lake: String },
    Help,
}

/// Parse argv (minus program name).
pub fn parse_args(args: &[String]) -> Result<Command> {
    let mut it = args.iter();
    let cmd = match it.next().map(|s| s.as_str()) {
        None | Some("help") | Some("--help") | Some("-h") => return Ok(Command::Help),
        Some(c) => c,
    };
    let rest: Vec<&String> = it.collect();
    let flag = |name: &str, default: &str| -> String {
        rest.iter()
            .position(|a| a.as_str() == name)
            .and_then(|i| rest.get(i + 1))
            .map(|s| s.to_string())
            .unwrap_or_else(|| default.to_string())
    };
    let positional = || -> Option<String> {
        rest.iter()
            .enumerate()
            .filter(|(i, a)| {
                !a.starts_with("--")
                    && (*i == 0 || !rest[*i - 1].starts_with("--"))
            })
            .map(|(_, a)| a.to_string())
            .next()
    };
    let lake_flag = || flag("--lake", ".bauplan");
    match cmd {
        "demo" => Ok(Command::Demo { artifacts: flag("--artifacts", "artifacts") }),
        "run" => Ok(Command::Run {
            project: positional().ok_or_else(|| {
                BauplanError::Parse("run: missing <project.bpln>".into())
            })?,
            branch: flag("--branch", "main"),
            artifacts: flag("--artifacts", "artifacts"),
            lake: rest.iter().position(|a| a.as_str() == "--lake").and_then(|i| rest.get(i + 1)).map(|s| s.to_string()),
        }),
        "check" => Ok(Command::Check {
            project: positional().ok_or_else(|| {
                BauplanError::Parse("check: missing <project.bpln>".into())
            })?,
        }),
        "model" => Ok(Command::Model { scenario: positional() }),
        "init" => Ok(Command::Init { lake: lake_flag() }),
        "branch" => Ok(Command::Branch {
            lake: lake_flag(),
            name: positional().ok_or_else(|| {
                BauplanError::Parse("branch: missing <name>".into())
            })?,
            from: flag("--from", "main"),
        }),
        "branches" => Ok(Command::Branches { lake: lake_flag() }),
        "log" => Ok(Command::Log { lake: lake_flag(), reference: positional().unwrap_or_else(|| "main".into()) }),
        "diff" => {
            let pos: Vec<String> = rest
                .iter()
                .enumerate()
                .filter(|(i, a)| !a.starts_with("--") && (*i == 0 || !rest[*i - 1].starts_with("--")))
                .map(|(_, a)| a.to_string())
                .collect();
            if pos.len() != 2 {
                return Err(BauplanError::Parse("diff: need <from> <to>".into()));
            }
            Ok(Command::Diff { lake: lake_flag(), from: pos[0].clone(), to: pos[1].clone() })
        }
        "tag" => Ok(Command::Tag {
            lake: lake_flag(),
            name: positional().ok_or_else(|| BauplanError::Parse("tag: missing <name>".into()))?,
            target: flag("--at", "main"),
        }),
        "gc" => Ok(Command::Gc { lake: lake_flag() }),
        other => Err(BauplanError::Parse(format!("unknown command '{other}'"))),
    }
}

pub const HELP: &str = "\
bauplan — correct-by-design lakehouse (paper reproduction)

USAGE:
  bauplan demo [--artifacts DIR]            end-to-end walkthrough on demo data
  bauplan run <project.bpln> [--branch B] [--artifacts DIR] [--lake DIR]
  bauplan check <project.bpln>              parse + contract checks only (M1/M2)
  bauplan model [fig3|fig4|guardrail|all]   bounded model checker (paper §4)

persisted-lake commands (default --lake .bauplan):
  bauplan init [--lake DIR]                 create a durable lake
  bauplan branch <name> [--from REF]        create a branch
  bauplan branches                          list branches (+ txn state)
  bauplan log [REF]                         history
  bauplan diff <from> <to>                  table-level diff
  bauplan tag <name> [--at REF]             immutable tag
  bauplan gc                                drop unreachable commits/objects
  bauplan help
";

/// Execute a parsed command; returns the process exit code.
pub fn execute(cmd: Command) -> i32 {
    match run_command(cmd) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run_command(cmd: Command) -> Result<()> {
    match cmd {
        Command::Help => {
            print!("{HELP}");
            Ok(())
        }
        Command::Check { project } => {
            let text = std::fs::read_to_string(&project)?;
            let spec = crate::dag::parser::parse_pipeline(&text)?;
            let plan = spec.plan()?;
            println!("OK: pipeline '{}' plans; write order: {:?}",
                     plan.pipeline, plan.outputs());
            Ok(())
        }
        Command::Model { scenario } => {
            let scenarios: Vec<Scenario> = match scenario.as_deref() {
                Some("fig3") => vec![Scenario::direct_writes(), Scenario::paper_protocol()],
                Some("fig4") => vec![Scenario::counterexample()],
                Some("guardrail") => vec![Scenario::counterexample_fixed()],
                _ => vec![
                    Scenario::direct_writes(),
                    Scenario::paper_protocol(),
                    Scenario::counterexample(),
                    Scenario::counterexample_fixed(),
                ],
            };
            for sc in scenarios {
                let out = check(&sc);
                println!("scenario {:<28} states={:<8} depth={}",
                         out.scenario, out.states_explored, out.max_depth_reached);
                match out.violation {
                    Some(t) => println!("  VIOLATION (shortest trace):\n{}", t.render()),
                    None => println!("  no violation within scope"),
                }
            }
            Ok(())
        }
        Command::Run { project, branch, artifacts, lake } => {
            let text = std::fs::read_to_string(&project)?;
            let client = match &lake {
                Some(dir) => {
                    // journaled open: replays any tail past the checkpoint
                    let catalog = crate::catalog::Catalog::recover(std::path::Path::new(dir))?;
                    Client::open_with_catalog(&artifacts, catalog)?
                }
                None => Client::open(&artifacts)?,
            };
            if branch != "main" && client.catalog.branch_info(&branch).is_err() {
                client.create_branch(&branch, "main")?;
            }
            if client.catalog.read_ref(&branch)?.tables.is_empty() {
                client.seed_raw_table(&branch, 4, 1500)?;
            }
            let run = client.run_text(&text, &branch)?;
            println!("run {} on '{}': {:?}", run.run_id, branch, run.status);
            if let Some(dir) = &lake {
                // every mutation is already journaled; the checkpoint just
                // bounds the next open's replay
                let seq = client.catalog.checkpoint()?;
                println!("lake checkpointed at {dir} (journal seq {seq})");
            }
            Ok(())
        }
        Command::Init { lake } => {
            let dir = std::path::Path::new(&lake);
            let catalog = crate::catalog::Catalog::recover(dir)?;
            catalog.checkpoint()?;
            println!("initialized journaled lake at {lake}");
            Ok(())
        }
        Command::Branch { lake, name, from } => {
            with_lake(&lake, |c| {
                c.create_branch(&name, &from, false)?;
                println!("created branch '{name}' from '{from}'");
                Ok(())
            })
        }
        Command::Branches { lake } => with_lake(&lake, |c| {
            for b in c.list_branches() {
                println!("{:<32} {:<12} {:?}{}", b.name, &b.head[..12], b.state,
                         if b.transactional { " [txn]" } else { "" });
            }
            Ok(())
        }),
        Command::Log { lake, reference } => with_lake(&lake, |c| {
            for commit in c.log(&reference, 50)? {
                println!("{}  {:<32} {}", &commit.id[..12], commit.message,
                         commit.run_id.as_deref().unwrap_or("-"));
            }
            Ok(())
        }),
        Command::Diff { lake, from, to } => with_lake(&lake, |c| {
            for d in c.diff(&from, &to)? {
                println!("{d:?}");
            }
            Ok(())
        }),
        Command::Tag { lake, name, target } => with_lake(&lake, |c| {
            let id = c.tag(&name, &target)?;
            println!("tagged {name} -> {}", &id[..12]);
            Ok(())
        }),
        Command::Gc { lake } => with_lake(&lake, |c| {
            let (commits, snaps, objects, bytes) = c.gc()?;
            println!("gc: dropped {commits} commits, {snaps} snapshots, {objects} objects ({bytes} bytes)");
            Ok(())
        }),
        Command::Demo { artifacts } => demo(&artifacts),
    }
}

/// Open a journaled lake (recovering any journal tail), run `f`. Every
/// mutation `f` performs is write-ahead journaled, so there is nothing
/// to save on the way out — durability is per-operation, not per-exit.
fn with_lake(
    lake: &str,
    f: impl FnOnce(&crate::catalog::Catalog) -> Result<()>,
) -> Result<()> {
    let dir = std::path::Path::new(lake);
    let catalog = crate::catalog::Catalog::recover(dir)?;
    f(&catalog)
}

/// The end-to-end walkthrough: Listing 6's workflow narrated.
fn demo(artifacts: &str) -> Result<()> {
    println!("== bauplan demo: correct-by-design lakehouse ==");
    let client = Client::open(artifacts)?;
    client.seed_raw_table("main", 4, 1500)?;
    println!("seeded raw_table on main (4 batches x 1500 rows)");

    let feature = client.create_branch("feature", "main")?;
    let run = client.run_text(PAPER_PIPELINE_TEXT, &feature)?;
    println!("run {} on '{feature}': {:?}", run.run_id, run.status);

    let diff = client.diff("main", &feature)?;
    println!("PR diff vs main: {} tables changed", diff.len());
    client.merge(&feature, "main")?;
    println!("merged '{feature}' into main");

    // failure path: injected crash leaves main intact
    let plan = client.control_plane.plan_from_text(PAPER_PIPELINE_TEXT)?;
    let before = client.catalog.resolve("main")?;
    let failed = client.run_plan(
        &plan,
        "main",
        RunMode::Transactional,
        &FailurePlan::crash_after("parent_table"),
        &[Verifier::min_rows("grand_child", 1)],
    )?;
    let after = client.catalog.resolve("main")?;
    println!("injected failure run: {:?}", failed.status);
    println!("main untouched: {}", before == after);

    println!("{}", client.runner.metrics.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_commands() {
        assert_eq!(parse_args(&s(&[])).unwrap(), Command::Help);
        assert_eq!(
            parse_args(&s(&["demo"])).unwrap(),
            Command::Demo { artifacts: "artifacts".into() }
        );
        assert_eq!(
            parse_args(&s(&["run", "p.bpln", "--branch", "dev"])).unwrap(),
            Command::Run {
                project: "p.bpln".into(),
                branch: "dev".into(),
                artifacts: "artifacts".into(),
                lake: None,
            }
        );
        assert_eq!(
            parse_args(&s(&["branch", "f1", "--from", "dev", "--lake", "/tmp/l"])).unwrap(),
            Command::Branch { lake: "/tmp/l".into(), name: "f1".into(), from: "dev".into() }
        );
        assert_eq!(
            parse_args(&s(&["diff", "main", "dev"])).unwrap(),
            Command::Diff { lake: ".bauplan".into(), from: "main".into(), to: "dev".into() }
        );
        assert!(parse_args(&s(&["diff", "main"])).is_err());
        assert_eq!(parse_args(&s(&["gc"])).unwrap(), Command::Gc { lake: ".bauplan".into() });
        assert_eq!(
            parse_args(&s(&["model", "fig4"])).unwrap(),
            Command::Model { scenario: Some("fig4".into()) }
        );
        assert!(parse_args(&s(&["run"])).is_err());
        assert!(parse_args(&s(&["frobnicate"])).is_err());
    }
}
