//! Lightweight metrics registry: counters and latency histograms for the
//! coordinator's hot paths. Lock-free counters; histograms use coarse
//! power-of-two-ish buckets (µs) — enough for the p50/p99 the benches
//! report without pulling in a metrics crate.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Histogram bucket upper bounds in microseconds.
const BUCKETS_US: [u64; 16] = [
    1, 2, 5, 10, 20, 50, 100, 200, 500, 1_000, 2_000, 5_000, 10_000, 50_000,
    200_000, 1_000_000,
];

/// A latency histogram with fixed µs buckets.
#[derive(Debug, Default)]
pub struct Histogram {
    counts: [AtomicU64; 17],
    total: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(16);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// The bucket upper bounds, in order. The final implicit bucket
    /// (everything above the last bound) is not listed — renderers add
    /// their own `+Inf` line.
    pub fn bucket_bounds_us() -> &'static [u64] {
        &BUCKETS_US
    }

    /// Per-bucket observation counts, one per bound plus a trailing
    /// overflow slot. These are raw (non-cumulative) counts; the
    /// Prometheus renderer accumulates them into `le`-style buckets.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (q * n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

/// Global-ish registry: named counters + histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.into()).or_insert(0) += by;
    }

    /// Set a counter to an absolute value. For bridging counters tracked
    /// elsewhere as atomics (e.g. the object store's `store.cache_*`
    /// family) into the registry right before rendering — `incr` would
    /// double-count them.
    pub fn set(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.into(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.into())
            .or_default()
            .clone()
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let h = self.histogram(name);
        let t0 = Instant::now();
        let out = f();
        h.record_us(t0.elapsed().as_micros() as u64);
        out
    }

    /// Record a dimensionless sample (e.g. `run.parallelism`, the peak
    /// concurrent nodes of one run) into the named histogram — the
    /// buckets read as plain values rather than microseconds.
    pub fn record(&self, name: &str, value: u64) {
        self.histogram(name).record_us(value);
    }

    /// A namespaced view: `metrics.clone().ns("cache").incr("hits", 1)`
    /// bumps the `cache.hits` counter. Namespaces keep subsystem
    /// counters (cache, run, worker) greppable and let callers read a
    /// whole family back with [`Metrics::counters_prefixed`].
    pub fn ns(self: std::sync::Arc<Self>, prefix: &str) -> MetricsNs {
        MetricsNs { metrics: self, prefix: prefix.to_string() }
    }

    /// All counters under `prefix.` (sorted), e.g. run-summary lines for
    /// the `cache.*` family.
    pub fn counters_prefixed(&self, prefix: &str) -> Vec<(String, u64)> {
        let dotted = format!("{prefix}.");
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(&dotted))
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// All counters, sorted by name (the API server's `/metrics`
    /// endpoint renders these in Prometheus text format).
    pub fn all_counters(&self) -> Vec<(String, u64)> {
        self.counters.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Per-histogram summary `(name, count, mean_us, p50_us, p99_us)`,
    /// sorted by name.
    pub fn all_histograms(&self) -> Vec<(String, u64, f64, u64, u64)> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                (k.clone(), h.count(), h.mean_us(), h.quantile_us(0.5), h.quantile_us(0.99))
            })
            .collect()
    }

    /// Every histogram with its live handle, sorted by name — the
    /// Prometheus renderer reads raw bucket counts through these.
    pub fn all_histogram_handles(&self) -> Vec<(String, std::sync::Arc<Histogram>)> {
        self.histograms.lock().unwrap().iter().map(|(k, h)| (k.clone(), h.clone())).collect()
    }

    /// Canonical-JSON snapshot of the whole registry: every counter plus
    /// the count/mean/p50/p99 summary of every histogram. Served at
    /// `GET /v1/metrics/json` and printed by `bauplan metrics`.
    pub fn snapshot_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut counters = BTreeMap::new();
        for (k, v) in self.all_counters() {
            counters.insert(k, Json::Num(v as f64));
        }
        let mut hists = BTreeMap::new();
        for (name, count, mean_us, p50_us, p99_us) in self.all_histograms() {
            hists.insert(
                name,
                Json::obj(vec![
                    ("count", Json::Num(count as f64)),
                    ("mean_us", Json::Num(mean_us)),
                    ("p50_us", Json::Num(p50_us as f64)),
                    ("p99_us", Json::Num(p99_us as f64)),
                ]),
            );
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("histograms", Json::Obj(hists)),
        ])
    }

    /// Render all metrics as text (CLI `bauplan metrics`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {k}: n={} mean={:.1}us p50<={}us p99<={}us\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.99)
            ));
        }
        out
    }
}

/// A prefix-scoped handle onto a shared [`Metrics`] registry.
#[derive(Debug, Clone)]
pub struct MetricsNs {
    metrics: std::sync::Arc<Metrics>,
    prefix: String,
}

impl MetricsNs {
    /// Increment `<prefix>.<name>`.
    pub fn incr(&self, name: &str, by: u64) {
        self.metrics.incr(&format!("{}.{name}", self.prefix), by);
    }

    /// Read `<prefix>.<name>`.
    pub fn counter(&self, name: &str) -> u64 {
        self.metrics.counter(&format!("{}.{name}", self.prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("x", 2);
        m.incr("x", 3);
        assert_eq!(m.counter("x"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_is_absolute_not_additive() {
        let m = Metrics::new();
        m.set("store.cache_hits", 7);
        m.set("store.cache_hits", 5);
        assert_eq!(m.counter("store.cache_hits"), 5);
        m.incr("store.cache_hits", 1);
        assert_eq!(m.counter("store.cache_hits"), 6);
    }

    #[test]
    fn histogram_quantiles_are_monotone() {
        let h = Histogram::default();
        for us in [1, 3, 8, 40, 90, 900, 4000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn namespaced_counters_share_the_registry() {
        let m = std::sync::Arc::new(Metrics::new());
        let cache = m.clone().ns("cache");
        cache.incr("hits", 2);
        cache.incr("bytes_saved", 512);
        m.incr("cache.hits", 1);
        assert_eq!(cache.counter("hits"), 3);
        assert_eq!(m.counter("cache.hits"), 3);
        let fam = m.counters_prefixed("cache");
        assert_eq!(fam.len(), 2);
        assert!(fam.iter().any(|(k, v)| k == "cache.hits" && *v == 3));
        assert!(m.counters_prefixed("run").is_empty());
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.histogram("op").count(), 1);
        assert!(m.render().contains("hist op"));
    }

    #[test]
    fn bucket_counts_align_with_bounds() {
        let h = Histogram::default();
        h.record_us(1); // first bucket (<= 1)
        h.record_us(3); // <= 5
        h.record_us(2_000_000); // overflow slot
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), Histogram::bucket_bounds_us().len() + 1);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[2], 1);
        assert_eq!(counts[counts.len() - 1], 1);
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us(), 2_000_004);
    }

    #[test]
    fn snapshot_json_carries_counters_and_histograms() {
        let m = Metrics::new();
        m.incr("server.requests", 3);
        m.record("run.parallelism", 4);
        let snap = m.snapshot_json();
        assert_eq!(
            snap.get("counters").get("server.requests").as_usize(),
            Some(3)
        );
        let h = snap.get("histograms").get("run.parallelism");
        assert_eq!(h.get("count").as_usize(), Some(1));
        assert_eq!(h.get("p50_us").as_usize(), Some(5));
    }

    #[test]
    fn record_takes_dimensionless_samples() {
        let m = Metrics::new();
        m.record("run.parallelism", 4);
        m.record("run.parallelism", 1);
        let h = m.histogram("run.parallelism");
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean_us(), 2.5);
    }
}
