//! Reusable crash-matrix harness for the durable commit pipeline.
//!
//! The durability claim in `doc/COMMIT_PIPELINE.md` is not "fsync was
//! called" but "at **every** kill point of the pipeline, recovery lands on
//! a byte-identical catalog export". This module makes that claim
//! executable: [`run_crash_matrix`] enumerates the pipeline's kill points
//! ([`CrashPoint::ALL`] plus the group-commit enqueue-vs-fsync window),
//! drives a representative workload into each one, kills the catalog
//! there, recovers twice, and reports the three exports for comparison.
//!
//! The matrix is consumed by `tests/crash_matrix.rs` (CI job
//! `crash-matrix`) and is deliberately deterministic: no threads, no
//! timing — each kill point is armed via [`Catalog::inject_crash_point`]
//! and trips on the exact pipeline step it names.

use std::path::{Path, PathBuf};

use crate::audit::{fsck_path, FsckReport};
use crate::catalog::{
    Catalog, CrashPoint, JournalConfig, RecoveryStats, Snapshot, SyncPolicy, MAIN,
};
use crate::error::Result;
use crate::testing::commit_table;
use crate::util::json::Json;

/// One kill-point scenario of the matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashScenario {
    /// Arm a [`CrashPoint`] and drive the pipeline into it.
    Kill(CrashPoint),
    /// The group-commit enqueue-vs-fsync window: records appended to the
    /// active segment but not yet covered by a leader fsync are lost at
    /// power-off. Modeled with a batched sync policy + an explicit
    /// unsynced-tail drop, which produces the identical disk state.
    LostSyncWindow,
}

impl CrashScenario {
    /// Every scenario the matrix runs.
    pub fn all() -> Vec<CrashScenario> {
        let mut v: Vec<CrashScenario> =
            CrashPoint::ALL.iter().map(|p| CrashScenario::Kill(*p)).collect();
        v.push(CrashScenario::LostSyncWindow);
        v
    }

    /// Stable name (directory name + failure messages).
    pub fn name(&self) -> &'static str {
        match self {
            CrashScenario::Kill(CrashPoint::MidRecord) => "mid_record",
            CrashScenario::Kill(CrashPoint::AtRotationSealed) => "at_rotation_sealed",
            CrashScenario::Kill(CrashPoint::MidDeltaFlush) => "mid_delta_flush",
            CrashScenario::Kill(CrashPoint::MidCompactBase) => "mid_compact_base",
            CrashScenario::Kill(CrashPoint::MidCompactRetire) => "mid_compact_retire",
            CrashScenario::LostSyncWindow => "lost_sync_window",
        }
    }
}

/// What one scenario produced: the export the crashed catalog was
/// supposed to preserve, and the exports of two successive recoveries.
#[derive(Debug)]
pub struct CrashOutcome {
    /// Which scenario ran.
    pub scenario: CrashScenario,
    /// Canonical export the recovery must reproduce byte-for-byte.
    pub expected_export: String,
    /// Export after the first recovery.
    pub recovered_export: String,
    /// Export after recovering the recovered lake again (idempotence).
    pub rerecovered_export: String,
    /// What the first recovery actually read.
    pub recovery: RecoveryStats,
    /// Deep integrity audit of the crashed, un-recovered directory.
    pub crashed_fsck: FsckReport,
    /// Deep integrity audit after the first recovery.
    pub recovered_fsck: FsckReport,
}

impl CrashOutcome {
    /// Assert the scenario's recovery contract: byte-identical export,
    /// and a second recovery that changes nothing.
    pub fn assert_byte_identical(&self) {
        assert_eq!(
            self.expected_export,
            self.recovered_export,
            "crash scenario '{}': recovered export diverged from pre-crash state",
            self.scenario.name()
        );
        assert_eq!(
            self.recovered_export,
            self.rerecovered_export,
            "crash scenario '{}': recovery is not idempotent",
            self.scenario.name()
        );
    }

    /// Assert the integrity-audit contract: the lake must audit clean
    /// (no error/warn findings) both in the crashed state — torn active
    /// tails are expected, info-severity residue — and after recovery.
    pub fn assert_fsck_clean(&self) {
        assert!(
            self.crashed_fsck.clean(),
            "crash scenario '{}': crashed lake audits unclean:\n{}",
            self.scenario.name(),
            self.crashed_fsck.render()
        );
        assert!(
            self.recovered_fsck.clean(),
            "crash scenario '{}': recovered lake audits unclean:\n{}",
            self.scenario.name(),
            self.recovered_fsck.render()
        );
    }
}

/// Journal tuning the matrix runs under: tiny segments so rotation and
/// retirement happen within a handful of commits, and a compaction
/// threshold the scenarios stay below unless they compact explicitly.
pub fn matrix_config() -> JournalConfig {
    JournalConfig {
        sync: SyncPolicy::EveryAppend,
        segment_bytes: 1500,
        compact_after_deltas: 64,
        sync_latency_micros: 0,
    }
}

/// A one-object snapshot whose object really exists in the store — the
/// integrity audit verifies every snapshot-referenced key resolves (and,
/// deep, that its bytes re-hash to the key), so fake keys would fail
/// the matrix's fsck assertions.
fn snap(cat: &Catalog, tag: &str) -> Snapshot {
    let key = cat.store().put(format!("crash matrix object {tag}").into_bytes());
    Snapshot::new(vec![key], "S", "fp", 1, "rw")
}

/// A workload touching every journaled op family: commits on two
/// branches, a tag, a (closed) transactional branch, a run record, and a
/// mid-stream delta checkpoint.
fn seed_workload(cat: &Catalog) -> Result<()> {
    for i in 0..4 {
        commit_table(cat, MAIN, &format!("t{i}"), snap(cat, &format!("m{i}")), "u", "seed", None)?;
    }
    cat.create_branch("dev", MAIN, false)?;
    commit_table(cat, "dev", "t0", snap(cat, "d0"), "u", "dev write", None)?;
    cat.tag("v1", MAIN)?;
    cat.create_txn_branch(MAIN, "r9")?;
    commit_table(cat, "txn/r9", "p", snap(cat, "x9"), "u", "txn write", Some("r9".into()))?;
    cat.set_branch_state("txn/r9", crate::catalog::BranchState::Aborted)?;
    cat.put_run_record("run_9", Json::obj(vec![("state", Json::str("aborted"))]))?;
    cat.checkpoint()?;
    // a journal tail above the checkpoint floor, so recovery always has
    // uncovered records to replay
    for i in 0..2 {
        commit_table(cat, MAIN, "tail", snap(cat, &format!("tl{i}")), "u", "tail", None)?;
    }
    Ok(())
}

/// Run one scenario in `dir` (wiped first). Returns the outcome; the
/// caller asserts.
pub fn run_scenario(dir: &Path, scenario: CrashScenario) -> Result<CrashOutcome> {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir)?;
    let config = match scenario {
        // the lost-window scenario needs an unsynced tail, so it runs
        // batched with a segment large enough that no rotation (which
        // syncs) lands mid-window
        CrashScenario::LostSyncWindow => JournalConfig {
            sync: SyncPolicy::Batch(10_000),
            segment_bytes: 1 << 20,
            ..matrix_config()
        },
        CrashScenario::Kill(_) => matrix_config(),
    };
    let cat = Catalog::open_durable_cfg(dir, config)?;
    seed_workload(&cat)?;

    let expected = match scenario {
        CrashScenario::Kill(point) => {
            cat.inject_crash_point(point);
            match point {
                CrashPoint::MidRecord => {
                    commit_table(&cat, MAIN, "doomed", snap(&cat, "doom"), "u", "m", None)
                        .expect_err("mid-record kill point must fail the commit");
                }
                CrashPoint::AtRotationSealed => {
                    // keep committing until a rotation is reached; with
                    // ~1.5 KiB segments that is a handful of commits
                    let mut tripped = false;
                    for i in 0..64 {
                        match commit_table(
                            &cat,
                            MAIN,
                            "rot",
                            snap(&cat, &format!("rot{i}")),
                            "u",
                            "m",
                            None,
                        ) {
                            Ok(_) => continue,
                            Err(_) => {
                                tripped = true;
                                break;
                            }
                        }
                    }
                    assert!(tripped, "rotation kill point never reached");
                }
                CrashPoint::MidDeltaFlush => {
                    commit_table(&cat, MAIN, "pend", snap(&cat, "pend"), "u", "m", None)?;
                    cat.checkpoint()
                        .expect_err("mid-delta-flush kill point must fail the checkpoint");
                }
                CrashPoint::MidCompactBase | CrashPoint::MidCompactRetire => {
                    cat.compact().expect_err("compaction kill point must fail the compact");
                }
            }
            // the failed operation must not be visible: whatever the
            // crashed process could still observe is what recovery owes us
            cat.export().to_string()
        }
        CrashScenario::LostSyncWindow => {
            cat.journal_sync()?;
            // acknowledged-up-to-here is the durable state…
            let durable = cat.export().to_string();
            // …then a burst of appends enqueued but never fsynced
            for i in 0..3 {
                commit_table(&cat, MAIN, "lost", snap(&cat, &format!("lost{i}")), "u", "m", None)?;
            }
            cat.debug_lose_unsynced_tail()?;
            durable
        }
    };
    drop(cat);

    // Audit the crashed directory before anyone repairs it: damage the
    // kill point left behind must be at worst info-severity residue
    // (torn active tail, orphan objects), never corruption.
    let crashed_fsck = fsck_path(dir, true)?;

    let recovered_cat = Catalog::open_durable_cfg(dir, config)?;
    let recovered = recovered_cat.export().to_string();
    let recovery = recovered_cat.recovery_stats().expect("recovered catalog is durable");
    drop(recovered_cat);

    let recovered_fsck = fsck_path(dir, true)?;

    let rerecovered_cat = Catalog::open_durable_cfg(dir, config)?;
    let rerecovered = rerecovered_cat.export().to_string();
    drop(rerecovered_cat);

    Ok(CrashOutcome {
        scenario,
        expected_export: expected,
        recovered_export: recovered,
        rerecovered_export: rerecovered,
        recovery,
        crashed_fsck,
        recovered_fsck,
    })
}

/// Run the whole matrix under `base_dir` (one subdirectory per scenario)
/// and return every outcome. Panics on I/O failure — the harness runs
/// inside tests.
pub fn run_crash_matrix(base_dir: &Path) -> Vec<CrashOutcome> {
    CrashScenario::all()
        .into_iter()
        .map(|s| {
            let dir: PathBuf = base_dir.join(s.name());
            run_scenario(&dir, s)
                .unwrap_or_else(|e| panic!("crash scenario '{}' errored: {e:?}", s.name()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_enumerates_every_kill_point() {
        let all = CrashScenario::all();
        assert_eq!(all.len(), CrashPoint::ALL.len() + 1);
        for p in CrashPoint::ALL {
            assert!(all.contains(&CrashScenario::Kill(p)));
        }
        assert!(all.contains(&CrashScenario::LostSyncWindow));
    }

    #[test]
    fn scenario_names_are_unique() {
        let mut names: Vec<&str> = CrashScenario::all().iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), CrashScenario::all().len());
    }
}
