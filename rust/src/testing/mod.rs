//! Deterministic property-testing harness.
//!
//! The offline crate set has no `proptest`, so we carry a small
//! deterministic generator framework: a splittable xorshift PRNG plus
//! `for_cases`, which runs a property over N seeded cases and reports the
//! failing seed — enough to express the coordinator invariants the paper's
//! claims rest on (catalog linearity, merge atomicity, run isolation).
//!
//! [`crash`] adds the reusable crash-matrix harness: it enumerates the
//! durability pipeline's kill points and proves byte-identical recovery at
//! each one.

pub mod crash;

use crate::catalog::{Catalog, CommitId, CommitRequest, RetryPolicy, Snapshot};
use crate::error::Result;

/// Test/bench convenience: unconditional publish on the current head,
/// with the pre-PR-9 `commit_table` signature. Product code builds a
/// [`CommitRequest`] and calls [`Catalog::commit`] directly.
pub fn commit_table(
    c: &Catalog,
    branch: &str,
    table: &str,
    snapshot: Snapshot,
    author: &str,
    message: &str,
    run_id: Option<String>,
) -> Result<CommitId> {
    c.commit(
        CommitRequest::new(branch, table, snapshot)
            .author(author)
            .message(message)
            .run_id(run_id)
            .retry(RetryPolicy::rebase()),
    )
    .map(|o| o.commit)
}

/// Test/bench convenience: strict CAS against `expected_head`, with the
/// pre-PR-9 `commit_table_cas` signature.
pub fn commit_table_cas(
    c: &Catalog,
    branch: &str,
    expected_head: &str,
    table: &str,
    snapshot: Snapshot,
    author: &str,
    message: &str,
    run_id: Option<String>,
) -> Result<CommitId> {
    c.commit(
        CommitRequest::new(branch, table, snapshot)
            .author(author)
            .message(message)
            .run_id(run_id)
            .expected_head(expected_head),
    )
    .map(|o| o.commit)
}

/// Test/bench convenience: optimistic rebase until the commit lands,
/// with the pre-PR-9 `commit_table_retrying` signature. Returns
/// `(commit id, conflict rounds survived)`.
pub fn commit_table_retrying(
    c: &Catalog,
    branch: &str,
    table: &str,
    snapshot: Snapshot,
    author: &str,
    message: &str,
    run_id: Option<String>,
) -> Result<(CommitId, u64)> {
    c.commit(
        CommitRequest::new(branch, table, snapshot)
            .author(author)
            .message(message)
            .run_id(run_id)
            .retry(RetryPolicy::rebase()),
    )
    .map(|o| (o.commit, o.retries))
}

/// xorshift64* — tiny, fast, deterministic; good enough for test-case
/// generation (NOT cryptographic).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.max(1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`; n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        (self.f32() as f64) < p_true
    }

    /// Pick an element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Independent child generator (for shrink-free case splitting).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed so a
/// failure is reproducible with `Rng::new(seed)`.
pub fn for_cases(cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 1..=cases {
        let mut rng = Rng::new(seed * 0x5DEE_CE66);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn for_cases_reports_seed() {
        let err = std::panic::catch_unwind(|| {
            for_cases(10, |rng| {
                // fails on some case
                assert!(rng.below(4) != 1, "boom");
            });
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed at seed"));
    }
}
