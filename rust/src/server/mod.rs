//! Zero-dependency lakehouse API server: the multi-tenant service
//! boundary in front of the vertically-integrated stack.
//!
//! The paper's programming model assumes many humans and agents issuing
//! concurrent branch/run operations against one shared catalog; the
//! agentic-lakehouse line of work (PAPERS.md) frames that as *untrusted
//! clients behind a checked API*. This module is that boundary, built
//! from `std` alone to keep the crate's zero-dependency rule:
//!
//! - [`http`] — a bounded HTTP/1.1 parser (keep-alive, `Content-Length`
//!   bodies, hard head/body size limits) and response writer;
//! - [`api`] — the JSON route table. Handlers call the exact same
//!   `Client`/`Catalog`/`Runner` methods as in-process callers, so a
//!   remote tenant inherits the catalog's optimistic-concurrency
//!   guarantees verbatim: the single write lock serializes commits, CAS
//!   conflicts come back as retryable 409s in one structured
//!   [`ApiError`](api::ApiError) shape;
//! - this file — connection lifecycle: a fixed worker pool accepts
//!   concurrent connections, each worker serving one keep-alive
//!   connection at a time; shutdown closes live connections and joins
//!   every thread (the simulator restarts servers mid-trace, so
//!   shutdown must be prompt and complete).
//!
//! The remote twin lives in `client/remote.rs` (`RemoteClient`), and the
//! PR 4 simulator drives the whole stack through it over a real TCP
//! loopback connection (`bauplan simulate --remote-loopback`), with all
//! oracles — refinement, Fig. 3, Fig. 4 guardrail, recovery idempotence
//! — required to stay green. Wire protocol and verification guide:
//! `doc/SERVER.md`.

pub mod api;
pub mod http;

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::client::Client;
use crate::error::Result;

pub use api::{api_error, render_prometheus, ApiError, ApiState};

/// Tunables for one server instance.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Fixed worker-pool size: how many connections are served
    /// concurrently (each worker owns one connection at a time).
    pub threads: usize,
    /// Socket read timeout; a keep-alive connection idle longer than
    /// this is closed, so a stalled client cannot pin a worker.
    pub read_timeout: Duration,
    /// Emit one canonical-JSON access-log line per request on stdout
    /// (`bauplan serve --access-log`). Off by default: the loopback
    /// simulator issues thousands of requests per seed.
    pub access_log: bool,
    /// Background integrity-auditor knobs. The auditor only runs when
    /// the server fronts a durable lake; on memory-only catalogs the
    /// config is inert.
    pub audit: crate::audit::online::AuditConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            threads: 8,
            read_timeout: Duration::from_secs(5),
            access_log: false,
            audit: crate::audit::online::AuditConfig::default(),
        }
    }
}

/// Live connections, tracked so shutdown can close them and unblock
/// the workers parked in blocking reads.
type Conns = Arc<Mutex<Vec<(u64, TcpStream)>>>;

static CONN_SEQ: AtomicU64 = AtomicU64::new(0);

/// The API server. [`Server::start`] returns a [`ServerHandle`]; the
/// server runs until the handle is shut down or dropped.
pub struct Server;

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// `client` on a fixed thread pool. Metrics land in the runner's
    /// registry, so one `/metrics` scrape covers server and engine.
    pub fn start(client: Client, addr: &str, config: ServerConfig) -> Result<ServerHandle> {
        let metrics = client.runner.metrics.clone();
        // Keep a handle on the flight recorder (and the lake directory,
        // when durable) so shutdown can persist the ring of recent
        // server/catalog spans — the post-mortem view of the last thing
        // this instance was doing.
        let flight = client.catalog.flight().clone();
        let flight_dir = client.catalog.durable_dir();
        // A durable lake gets the background integrity auditor: the
        // offline fsck walker on a budgeted cadence, exporting `audit.*`
        // metrics into the same registry this server serves. It shares
        // the flight recorder so error-severity findings dump the ring.
        let auditor = match (&flight_dir, config.audit.enabled) {
            (Some(dir), true) => Some(crate::audit::online::AuditorHandle::spawn(
                dir.clone(),
                config.audit.clone(),
                metrics.clone(),
                flight.clone(),
            )),
            _ => None,
        };
        let state = Arc::new(ApiState {
            client,
            metrics,
            started: std::time::Instant::now(),
            audit: auditor.as_ref().map(|a| a.shared()),
        });
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Conns = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let threads = config.threads.max(1);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = rx.clone();
            let state = state.clone();
            let stop = shutdown.clone();
            let conns = conns.clone();
            let cfg = config.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bauplan-http-{i}"))
                    .spawn(move || worker_loop(rx, state, stop, conns, cfg))?,
            );
        }
        let stop = shutdown.clone();
        let accept = std::thread::Builder::new()
            .name("bauplan-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        if tx.send(stream).is_err() {
                            break;
                        }
                    }
                }
                // dropping `tx` here unblocks every idle worker's recv()
            })?;
        Ok(ServerHandle {
            addr: local_addr,
            shutdown,
            conns,
            accept: Some(accept),
            workers,
            flight,
            flight_dir,
            auditor,
        })
    }
}

/// Handle onto a running server: its address and its shutdown switch.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    conns: Conns,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    flight: crate::trace::FlightRecorder,
    flight_dir: Option<std::path::PathBuf>,
    auditor: Option<crate::audit::online::AuditorHandle>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Base URL clients connect to (`http://host:port`).
    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting, close live connections, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block until the server stops (the `bauplan serve` foreground
    /// path — effectively forever, until the process is killed).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    fn stop(&mut self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Stop the background auditor first: it reads the lake directory
        // this shutdown is about to flight-dump into, and it must not
        // outlive the catalog the workers hold.
        if let Some(a) = &mut self.auditor {
            a.stop();
        }
        // poke the accept loop awake so it observes the flag ...
        let _ = TcpStream::connect(self.addr);
        // ... and close live connections so workers leave blocking reads
        for (_, s) in self.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Workers are parked, so the ring is quiescent: persist it as
        // the instance's final flight dump. Best-effort — shutdown must
        // succeed even on a read-only lake directory.
        if let Some(dir) = &self.flight_dir {
            let _ = self.flight.dump(dir, "server shutdown");
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<TcpStream>>>,
    state: Arc<ApiState>,
    stop: Arc<AtomicBool>,
    conns: Conns,
    cfg: ServerConfig,
) {
    loop {
        // the Mutex<Receiver> hand-off: one idle worker waits in recv at
        // a time; taking a connection releases the lock to the next
        let stream = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(s) => s,
                Err(_) => return, // sender dropped: shutting down
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let id = CONN_SEQ.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            conns.lock().unwrap().push((id, clone));
        }
        let _ = serve_connection(stream, &state, &cfg);
        conns.lock().unwrap().retain(|(i, _)| *i != id);
    }
}

/// Serve one (keep-alive) connection until it closes, errors, or sends
/// something the parser refuses — refusals get a structured error
/// response and a clean close, never a dead worker.
fn serve_connection(
    stream: TcpStream,
    state: &Arc<ApiState>,
    cfg: &ServerConfig,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(cfg.read_timeout)).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(http::ReadError::Closed) => return Ok(()),
            Err(http::ReadError::TooLarge) => {
                state.metrics.incr("server.http_413", 1);
                let body = ApiError {
                    status: 413,
                    code: "too_large".into(),
                    message: "request exceeds size limits".into(),
                    retryable: false,
                    details: None,
                }
                .to_json()
                .to_string();
                http::write_response(&mut writer, 413, "application/json", body.as_bytes(), false)?;
                return Ok(());
            }
            Err(http::ReadError::Malformed(m)) => {
                state.metrics.incr("server.http_400", 1);
                let body = ApiError {
                    status: 400,
                    code: "malformed_request".into(),
                    message: m,
                    retryable: false,
                    details: None,
                }
                .to_json()
                .to_string();
                http::write_response(&mut writer, 400, "application/json", body.as_bytes(), false)?;
                return Ok(());
            }
        };
        let keep = req.keep_alive;
        let t0 = std::time::Instant::now();
        let (status, bytes_out) = match api::handle(state, &req) {
            api::Reply::Json(status, j) => (
                status,
                http::write_response(
                    &mut writer,
                    status,
                    "application/json",
                    j.to_string().as_bytes(),
                    keep,
                )?,
            ),
            api::Reply::Text(status, t) => (
                status,
                http::write_response(&mut writer, status, "text/plain", t.as_bytes(), keep)?,
            ),
            api::Reply::Bytes(status, b) => (
                status,
                http::write_response(&mut writer, status, "application/octet-stream", &b, keep)?,
            ),
            api::Reply::Frames(status, frames) => {
                // Streamed in deadline-checked chunks; the returned byte
                // count is what actually hit the wire, so the access log
                // stays truthful for chunked bodies too.
                let refs: Vec<&[u8]> = frames.iter().map(|f| &**f).collect();
                (
                    status,
                    http::write_frame_response(
                        &mut writer,
                        status,
                        "application/x-bauplan-frames",
                        &refs,
                        keep,
                    )?,
                )
            }
        };
        if cfg.access_log {
            println!("{}", access_log_line(&req, status, t0.elapsed().as_micros() as u64, bytes_out));
        }
        if !keep {
            return Ok(());
        }
    }
}

/// One access-log record as canonical JSON: timestamp, wire trace id
/// (when the client sent one), method/path, status, handling latency,
/// and bytes both ways. One line per request, machine-parseable — the
/// structured replacement for ad-hoc request printing.
fn access_log_line(req: &http::Request, status: u16, duration_us: u64, bytes_out: u64) -> String {
    use crate::util::json::Json;
    Json::obj(vec![
        ("ts_us", Json::num(crate::util::now_micros() as f64)),
        ("trace", req.trace.as_ref().map(Json::str).unwrap_or(Json::Null)),
        ("method", Json::str(&req.method)),
        ("path", Json::str(&req.path)),
        ("status", Json::num(status as f64)),
        ("duration_us", Json::num(duration_us as f64)),
        ("bytes_in", Json::num(req.body.len() as f64)),
        ("bytes_out", Json::num(bytes_out as f64)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_log_lines_are_canonical_json() {
        let req = http::Request {
            method: "POST".into(),
            path: "/v1/runs".into(),
            query: vec![],
            keep_alive: true,
            body: b"{\"project\":\"x\"}".to_vec(),
            trace: Some("tr_1:sp_2".into()),
        };
        let line = access_log_line(&req, 200, 1500, 64);
        let j = crate::util::json::Json::parse(&line).expect("access log line parses");
        assert_eq!(j.get("method").as_str(), Some("POST"));
        assert_eq!(j.get("path").as_str(), Some("/v1/runs"));
        assert_eq!(j.get("trace").as_str(), Some("tr_1:sp_2"));
        assert_eq!(j.get("status").as_usize(), Some(200));
        assert_eq!(j.get("duration_us").as_usize(), Some(1500));
        assert_eq!(j.get("bytes_in").as_usize(), Some(15));
        assert_eq!(j.get("bytes_out").as_usize(), Some(64));
        assert!(j.get("ts_us").as_f64().is_some());
    }

    #[test]
    fn absent_trace_logs_as_null() {
        let req = http::Request {
            method: "GET".into(),
            path: "/healthz".into(),
            query: vec![],
            keep_alive: false,
            body: vec![],
            trace: None,
        };
        let line = access_log_line(&req, 200, 10, 5);
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert!(matches!(j.get("trace"), &crate::util::json::Json::Null));
        assert_eq!(j.get("bytes_in").as_usize(), Some(0));
    }
}
