//! The JSON API: route table, handlers, and the structured error shape.
//!
//! Every handler is a thin adapter from wire JSON onto the exact same
//! `Client`/`Catalog`/`Runner` calls in-process callers make — the
//! server adds *no* semantics of its own, so a remote tenant gets the
//! identical optimistic-concurrency and visibility guarantees (the
//! catalog's per-branch OCC critical section is the serialization
//! point, exactly as for threads sharing a `Catalog`; see
//! `doc/CONCURRENCY.md`).
//!
//! Errors cross the wire as **one** canonical shape
//! (`{"error": {code, message, retryable, details?}}`), produced by
//! [`api_error`] from [`BauplanError`]. `retryable` is the contract
//! with clients: `true` means the request may be retried safely *after
//! refreshing observed state* — today that is exactly the CAS-conflict
//! 409, whose details carry `branch` / `expected_head` / `actual_head`
//! so `RemoteClient::commit`'s informed loop can rebase onto the live
//! head without an extra read (legacy `reference` / `expected` /
//! `found` keys ride along for older clients). `details` carries each
//! variant's structured payload so a client can reconstruct the
//! original error (see `client/remote.rs::decode_error`).

use crate::catalog::{persist, CommitRequest, RetryPolicy, Snapshot, TableDiff};
use crate::client::Client;
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::runs::{FailurePlan, RunMode, RunState, Verifier};
use crate::server::http::Request;
use crate::storage::object_store::valid_object_key;
use crate::util::json::Json;

/// Shared state behind every connection: the full in-process stack plus
/// the metrics registry (`/metrics` renders it; the server's own
/// `server.*` counters land in the same registry as the runner's).
pub struct ApiState {
    /// The vertically-integrated lakehouse the server fronts.
    pub client: Client,
    /// Shared metrics registry (the runner's, so one scrape sees all).
    pub metrics: std::sync::Arc<Metrics>,
    /// Instance start time; `/v1/status` and the `bauplan_uptime_seconds`
    /// gauge report seconds since this instant.
    pub started: std::time::Instant,
    /// Background-auditor state when the server fronts a durable lake
    /// with auditing enabled; `/v1/status` embeds its summary and
    /// `/v1/admin/fsck` serves its latest full report.
    pub audit: Option<std::sync::Arc<crate::audit::online::AuditShared>>,
}

/// One response, by content type.
pub enum Reply {
    /// `application/json`.
    Json(u16, Json),
    /// `text/plain` (the `/metrics` endpoint).
    Text(u16, String),
    /// `application/octet-stream` (raw object reads). Holds the block
    /// cache's shared handle so serving an object is zero-copy.
    Bytes(u16, std::sync::Arc<[u8]>),
    /// `application/x-bauplan-frames` — a length-prefixed frame stream
    /// (see `server::http::write_frame_response`). Frame 0 is JSON
    /// metadata; later frames are raw codec objects, passed through as
    /// the store's shared handles without copying.
    Frames(u16, Vec<std::sync::Arc<[u8]>>),
}

/// The structured error every non-2xx response carries.
#[derive(Debug, Clone)]
pub struct ApiError {
    /// HTTP status to respond with.
    pub status: u16,
    /// Stable machine-readable code (`cas_conflict`, `unknown_ref`, ...).
    pub code: String,
    /// Human-readable rendering (the `BauplanError` display).
    pub message: String,
    /// May the client retry after refreshing observed state?
    pub retryable: bool,
    /// Variant payload for client-side error reconstruction.
    pub details: Option<Json>,
}

impl ApiError {
    /// The canonical wire shape.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::str(&self.code)),
            ("message", Json::str(&self.message)),
            ("retryable", Json::Bool(self.retryable)),
        ];
        if let Some(d) = &self.details {
            fields.push(("details", d.clone()));
        }
        Json::obj(vec![("error", Json::obj(fields))])
    }
}

/// Map a [`BauplanError`] onto the one wire error shape. CAS conflicts
/// are the only retryable class: the 409's details hand the losing
/// writer the live head (`actual_head`), so its next round is a rebase,
/// not a blind resubmit — the wire half of the catalog's informed OCC
/// loop.
pub fn api_error(e: &BauplanError) -> ApiError {
    use BauplanError::*;
    let (status, code, retryable, details) = match e {
        UnknownRef(r) => (404, "unknown_ref", false, Some(detail_str("ref", r))),
        RefExists(r) => (409, "ref_exists", false, Some(detail_str("ref", r))),
        CasConflict { reference, expected, found } => (
            409,
            "cas_conflict",
            true,
            // Both key generations: PR 9 names first, pre-PR-9 names
            // alongside so older clients keep decoding.
            Some(Json::obj(vec![
                ("branch", Json::str(reference)),
                ("expected_head", Json::str(expected)),
                ("actual_head", Json::str(found)),
                ("reference", Json::str(reference)),
                ("expected", Json::str(expected)),
                ("found", Json::str(found)),
            ])),
        ),
        MergeConflict(m) => (409, "merge_conflict", false, Some(detail_str("message", m))),
        Visibility(m) => (403, "visibility", false, Some(detail_str("message", m))),
        ContractLocal(_) | ContractPlan(_) | ContractRuntime(_) => (422, "contract", false, None),
        RunFailed { .. } => (422, "run_failed", false, None),
        RunAborted(_) => (422, "run_aborted", false, None),
        ObjectNotFound(k) => (404, "object_not_found", false, Some(detail_str("key", k))),
        TableNotFound(t) => (404, "table_not_found", false, Some(detail_str("table", t))),
        Parse(_) | Dag(_) => (400, "parse", false, None),
        Poisoned(m) => (503, "poisoned", false, Some(detail_str("message", m))),
        Io(_) => (500, "io", false, None),
        _ => (500, "internal", false, None),
    };
    ApiError {
        status,
        code: code.to_string(),
        message: e.to_string(),
        retryable,
        details,
    }
}

fn detail_str(key: &str, value: &str) -> Json {
    Json::obj(vec![(key, Json::str(value))])
}

/// Dispatch one request; never panics across the wire — every error
/// becomes the canonical JSON error shape. Every request also leaves a
/// `server.request` span in the catalog's flight recorder, so the last
/// N requests (method, path, status, wire trace id) are part of any
/// flight dump — the "what was the server doing just before it
/// poisoned" evidence.
pub fn handle(state: &ApiState, req: &Request) -> Reply {
    let mut fs = state.client.catalog.flight().begin("server.request");
    fs.attr_str("method", &req.method);
    fs.attr_str("path", &req.path);
    if let Some(t) = &req.trace {
        fs.attr_str("trace", t.as_str());
    }
    let reply = handle_inner(state, req);
    let status = match &reply {
        Reply::Json(s, _) | Reply::Text(s, _) | Reply::Bytes(s, _) | Reply::Frames(s, _) => *s,
    };
    fs.attr_u64("status", status as u64);
    if status >= 500 {
        fs.fail(format!("status {status}"));
    }
    reply
}

fn handle_inner(state: &ApiState, req: &Request) -> Reply {
    state.metrics.incr("server.requests", 1);
    // A poisoned catalog (group-commit fsync failure after a mutation was
    // applied) serves nothing but /metrics and the flight-recorder dump:
    // its in-memory state may be ahead of what the journal can reproduce,
    // so readers must not keep acting on it. 503 on every other route —
    // including /healthz, so load balancers drain the instance — until
    // the operator restarts the server (which recovers the lake from the
    // journal). /v1/trace/flight stays up because the ring of recent
    // spans is exactly the evidence an operator wants from a poisoned
    // server. /v1/status is the readiness probe: it must keep answering
    // (reporting `poisoned: true`) so operators can distinguish "drained
    // because poisoned" from "dead".
    let exempt = req.method == "GET"
        && (req.path == "/metrics"
            || req.path == "/v1/trace/flight"
            || req.path == "/v1/status");
    if state.client.catalog.is_poisoned() && !exempt {
        state.metrics.incr("server.errors", 1);
        let ae = api_error(&BauplanError::Poisoned(
            "a group-commit fsync failed; restart the server to recover".into(),
        ));
        return Reply::Json(ae.status, ae.to_json());
    }
    match route(state, req) {
        Ok(reply) => reply,
        Err(e) => {
            state.metrics.incr("server.errors", 1);
            let ae = api_error(&e);
            Reply::Json(ae.status, ae.to_json())
        }
    }
}

fn ok(j: Json) -> Result<Reply> {
    Ok(Reply::Json(200, j))
}

fn need_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .as_str()
        .ok_or_else(|| BauplanError::Parse(format!("missing or non-string field '{key}'")))
}

/// JSON body of one branch (the wire twin of `BranchInfo`).
pub fn branch_json(b: &crate::catalog::BranchInfo) -> Json {
    Json::obj(vec![
        ("name", Json::str(&b.name)),
        ("head", Json::str(&b.head)),
        ("state", Json::str(persist::branch_state_str(b.state))),
        ("transactional", Json::Bool(b.transactional)),
        ("owner_run", b.owner_run.as_ref().map(Json::str).unwrap_or(Json::Null)),
    ])
}

fn commit_json(c: &crate::catalog::Commit) -> Json {
    Json::obj(vec![("id", Json::str(&c.id)), ("commit", persist::commit_to_json(c))])
}

fn diff_json(d: &TableDiff) -> Json {
    match d {
        TableDiff::Added(t, s) => Json::obj(vec![
            ("kind", Json::str("added")),
            ("table", Json::str(t)),
            ("to", Json::str(s)),
        ]),
        TableDiff::Removed(t, s) => Json::obj(vec![
            ("kind", Json::str("removed")),
            ("table", Json::str(t)),
            ("from", Json::str(s)),
        ]),
        TableDiff::Changed { table, from, to } => Json::obj(vec![
            ("kind", Json::str("changed")),
            ("table", Json::str(table)),
            ("from", Json::str(from)),
            ("to", Json::str(to)),
        ]),
    }
}

/// Terminal run state as wire JSON (`run_state_to_json` + the run id).
pub fn run_json(s: &RunState) -> Json {
    let mut j = crate::runs::run_state_to_json(s);
    if let Json::Obj(o) = &mut j {
        o.insert("run_id".into(), Json::str(&s.run_id));
    }
    j
}

/// One decoded batch as wire JSON — the `format=json` comparison path
/// of the table-data route. Columns become number arrays (plus the
/// per-column null mask when present) and the batch keeps its valid
/// mask, so a client can reconstruct the exact `Batch`.
fn batch_json(b: &crate::storage::Batch) -> Json {
    use crate::storage::ColumnData;
    fn nums_f32(v: &[f32]) -> Json {
        // Non-finite values have no JSON literal; they ship as null.
        // The binary frame path is the exact one — this is a baseline.
        Json::Arr(
            v.iter()
                .map(|x| if x.is_finite() { Json::num(*x as f64) } else { Json::Null })
                .collect(),
        )
    }
    let cols = b
        .columns
        .iter()
        .map(|col| {
            let values = match &col.data {
                ColumnData::F32(v) => nums_f32(v),
                ColumnData::I32(v) => {
                    Json::Arr(v.iter().map(|x| Json::num(*x as f64)).collect())
                }
            };
            let kind = match &col.data {
                ColumnData::F32(_) => "f32",
                ColumnData::I32(_) => "i32",
            };
            let mut fields = vec![
                ("name", Json::str(&col.name)),
                ("kind", Json::str(kind)),
                ("values", values),
            ];
            if let Some(m) = &col.nulls {
                fields.push(("nulls", nums_f32(m)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![("columns", Json::Arr(cols)), ("valid", nums_f32(&b.valid))])
}

/// Bridge the object store's block-cache atomics into the shared
/// registry as `store.*` absolute counters, right before a scrape, and
/// hand the snapshot back for gauge lines `Metrics` can't carry.
fn sync_store_metrics(state: &ApiState) -> crate::storage::CacheStats {
    let s = state.client.catalog.store().cache_stats();
    state.metrics.set("store.cache_hits", s.hits);
    state.metrics.set("store.cache_misses", s.misses);
    state.metrics.set("store.cache_evicted_bytes", s.evicted_bytes);
    state.metrics.set("store.cache_bytes", s.cached_bytes);
    state.metrics.set("store.cache_entries", s.entries);
    s
}

/// `GET /v1/status` — the readiness document: build identity, uptime,
/// the poisoned flag (this route answers even when poisoned, unlike
/// `/healthz`), how the lake was recovered, and the background
/// auditor's rolled-up verdict. `doc/SERVER.md` contrasts this with
/// the `/healthz` liveness probe.
fn status_json(state: &ApiState) -> Json {
    let catalog = &state.client.catalog;
    let recovery = match catalog.recovery_stats() {
        Some(r) => Json::obj(vec![
            ("segments_scanned", Json::num(r.segments_scanned as f64)),
            ("segments_skipped", Json::num(r.segments_skipped as f64)),
            ("records_replayed", Json::num(r.records_replayed as f64)),
            ("bytes_scanned", Json::num(r.bytes_scanned as f64)),
            ("base_seq", Json::num(r.base_seq as f64)),
            ("deltas_loaded", Json::num(r.deltas_loaded as f64)),
        ]),
        None => Json::Null,
    };
    let audit = match &state.audit {
        Some(a) => a.summary_json(),
        None => Json::Null,
    };
    let poisoned = catalog.is_poisoned();
    Json::obj(vec![
        ("ok", Json::Bool(!poisoned)),
        ("version", Json::str(env!("CARGO_PKG_VERSION"))),
        ("uptime_seconds", Json::num(state.started.elapsed().as_secs() as f64)),
        ("poisoned", Json::Bool(poisoned)),
        ("durable", Json::Bool(catalog.durable_dir().is_some())),
        ("recovery", recovery),
        ("audit", audit),
    ])
}

fn route(state: &ApiState, req: &Request) -> Result<Reply> {
    let c = &state.client;
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => ok(Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", ["v1", "status"]) => ok(status_json(state)),
        ("GET", ["metrics"]) => {
            let cache = sync_store_metrics(state);
            let mut text = render_prometheus(&state.metrics);
            text.push_str(&format!(
                "# TYPE bauplan_store_cache_hit_rate gauge\nbauplan_store_cache_hit_rate {}\n",
                cache.hit_rate()
            ));
            // Build/uptime identity gauges, appended the same way as the
            // hit-rate line: `Metrics` carries only u64 counters, and
            // the version label belongs on a constant `_info`-style
            // series, not in a metric name.
            text.push_str(&format!(
                "# TYPE bauplan_build_info gauge\nbauplan_build_info{{version=\"{}\"}} 1\n",
                env!("CARGO_PKG_VERSION")
            ));
            text.push_str(&format!(
                "# TYPE bauplan_uptime_seconds gauge\nbauplan_uptime_seconds {}\n",
                state.started.elapsed().as_secs()
            ));
            Ok(Reply::Text(200, text))
        }
        ("GET", ["v1", "metrics", "json"]) => {
            sync_store_metrics(state);
            ok(state.metrics.snapshot_json())
        }
        ("GET", ["v1", "export"]) => ok(c.catalog.export()),

        // ---------------------------------------------------- tracing
        ("GET", ["v1", "trace", "flight"]) => ok(c.catalog.flight().to_json()),
        ("GET", ["v1", "trace", run_id]) => match c.catalog.get_run_trace(run_id) {
            Some(t) => ok(t),
            None => Err(BauplanError::ObjectNotFound(format!("trace for run {run_id}"))),
        },

        // ---------------------------------------------------- branches
        ("GET", ["v1", "branches"]) => {
            let branches: Vec<Json> = c.catalog.list_branches().iter().map(branch_json).collect();
            ok(Json::obj(vec![("branches", Json::Arr(branches))]))
        }
        ("POST", ["v1", "branches"]) => {
            let b = req.json()?;
            let allow = b.get("allow_aborted").as_bool().unwrap_or(false);
            let info =
                c.catalog.create_branch(need_str(&b, "name")?, need_str(&b, "from")?, allow)?;
            ok(branch_json(&info))
        }
        ("POST", ["v1", "txn-branches"]) => {
            let b = req.json()?;
            let info =
                c.catalog.create_txn_branch(need_str(&b, "target")?, need_str(&b, "run_id")?)?;
            ok(branch_json(&info))
        }
        ("POST", ["v1", "branches", rest @ ..]) if rest.last() == Some(&"state") => {
            let name = rest[..rest.len() - 1].join("/");
            let b = req.json()?;
            let new_state = persist::parse_branch_state(need_str(&b, "state")?)?;
            c.catalog.set_branch_state(&name, new_state)?;
            ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", ["v1", "branches", rest @ ..]) if !rest.is_empty() => {
            ok(branch_json(&c.catalog.branch_info(&rest.join("/"))?))
        }
        ("DELETE", ["v1", "branches", rest @ ..]) if !rest.is_empty() => {
            c.catalog.delete_branch(&rest.join("/"))?;
            ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }

        // ---------------------------------------------------- merge ops
        ("POST", ["v1", "merge"]) => {
            let b = req.json()?;
            let allow = b.get("allow_aborted").as_bool().unwrap_or(false);
            let id = c.catalog.merge(need_str(&b, "src")?, need_str(&b, "dst")?, allow)?;
            ok(Json::obj(vec![("commit", Json::str(id))]))
        }
        ("POST", ["v1", "rebase"]) => {
            let b = req.json()?;
            let id = c.catalog.rebase(need_str(&b, "branch")?, need_str(&b, "onto")?)?;
            ok(Json::obj(vec![("commit", Json::str(id))]))
        }
        ("POST", ["v1", "cherry-pick"]) => {
            let b = req.json()?;
            let id = c.catalog.cherry_pick(need_str(&b, "commit_ref")?, need_str(&b, "onto")?)?;
            ok(Json::obj(vec![("commit", Json::str(id))]))
        }
        ("POST", ["v1", "tags"]) => {
            let b = req.json()?;
            let id = c.catalog.tag(need_str(&b, "name")?, need_str(&b, "target")?)?;
            ok(Json::obj(vec![("commit", Json::str(id))]))
        }

        // ---------------------------------------------------- reads
        ("GET", ["v1", "refs", rest @ ..]) if !rest.is_empty() => {
            ok(commit_json(&c.catalog.read_ref(&rest.join("/"))?))
        }
        ("GET", ["v1", "log", rest @ ..]) if !rest.is_empty() => {
            let limit = req.query_param("limit").and_then(|s| s.parse().ok()).unwrap_or(50);
            let commits: Vec<Json> =
                c.catalog.log(&rest.join("/"), limit)?.iter().map(commit_json).collect();
            ok(Json::obj(vec![("commits", Json::Arr(commits))]))
        }
        ("GET", ["v1", "diff"]) => {
            let from = req
                .query_param("from")
                .ok_or_else(|| BauplanError::Parse("diff: missing 'from'".into()))?;
            let to = req
                .query_param("to")
                .ok_or_else(|| BauplanError::Parse("diff: missing 'to'".into()))?;
            let diffs: Vec<Json> = c.catalog.diff(from, to)?.iter().map(diff_json).collect();
            ok(Json::obj(vec![("diffs", Json::Arr(diffs))]))
        }
        ("GET", ["v1", "table"]) => {
            let r = req
                .query_param("ref")
                .ok_or_else(|| BauplanError::Parse("table: missing 'ref'".into()))?;
            let name = req
                .query_param("name")
                .ok_or_else(|| BauplanError::Parse("table: missing 'name'".into()))?;
            let commit = c.catalog.read_ref(r)?;
            let snap_id = commit
                .tables
                .get(name)
                .ok_or_else(|| BauplanError::TableNotFound(name.to_string()))?;
            let snap = c.catalog.get_snapshot(snap_id)?;
            let bytes: u64 = snap
                .objects
                .iter()
                .filter_map(|o| c.catalog.store().object_size(o))
                .sum();
            let mut j = persist::snapshot_to_json(&snap);
            if let Json::Obj(o) = &mut j {
                o.insert("snapshot_id".into(), Json::str(&snap.id));
                o.insert("bytes".into(), Json::num(bytes as f64));
            }
            ok(j)
        }
        ("GET", ["v1", "table", name, "data"]) => {
            let r = req
                .query_param("ref")
                .ok_or_else(|| BauplanError::Parse("table data: missing 'ref'".into()))?;
            let commit = c.catalog.read_ref(r)?;
            let snap_id = commit
                .tables
                .get(*name)
                .ok_or_else(|| BauplanError::TableNotFound(name.to_string()))?;
            let snap = c.catalog.get_snapshot(snap_id)?;
            let meta = Json::obj(vec![
                ("table", Json::str(*name)),
                ("schema_name", Json::str(&snap.schema_name)),
                ("snapshot_id", Json::str(&snap.id)),
                ("rows", Json::num(snap.row_count as f64)),
                ("objects", Json::num(snap.objects.len() as f64)),
            ]);
            if req.query_param("format") == Some("json") {
                // The pre-framing read path, kept as the comparison
                // baseline: every batch decoded server-side and shipped
                // as JSON number arrays. bench_server measures it
                // against the frame stream below.
                let mut batches = Vec::with_capacity(snap.objects.len());
                for key in &snap.objects {
                    let b = crate::storage::codec::decode_batch(&c.catalog.store().get(key)?)?;
                    batches.push(batch_json(&b));
                }
                return ok(Json::obj(vec![
                    ("meta", meta),
                    ("batches", Json::Arr(batches)),
                ]));
            }
            let mut frames: Vec<std::sync::Arc<[u8]>> =
                Vec::with_capacity(snap.objects.len() + 1);
            frames.push(meta.to_string().into_bytes().into());
            for key in &snap.objects {
                frames.push(c.catalog.store().get(key)?);
            }
            Ok(Reply::Frames(200, frames))
        }
        ("GET", ["v1", "objects", key]) => {
            if !valid_object_key(key) {
                return Err(BauplanError::ObjectNotFound(format!("invalid object key {key:?}")));
            }
            Ok(Reply::Bytes(200, c.catalog.store().get(key)?))
        }
        ("POST", ["v1", "objects"]) => {
            let b = req.json()?;
            let key = c.catalog.store().put(need_str(&b, "content")?.as_bytes().to_vec());
            ok(Json::obj(vec![("key", Json::str(key))]))
        }

        // ---------------------------------------------------- writes
        ("POST", ["v1", "commit"]) => handle_commit(state, req),
        ("POST", ["v1", "seed"]) => {
            let b = req.json()?;
            let branch = need_str(&b, "branch")?;
            let batches = b.get("batches").as_usize().unwrap_or(2);
            let rows = b.get("rows").as_usize().unwrap_or(200);
            c.seed_raw_table(branch, batches, rows)?;
            ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }

        // ---------------------------------------------------- runs
        ("POST", ["v1", "runs"]) => handle_run(state, req),
        ("GET", ["v1", "runs", id]) => match c.runner.get_run(id) {
            Some(s) => ok(run_json(&s)),
            None => Err(BauplanError::ObjectNotFound(format!("run {id}"))),
        },

        // ---------------------------------------------------- admin
        ("GET", ["v1", "cache", "stats"]) => {
            let j = match c.runner.cache() {
                Some(cache) => {
                    let s = cache.stats();
                    Json::obj(vec![
                        ("attached", Json::Bool(true)),
                        ("entries", Json::num(s.entries as f64)),
                        ("total_bytes", Json::num(s.total_bytes as f64)),
                        ("hits", Json::num(s.hits as f64)),
                        ("misses", Json::num(s.misses as f64)),
                        ("populated", Json::num(s.populated as f64)),
                        ("evictions", Json::num(s.evictions as f64)),
                        ("bytes_saved", Json::num(s.bytes_saved as f64)),
                    ])
                }
                None => Json::obj(vec![("attached", Json::Bool(false))]),
            };
            ok(j)
        }
        ("POST", ["v1", "admin", "checkpoint"]) => {
            let seq = c.catalog.checkpoint()?;
            ok(Json::obj(vec![("seq", Json::num(seq as f64))]))
        }
        ("POST", ["v1", "admin", "compact"]) => {
            let seq = c.catalog.compact()?;
            ok(Json::obj(vec![("seq", Json::num(seq as f64))]))
        }
        ("GET", ["v1", "admin", "fsck"]) => {
            // Prefer the background auditor's latest report (free); fall
            // back to a synchronous shallow online walk for servers that
            // run with auditing disabled. Memory-only lakes have no
            // on-disk structure to audit.
            if let Some(report) = state.audit.as_ref().and_then(|a| a.last_report_json()) {
                return ok(report);
            }
            let dir = c.catalog.durable_dir().ok_or_else(|| {
                BauplanError::Other("fsck: server is not backed by a durable lake".into())
            })?;
            let opts = crate::audit::FsckOptions { online: true, ..Default::default() };
            ok(crate::audit::fsck(&dir, &opts)?.to_json())
        }
        ("POST", ["v1", "admin", "gc"]) => {
            let (commits, snapshots, objects, bytes) = c.catalog.gc()?;
            ok(Json::obj(vec![
                ("commits", Json::num(commits as f64)),
                ("snapshots", Json::num(snapshots as f64)),
                ("objects", Json::num(objects as f64)),
                ("bytes", Json::num(bytes as f64)),
            ]))
        }

        _ => Err(BauplanError::ObjectNotFound(format!(
            "no route for {} {}",
            req.method, req.path
        ))),
    }
}

/// `POST /v1/commit` — one table commit through the same
/// [`CommitRequest`] API as in-process callers: with `expected_head`
/// pinned it is a strict CAS (conflicts come back as enriched,
/// retryable 409s carrying the live head); without, the server runs
/// the catalog's informed rebase loop itself.
fn handle_commit(state: &ApiState, req: &Request) -> Result<Reply> {
    let c = &state.client;
    let b = req.json()?;
    let branch = need_str(&b, "branch")?;
    let table = need_str(&b, "table")?;
    let content = need_str(&b, "content")?;
    let schema = b.get("schema").as_str().unwrap_or("RemoteTable");
    let fingerprint = b.get("fingerprint").as_str().unwrap_or("remote_fp");
    let rows = b.get("rows").as_f64().unwrap_or(1.0) as u64;
    let snap_run = b.get("snap_run_id").as_str().unwrap_or("remote");
    let author = b.get("author").as_str().unwrap_or("remote");
    let default_message = format!("write {table}");
    let message = b.get("message").as_str().unwrap_or(&default_message);
    let run_id = b.get("run_id").as_str().map(String::from);
    let key = c.catalog.store().put(content.as_bytes().to_vec());
    let snap = Snapshot::new(vec![key], schema, fingerprint, rows, snap_run);
    let mut request = CommitRequest::new(branch, table, snap)
        .author(author)
        .message(message)
        .run_id(run_id);
    request = match b.get("expected_head").as_str() {
        Some(expected) => request.expected_head(expected),
        None => request.retry(RetryPolicy::rebase()),
    };
    let out = c.catalog.commit(request)?;
    state.metrics.incr("server.commits", 1);
    ok(Json::obj(vec![
        ("commit", Json::str(out.commit)),
        ("snapshot", Json::str(out.snapshot)),
        ("cas_retries", Json::num(out.retries as f64)),
    ]))
}

/// `POST /v1/runs` — plan + execute a pipeline project text with the
/// full transactional protocol, exactly like `Client::run_text`, plus
/// the serializable fault/verifier knobs the simulator exercises.
fn handle_run(state: &ApiState, req: &Request) -> Result<Reply> {
    let c = &state.client;
    let b = req.json()?;
    let project = need_str(&b, "project")?;
    let branch = need_str(&b, "branch")?;
    let mode = match b.get("mode").as_str().unwrap_or("transactional") {
        "transactional" => RunMode::Transactional,
        "direct_write" => RunMode::DirectWrite,
        other => return Err(BauplanError::Parse(format!("unknown run mode '{other}'"))),
    };
    let jobs = b.get("jobs").as_usize().unwrap_or(1).max(1);
    let plan = c.control_plane.plan_from_text(project)?;
    let fj = b.get("fault");
    let failure = match fj.get("point").as_str() {
        None => FailurePlan::none(),
        Some(point) => {
            let node = need_str(fj, "node")?;
            match point {
                "crash_before" => FailurePlan::crash_before(node),
                "crash_after" => FailurePlan::crash_after(node),
                other => {
                    return Err(BauplanError::Parse(format!(
                        "unsupported fault point '{other}' (process-level faults \
                         cannot ride the wire)"
                    )))
                }
            }
        }
    };
    let mut verifiers: Vec<Verifier> = Vec::new();
    let vj = b.get("min_rows");
    if let Some(table) = vj.get("table").as_str() {
        let rows = vj.get("rows").as_f64().unwrap_or(0.0) as usize;
        verifiers.push(Verifier::min_rows(table, rows));
    }
    let mut runner = c.runner.clone().with_jobs(jobs);
    if b.get("no_cache").as_bool().unwrap_or(false) {
        runner = runner.without_cache();
    }
    // If the client sent an `x-bauplan-trace` header, the server-side
    // run trace continues that context: same trace id, run root parented
    // under the caller's span. A malformed header is ignored rather than
    // rejected — tracing must never fail a run.
    let ctx = req.trace.as_deref().and_then(crate::trace::TraceCtx::parse);
    let run_id = match b.get("run_id").as_str() {
        Some(rid) => rid.to_string(),
        None => crate::util::id::unique_id("run"),
    };
    let run_state =
        runner.run_traced(&plan, branch, mode, &failure, &verifiers, &run_id, ctx.as_ref())?;
    state.metrics.incr("server.runs", 1);
    ok(run_json(&run_state))
}

/// Render the metrics registry in Prometheus text exposition format:
/// counters as counters, histograms as native Prometheus histograms —
/// cumulative `_bucket{le="..."}` series (ending in `le="+Inf"`) plus
/// the `_sum` / `_count` pair, so `histogram_quantile()` works against
/// a scrape. The CLI keeps its precomputed p50/p99 view via
/// [`Metrics::snapshot_json`]; this endpoint ships the raw buckets.
pub fn render_prometheus(m: &Metrics) -> String {
    let mut out = String::new();
    for (name, v) in m.all_counters() {
        let n = prom_name(&name);
        out.push_str(&format!("# TYPE bauplan_{n} counter\nbauplan_{n} {v}\n"));
    }
    for (name, h) in m.all_histogram_handles() {
        let n = prom_name(&name);
        out.push_str(&format!("# TYPE bauplan_{n} histogram\n"));
        let mut cumulative = 0u64;
        for (bound, c) in
            crate::metrics::Histogram::bucket_bounds_us().iter().zip(h.bucket_counts())
        {
            cumulative += c;
            out.push_str(&format!("bauplan_{n}_bucket{{le=\"{bound}\"}} {cumulative}\n"));
        }
        // The overflow slot folds into +Inf, which by construction
        // equals _count.
        out.push_str(&format!("bauplan_{n}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
        out.push_str(&format!("bauplan_{n}_sum {}\n", h.sum_us()));
        out.push_str(&format!("bauplan_{n}_count {}\n", h.count()));
    }
    out
}

fn prom_name(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn api_error_maps_the_failure_taxonomy() {
        let e = api_error(&BauplanError::CasConflict {
            reference: "main".into(),
            expected: "a".into(),
            found: "b".into(),
        });
        assert_eq!((e.status, e.code.as_str(), e.retryable), (409, "cas_conflict", true));
        let d = e.details.unwrap();
        // PR 9 enriched keys — what informed clients rebase on...
        assert_eq!(d.get("branch").as_str(), Some("main"));
        assert_eq!(d.get("expected_head").as_str(), Some("a"));
        assert_eq!(d.get("actual_head").as_str(), Some("b"));
        // ...and the pre-PR-9 names still ride along for old clients.
        assert_eq!(d.get("reference").as_str(), Some("main"));
        assert_eq!(d.get("expected").as_str(), Some("a"));
        assert_eq!(d.get("found").as_str(), Some("b"));

        let e = api_error(&BauplanError::UnknownRef("dev".into()));
        assert_eq!((e.status, e.code.as_str(), e.retryable), (404, "unknown_ref", false));
        let e = api_error(&BauplanError::Visibility("no".into()));
        assert_eq!((e.status, e.code.as_str()), (403, "visibility"));
        let e = api_error(&BauplanError::MergeConflict("t".into()));
        assert_eq!((e.status, e.retryable), (409, false));
        let e = api_error(&BauplanError::Parse("x".into()));
        assert_eq!(e.status, 400);
        let e = api_error(&BauplanError::Other("x".into()));
        assert_eq!((e.status, e.code.as_str()), (500, "internal"));
    }

    #[test]
    fn api_error_json_shape_is_stable() {
        let j = api_error(&BauplanError::RefExists("b".into())).to_json();
        let inner = j.get("error");
        assert_eq!(inner.get("code").as_str(), Some("ref_exists"));
        assert_eq!(inner.get("retryable").as_bool(), Some(false));
        assert!(inner.get("message").as_str().unwrap().contains("b"));
    }

    #[test]
    fn prometheus_rendering_sanitizes_names() {
        let m = Metrics::new();
        m.incr("server.requests", 3);
        m.record("run.parallelism", 4);
        let text = render_prometheus(&m);
        assert!(text.contains("bauplan_server_requests 3"));
        assert!(text.contains("# TYPE bauplan_server_requests counter"));
        assert!(text.contains("# TYPE bauplan_run_parallelism histogram"));
        assert!(text.contains("bauplan_run_parallelism_count 1"));
        assert!(text.contains("bauplan_run_parallelism_sum 4"));
        assert!(text.contains("bauplan_run_parallelism_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn prometheus_buckets_are_cumulative() {
        let m = Metrics::new();
        // One sample in the first bucket (<=1), one in the third (<=5),
        // one past every bound (overflow → only +Inf).
        let h = m.histogram("op");
        h.record_us(1);
        h.record_us(4);
        h.record_us(5_000_000);
        let text = render_prometheus(&m);
        assert!(text.contains("bauplan_op_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("bauplan_op_bucket{le=\"2\"} 1\n"));
        assert!(text.contains("bauplan_op_bucket{le=\"5\"} 2\n"));
        assert!(text.contains("bauplan_op_bucket{le=\"1000000\"} 2\n"));
        assert!(text.contains("bauplan_op_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("bauplan_op_sum 5000005\n"));
        assert!(text.contains("bauplan_op_count 3\n"));
    }
}
