//! Minimal HTTP/1.1 wire layer: a bounded request parser and a response
//! writer (no external crates — the same zero-dep discipline as the rest
//! of the crate).
//!
//! Scope is deliberately small: exactly what the JSON API needs.
//! `Content-Length` bodies only (chunked transfer encoding is rejected
//! as malformed), keep-alive per HTTP/1.1 defaults, and hard limits on
//! head and body sizes so an untrusted client can neither balloon
//! memory nor wedge a worker:
//!
//! - request line + headers together are capped at [`MAX_HEAD_BYTES`];
//! - a declared body larger than [`MAX_BODY_BYTES`] is refused with 413
//!   *before* any of it is read;
//! - a truncated request (client died mid-body) surfaces as
//!   [`ReadError::Malformed`], never as a hung read — the server sets a
//!   socket read timeout, which this parser folds into
//!   [`ReadError::Closed`].

use std::io::{BufRead, Read, Write};
use std::time::{Duration, Instant};

/// Upper bound on a request body in bytes (413 past this).
pub const MAX_BODY_BYTES: usize = 4 << 20;

/// Total wall-clock budget for reading one request (head + body). The
/// socket read timeout bounds each *read*; this bounds the *request*,
/// so a drip-feeding client (one byte per read, each within the socket
/// timeout) still cannot pin a worker beyond the deadline.
pub const MAX_REQUEST_TIME: Duration = Duration::from_secs(30);

/// Upper bound on the request line + all headers combined (413 past
/// this — a head that large is an attack, not a request).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Maximum number of request headers accepted.
pub const MAX_HEADERS: usize = 100;

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Upper-cased method (`GET`, `POST`, `DELETE`, ...).
    pub method: String,
    /// Percent-decoded path, without the query string.
    pub path: String,
    /// Query parameters in request order (`k=v` pairs, decoded).
    pub query: Vec<(String, String)>,
    /// Should the connection stay open after the response?
    pub keep_alive: bool,
    /// Raw request body (`Content-Length` bytes).
    pub body: Vec<u8>,
    /// Raw value of the [`x-bauplan-trace`](crate::trace::TRACE_HEADER)
    /// header, if the client sent one (validated later by
    /// [`TraceCtx::parse`](crate::trace::TraceCtx::parse) — a malformed
    /// value is ignored, never an error).
    pub trace: Option<String>,
}

impl Request {
    /// First query parameter named `name`, if present.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON; an empty body reads as an empty object so
    /// handlers can treat every field as optional-with-default.
    pub fn json(&self) -> crate::error::Result<crate::util::json::Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| crate::error::BauplanError::Parse("request body is not utf-8".into()))?;
        if text.trim().is_empty() {
            return Ok(crate::util::json::Json::Obj(Default::default()));
        }
        crate::util::json::Json::parse(text)
    }
}

/// Why a request could not be read off the connection.
#[derive(Debug)]
pub enum ReadError {
    /// Clean close (EOF between keep-alive requests, or idle timeout):
    /// drop the connection without responding.
    Closed,
    /// Syntactically broken request: respond 400 and close.
    Malformed(String),
    /// Head or declared body exceeds the limits: respond 413 and close.
    TooLarge,
}

/// Read one line (up to `\n`, stripping a trailing `\r`) with a byte
/// cap and an optional wall-clock deadline. `Ok(None)` means clean EOF
/// before any byte arrived.
pub(crate) fn read_line_capped(
    r: &mut impl BufRead,
    cap: usize,
    deadline: Option<Instant>,
) -> std::result::Result<Option<String>, ReadError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        if let Some(d) = deadline {
            if Instant::now() > d {
                return Err(ReadError::Malformed("request deadline exceeded".into()));
            }
        }
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(ReadError::Malformed("truncated line".into()));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    break;
                }
                buf.push(byte[0]);
                if buf.len() > cap {
                    return Err(ReadError::TooLarge);
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // socket read timeout: treat as the peer going away
                return Err(ReadError::Closed);
            }
            Err(_) => return Err(ReadError::Closed),
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(Some(s)),
        Err(_) => Err(ReadError::Malformed("non-utf8 header bytes".into())),
    }
}

/// Decode `%XX` escapes (leaves invalid escapes untouched; `+` is not
/// treated as a space — the API never form-encodes).
fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'%' {
            if let Some(hex) = b.get(i + 1..i + 3) {
                if let Ok(v) = u8::from_str_radix(std::str::from_utf8(hex).unwrap_or("zz"), 16) {
                    out.push(v);
                    i += 3;
                    continue;
                }
            }
        }
        out.push(b[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Read and parse one request within [`MAX_REQUEST_TIME`]. The caller
/// loops on this for keep-alive connections and stops on any `Err`.
pub fn read_request(r: &mut impl BufRead) -> std::result::Result<Request, ReadError> {
    let deadline = Instant::now() + MAX_REQUEST_TIME;
    let request_line = match read_line_capped(r, MAX_HEAD_BYTES, Some(deadline))? {
        None => return Err(ReadError::Closed),
        Some(l) => l,
    };
    let parts: Vec<&str> = request_line.split_whitespace().collect();
    if parts.len() != 3 {
        return Err(ReadError::Malformed(format!("bad request line: {request_line:?}")));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Malformed(format!("unsupported version {version:?}")));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut trace: Option<String> = None;
    let mut content_length: usize = 0;
    let mut head_bytes = request_line.len();
    let mut headers = 0usize;
    loop {
        let line = match read_line_capped(r, MAX_HEAD_BYTES, Some(deadline))? {
            None => return Err(ReadError::Malformed("eof inside headers".into())),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        headers += 1;
        if head_bytes > MAX_HEAD_BYTES || headers > MAX_HEADERS {
            return Err(ReadError::TooLarge);
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ReadError::Malformed(format!("bad header line: {line:?}")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                content_length = value
                    .parse()
                    .map_err(|_| ReadError::Malformed(format!("bad content-length {value:?}")))?;
                if content_length > MAX_BODY_BYTES {
                    return Err(ReadError::TooLarge);
                }
            }
            "transfer-encoding" => {
                return Err(ReadError::Malformed(
                    "chunked transfer encoding is not supported".into(),
                ));
            }
            "connection" => {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
            name if name == crate::trace::TRACE_HEADER => {
                trace = Some(value.to_string());
            }
            _ => {}
        }
    }
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        if Instant::now() > deadline {
            return Err(ReadError::Malformed("request deadline exceeded".into()));
        }
        // chunked reads so the deadline is re-checked even against a
        // drip-fed body
        let end = (filled + 8192).min(content_length);
        match r.read(&mut body[filled..end]) {
            Ok(0) => return Err(ReadError::Malformed("truncated body".into())),
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(ReadError::Malformed("body read timed out".into()));
            }
            Err(_) => return Err(ReadError::Malformed("truncated body".into())),
        }
    }
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let query = raw_query
        .unwrap_or("")
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect();
    Ok(Request {
        method: method.to_ascii_uppercase(),
        path: percent_decode(raw_path),
        query,
        keep_alive,
        body,
        trace,
    })
}

/// Canonical reason phrase for the statuses the API emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write one complete response (status line, headers, body) and flush.
/// Returns the total bytes written (head + body) — the access log's
/// `bytes_out`.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<u64> {
    let head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok((head.len() + body.len()) as u64)
}

/// Magic prefix of a framed table-data response body
/// (`GET /v1/table/<t>/data`). After it: length-prefixed frames
/// (`len u32 LE | payload`), closed by a zero-length terminator frame.
/// Frame 0 is JSON metadata; every later frame is one encoded batch
/// object, passed through verbatim.
pub const FRAME_MAGIC: &[u8; 4] = b"BPW1";

/// Slice size for streamed response bodies: the largest write the frame
/// writer issues between deadline checks.
pub const STREAM_CHUNK_BYTES: usize = 64 * 1024;

/// Total wall-clock budget for writing one streamed response — bounds a
/// stalled (or deliberately slow) reader the same way
/// [`MAX_REQUEST_TIME`] bounds a drip-feeding sender.
pub const MAX_STREAM_TIME: Duration = Duration::from_secs(120);

/// Write one framed response without ever materializing the body.
///
/// `Content-Length` framing is kept — both wire peers reject chunked
/// transfer-encoding — and is computed from the frame lengths up front,
/// so the response size is bounded by the table, not by any body
/// buffer: the writer stages at most [`STREAM_CHUNK_BYTES`] at a time
/// and checks [`MAX_STREAM_TIME`] before each chunk hits the socket.
/// Returns the total bytes written (head + body), the access log's
/// `bytes_out`.
pub fn write_frame_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    frames: &[&[u8]],
    keep_alive: bool,
) -> std::io::Result<u64> {
    let deadline = Instant::now() + MAX_STREAM_TIME;
    write_frame_response_by(w, status, content_type, frames, keep_alive, deadline)
}

fn write_frame_response_by(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    frames: &[&[u8]],
    keep_alive: bool,
    deadline: Instant,
) -> std::io::Result<u64> {
    let body_len: u64 =
        4 + frames.iter().map(|f| 4 + f.len() as u64).sum::<u64>() + 4;
    let head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {content_type}\r\ncontent-length: {body_len}\r\nconnection: {}\r\n\r\n",
        status,
        reason(status),
        if keep_alive { "keep-alive" } else { "close" },
    );
    let mut cw = ChunkWriter {
        w,
        buf: Vec::with_capacity(STREAM_CHUNK_BYTES),
        deadline,
        total: 0,
    };
    cw.push(head.as_bytes())?;
    cw.push(FRAME_MAGIC)?;
    for f in frames {
        cw.push(&(f.len() as u32).to_le_bytes())?;
        cw.push(f)?;
    }
    cw.push(&0u32.to_le_bytes())?;
    cw.flush_buf()?;
    let total = cw.total;
    debug_assert_eq!(total, head.len() as u64 + body_len);
    w.flush()?;
    Ok(total)
}

/// Deadline-aware staging buffer: accumulates pushes into chunk-sized
/// writes so one slow frame boundary cannot trickle tiny writes, and
/// one stalled socket cannot hold the worker past the deadline.
struct ChunkWriter<'a, W: Write> {
    w: &'a mut W,
    buf: Vec<u8>,
    deadline: Instant,
    total: u64,
}

impl<W: Write> ChunkWriter<'_, W> {
    fn push(&mut self, mut bytes: &[u8]) -> std::io::Result<()> {
        while !bytes.is_empty() {
            let room = STREAM_CHUNK_BYTES - self.buf.len();
            let take = room.min(bytes.len());
            self.buf.extend_from_slice(&bytes[..take]);
            bytes = &bytes[take..];
            if self.buf.len() == STREAM_CHUNK_BYTES {
                self.flush_buf()?;
            }
        }
        Ok(())
    }

    fn flush_buf(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        if Instant::now() > self.deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "response write deadline exceeded",
            ));
        }
        self.w.write_all(&self.buf)?;
        self.total += self.buf.len() as u64;
        self.buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(bytes: &[u8]) -> std::result::Result<Request, ReadError> {
        let mut r = bytes;
        read_request(&mut r)
    }

    #[test]
    fn parses_get_with_query() {
        let req = parse(b"GET /v1/log/main?limit=5&x=a%20b HTTP/1.1\r\nhost: h\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/log/main");
        assert_eq!(req.query_param("limit"), Some("5"));
        assert_eq!(req.query_param("x"), Some("a b"));
        assert!(req.keep_alive);
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_and_connection_close() {
        let req = parse(
            b"POST /v1/commit HTTP/1.0\r\ncontent-length: 7\r\nconnection: close\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"a\":1}");
        assert!(!req.keep_alive);
        assert_eq!(req.json().unwrap().get("a").as_f64(), Some(1.0));
    }

    #[test]
    fn http10_defaults_to_close_but_can_keep_alive() {
        let req = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.keep_alive);
        let req = parse(b"GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(matches!(parse(b"NOT-HTTP\r\n\r\n"), Err(ReadError::Malformed(_))));
        assert!(matches!(parse(b"GET /\r\n\r\n"), Err(ReadError::Malformed(_))));
        assert!(matches!(parse(b"GET / SPDY/99\r\n\r\n"), Err(ReadError::Malformed(_))));
        // declared body never arrives
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\ncontent-length: 100\r\n\r\nabc"),
            Err(ReadError::Malformed(_))
        ));
        // header line without a colon
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nbroken header\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
        // chunked is out of scope, refused cleanly
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn rejects_oversized_head_and_body() {
        let long = "A".repeat(MAX_HEAD_BYTES + 10);
        let raw = format!("GET /{long} HTTP/1.1\r\n\r\n");
        assert!(matches!(parse(raw.as_bytes()), Err(ReadError::TooLarge)));
        let raw = format!("POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(parse(raw.as_bytes()), Err(ReadError::TooLarge)));
    }

    #[test]
    fn clean_eof_reads_as_closed() {
        assert!(matches!(parse(b""), Err(ReadError::Closed)));
    }

    #[test]
    fn captures_trace_header_raw() {
        let req = parse(b"GET / HTTP/1.1\r\nX-Bauplan-Trace: trace_ab/7\r\n\r\n").unwrap();
        assert_eq!(req.trace.as_deref(), Some("trace_ab/7"));
        let req = parse(b"GET / HTTP/1.1\r\nhost: h\r\n\r\n").unwrap();
        assert_eq!(req.trace, None);
    }

    #[test]
    fn response_round_trips_through_the_writer() {
        let mut out: Vec<u8> = Vec::new();
        let n = write_response(&mut out, 200, "application/json", b"{}", true).unwrap();
        assert_eq!(n, out.len() as u64);
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("content-length: 2\r\n"));
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn percent_decoding_is_lenient() {
        assert_eq!(percent_decode("a%2Fb"), "a/b");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn frame_writer_emits_magic_frames_terminator() {
        let mut out: Vec<u8> = Vec::new();
        let frames: Vec<&[u8]> = vec![b"{\"k\":1}", b"\x01\x02\x03"];
        let n = write_frame_response(&mut out, 200, "application/x-bauplan-frames", &frames, true)
            .unwrap();
        assert_eq!(n, out.len() as u64);
        let head_end = out.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        let head = std::str::from_utf8(&out[..head_end]).unwrap();
        let body = &out[head_end..];
        // declared length matches the streamed body exactly
        assert!(head.contains(&format!("content-length: {}\r\n", body.len())));
        assert!(head.contains("connection: keep-alive\r\n"));
        assert_eq!(&body[..4], FRAME_MAGIC);
        assert_eq!(&body[4..8], &7u32.to_le_bytes());
        assert_eq!(&body[8..15], b"{\"k\":1}");
        assert_eq!(&body[15..19], &3u32.to_le_bytes());
        assert_eq!(&body[19..22], b"\x01\x02\x03");
        assert_eq!(&body[22..], &0u32.to_le_bytes());
    }

    #[test]
    fn frame_writer_chunks_large_frames() {
        // a frame spanning several chunks arrives intact
        let big = vec![0xabu8; STREAM_CHUNK_BYTES * 2 + 17];
        let frames: Vec<&[u8]> = vec![&big];
        let mut out: Vec<u8> = Vec::new();
        let n = write_frame_response(&mut out, 200, "application/x-bauplan-frames", &frames, false)
            .unwrap();
        assert_eq!(n, out.len() as u64);
        let head_end = out.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        let body = &out[head_end..];
        assert_eq!(body.len(), 4 + 4 + big.len() + 4);
        assert_eq!(&body[8..8 + big.len()], &big[..]);
    }

    #[test]
    fn frame_writer_enforces_its_deadline() {
        let mut out: Vec<u8> = Vec::new();
        let frames: Vec<&[u8]> = vec![b"payload"];
        let past = Instant::now() - Duration::from_secs(1);
        let err = write_frame_response_by(
            &mut out,
            200,
            "application/x-bauplan-frames",
            &frames,
            false,
            past,
        )
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }
}
