//! User-defined verifiers — the protocol's step 3 ("run data tests /
//! user-defined verifiers on B'").
//!
//! A verifier sees the execution branch's lake state *before* publication
//! and can veto the merge. Expectation-style checks (row counts, value
//! relations across tables) complement the schema-level M3 checks the
//! worker already ran per table.

use crate::catalog::Commit;
use crate::error::{BauplanError, Result};
use crate::worker::Worker;

type CheckFn = dyn Fn(&Worker, &Commit) -> Result<()> + Send + Sync;

/// A named data test run on the transactional branch before merge.
pub struct Verifier {
    /// Human-readable name (surfaces in the abort cause).
    pub name: String,
    check: Box<CheckFn>,
}

impl Verifier {
    /// A verifier from an arbitrary check closure.
    pub fn new(
        name: &str,
        check: impl Fn(&Worker, &Commit) -> Result<()> + Send + Sync + 'static,
    ) -> Verifier {
        Verifier { name: name.into(), check: Box::new(check) }
    }

    /// Run the check against the lake state `state`.
    pub fn check(&self, worker: &Worker, state: &Commit) -> Result<()> {
        (self.check)(worker, state)
    }

    /// Table must exist and have at least `min_rows` valid rows.
    pub fn min_rows(table: &str, min_rows: usize) -> Verifier {
        let t = table.to_string();
        Verifier::new(&format!("min_rows({table},{min_rows})"), move |w, state| {
            let tbl = w.read_table(state, &t)?;
            if tbl.row_count() < min_rows {
                return Err(BauplanError::ContractRuntime(format!(
                    "table '{t}' has {} rows, expected >= {min_rows}",
                    tbl.row_count()
                )));
            }
            Ok(())
        })
    }

    /// Downstream table must not have more rows than upstream (row
    /// conservation for filter/aggregate pipelines).
    pub fn rows_not_amplified(upstream: &str, downstream: &str) -> Verifier {
        let u = upstream.to_string();
        let d = downstream.to_string();
        Verifier::new(&format!("rows_not_amplified({upstream},{downstream})"), move |w, state| {
            let ut = w.read_table(state, &u)?;
            let dt = w.read_table(state, &d)?;
            if dt.row_count() > ut.row_count() {
                return Err(BauplanError::ContractRuntime(format!(
                    "'{d}' has {} rows > '{u}' {} rows",
                    dt.row_count(),
                    ut.row_count()
                )));
            }
            Ok(())
        })
    }
}

impl std::fmt::Debug for Verifier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Verifier({})", self.name)
    }
}
