//! Failure injection for the consistency experiments (E3/E4).
//!
//! Models the mid-run crashes of Fig. 3: a run can be made to die
//! *before* computing a node, or *after* the node's table commit landed
//! on the execution branch (the worst spot: in DirectWrite mode the
//! target branch now holds a prefix of the run's outputs).

use crate::error::{BauplanError, Result};

/// Where to inject a failure relative to a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailurePoint {
    /// Before the node's compute runs.
    BeforeNode,
    /// After the node's output was committed to the execution branch.
    AfterCommit,
}

/// A failure schedule for one run.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    /// Fail at this output table.
    pub at_node: Option<String>,
    pub point: Option<FailurePoint>,
    /// Inject a compute-level poison instead of a crash (contract bugs).
    pub poison_node: Option<String>,
}

impl FailurePlan {
    /// No injected failures.
    pub fn none() -> FailurePlan {
        FailurePlan::default()
    }

    /// Crash before computing `node`.
    pub fn crash_before(node: &str) -> FailurePlan {
        FailurePlan {
            at_node: Some(node.into()),
            point: Some(FailurePoint::BeforeNode),
            poison_node: None,
        }
    }

    /// Crash after `node`'s commit landed (Fig. 3's run_2: parent
    /// published, child never arrives).
    pub fn crash_after(node: &str) -> FailurePlan {
        FailurePlan {
            at_node: Some(node.into()),
            point: Some(FailurePoint::AfterCommit),
            poison_node: None,
        }
    }

    pub fn check_before(&self, node: &str, run_id: &str) -> Result<()> {
        if self.point == Some(FailurePoint::BeforeNode)
            && self.at_node.as_deref() == Some(node)
        {
            return Err(BauplanError::RunFailed {
                run_id: run_id.into(),
                node: node.into(),
                cause: "injected crash (before node)".into(),
            });
        }
        Ok(())
    }

    pub fn check_after(&self, node: &str, run_id: &str) -> Result<()> {
        if self.point == Some(FailurePoint::AfterCommit)
            && self.at_node.as_deref() == Some(node)
        {
            return Err(BauplanError::RunFailed {
                run_id: run_id.into(),
                node: node.into(),
                cause: "injected crash (after commit)".into(),
            });
        }
        Ok(())
    }

    /// Hook between compute and persist: simulates a node whose output is
    /// corrupt enough that persisting it would be wrong.
    pub fn poison_hook(&self, node: &str) -> Result<()> {
        if self.poison_node.as_deref() == Some(node) {
            return Err(BauplanError::ContractRuntime(format!(
                "injected poison at node {node}")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let f = FailurePlan::none();
        f.check_before("x", "r").unwrap();
        f.check_after("x", "r").unwrap();
        f.poison_hook("x").unwrap();
    }

    #[test]
    fn fires_only_at_designated_point() {
        let f = FailurePlan::crash_after("child_table");
        f.check_before("child_table", "r").unwrap();
        f.check_after("parent_table", "r").unwrap();
        assert!(f.check_after("child_table", "r").is_err());
    }
}
