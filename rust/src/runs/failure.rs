//! Failure injection for the consistency experiments (E3/E4) and the
//! durability crash points of the commit pipeline.
//!
//! Models the mid-run crashes of Fig. 3: a run can be made to die
//! *before* computing a node, or *after* the node's table commit landed
//! on the execution branch (the worst spot: in DirectWrite mode the
//! target branch now holds a prefix of the run's outputs).
//!
//! Two durability extensions (spec: `doc/COMMIT_PIPELINE.md` §Crash
//! points):
//!
//! - **kill mode** ([`FailurePlan::kill_after`]): the injected failure is
//!   treated as the *process dying*, not an error the engine handles —
//!   the runner performs none of its abort bookkeeping (no `Aborted`
//!   transition, no registry entry), exactly like `kill -9`. Recovery via
//!   [`Catalog::recover`](crate::catalog::Catalog::recover) must then
//!   abort the orphaned transactional branch itself.
//! - **journal crash points** ([`FailurePlan::journal_crash_after`]): the
//!   catalog's journal starts failing after N more appends, so tests can
//!   pin the write-ahead ordering (a mutation whose record cannot be
//!   written never becomes visible).

use std::sync::Arc;

use crate::error::{BauplanError, Result};

/// Where to inject a failure relative to a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailurePoint {
    /// Before the node's compute runs.
    BeforeNode,
    /// After the node's output was committed to the execution branch.
    AfterCommit,
}

/// A pause-point callback: `(point, node)` fires at every scheduler
/// pause point of every node. The deterministic simulator uses this to
/// interleave *concurrent catalog operations* at exact positions inside
/// a run (e.g. another actor committing to the target branch between two
/// node commits) — mid-run interleaving control without threads racing.
pub type PauseHook = Arc<dyn Fn(FailurePoint, &str) + Send + Sync>;

/// A failure schedule for one run.
#[derive(Clone, Default)]
pub struct FailurePlan {
    /// Fail at this output table.
    pub at_node: Option<String>,
    /// When to fail relative to the node (None = never).
    pub point: Option<FailurePoint>,
    /// Inject a compute-level poison instead of a crash (contract bugs).
    pub poison_node: Option<String>,
    /// Treat the injected failure as the process dying: the run engine
    /// does no abort bookkeeping and the error propagates raw.
    pub kill: bool,
    /// Make the catalog journal fail after this many more appends
    /// (durability crash point; `None` = journal healthy).
    pub journal_fail_after: Option<u64>,
    /// Observation/interleaving hook fired at every node pause point
    /// (`None` = no hook). Unlike the crash fields, the hook injects no
    /// failure itself — it lets a test run *other* catalog operations at
    /// a deterministic spot mid-run.
    pub pause: Option<PauseHook>,
}

impl std::fmt::Debug for FailurePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FailurePlan")
            .field("at_node", &self.at_node)
            .field("point", &self.point)
            .field("poison_node", &self.poison_node)
            .field("kill", &self.kill)
            .field("journal_fail_after", &self.journal_fail_after)
            .field("pause", &self.pause.as_ref().map(|_| "<hook>"))
            .finish()
    }
}

impl FailurePlan {
    /// No injected failures.
    pub fn none() -> FailurePlan {
        FailurePlan::default()
    }

    /// Crash before computing `node`.
    pub fn crash_before(node: &str) -> FailurePlan {
        FailurePlan {
            at_node: Some(node.into()),
            point: Some(FailurePoint::BeforeNode),
            ..FailurePlan::default()
        }
    }

    /// Crash after `node`'s commit landed (Fig. 3's run_2: parent
    /// published, child never arrives).
    pub fn crash_after(node: &str) -> FailurePlan {
        FailurePlan {
            at_node: Some(node.into()),
            point: Some(FailurePoint::AfterCommit),
            ..FailurePlan::default()
        }
    }

    /// Like [`FailurePlan::crash_after`], but the process *dies* there:
    /// no abort transition, no run registry entry — the on-disk journal
    /// is the only witness. Pair with
    /// [`Catalog::recover`](crate::catalog::Catalog::recover).
    pub fn kill_after(node: &str) -> FailurePlan {
        FailurePlan { kill: true, ..FailurePlan::crash_after(node) }
    }

    /// Let `n` more journal appends succeed, then fail every later one
    /// (simulates the disk dying / the process being killed mid-append).
    pub fn journal_crash_after(n: u64) -> FailurePlan {
        FailurePlan { journal_fail_after: Some(n), ..FailurePlan::default() }
    }

    /// Is this plan a process-kill simulation?
    pub fn is_kill(&self) -> bool {
        self.kill
    }

    /// This plan, with a pause hook attached (builder style).
    pub fn with_pause(mut self, hook: PauseHook) -> FailurePlan {
        self.pause = Some(hook);
        self
    }

    /// Fire the pause hook, if any. Called by the scheduler at
    /// [`FailurePoint::BeforeNode`] (before the node's crash check) and
    /// [`FailurePoint::AfterCommit`] (right after the node's table
    /// commit lands, before the after-commit crash check).
    pub fn at_pause(&self, point: FailurePoint, node: &str) {
        if let Some(h) = &self.pause {
            h(point, node);
        }
    }

    /// Check the [`FailurePoint::BeforeNode`] crash point.
    pub fn check_before(&self, node: &str, run_id: &str) -> Result<()> {
        if self.point == Some(FailurePoint::BeforeNode)
            && self.at_node.as_deref() == Some(node)
        {
            return Err(BauplanError::RunFailed {
                run_id: run_id.into(),
                node: node.into(),
                cause: "injected crash (before node)".into(),
            });
        }
        Ok(())
    }

    /// Check the [`FailurePoint::AfterCommit`] crash point.
    pub fn check_after(&self, node: &str, run_id: &str) -> Result<()> {
        if self.point == Some(FailurePoint::AfterCommit)
            && self.at_node.as_deref() == Some(node)
        {
            return Err(BauplanError::RunFailed {
                run_id: run_id.into(),
                node: node.into(),
                cause: if self.kill {
                    "injected kill (process died after commit)".into()
                } else {
                    "injected crash (after commit)".into()
                },
            });
        }
        Ok(())
    }

    /// Hook between compute and persist: simulates a node whose output is
    /// corrupt enough that persisting it would be wrong.
    pub fn poison_hook(&self, node: &str) -> Result<()> {
        if self.poison_node.as_deref() == Some(node) {
            return Err(BauplanError::ContractRuntime(format!(
                "injected poison at node {node}")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let f = FailurePlan::none();
        f.check_before("x", "r").unwrap();
        f.check_after("x", "r").unwrap();
        f.poison_hook("x").unwrap();
        assert!(!f.is_kill());
        assert!(f.journal_fail_after.is_none());
    }

    #[test]
    fn fires_only_at_designated_point() {
        let f = FailurePlan::crash_after("child_table");
        f.check_before("child_table", "r").unwrap();
        f.check_after("parent_table", "r").unwrap();
        assert!(f.check_after("child_table", "r").is_err());
    }

    #[test]
    fn kill_mode_fires_like_a_crash_but_is_flagged() {
        let f = FailurePlan::kill_after("child_table");
        assert!(f.is_kill());
        let err = f.check_after("child_table", "r").unwrap_err();
        assert!(err.to_string().contains("process died"));
    }
}
