//! Transactional pipeline runs (paper §3.3, Fig. 3).
//!
//! The protocol, verbatim from the paper — if `B` is the target branch:
//!
//! 1. automatically create a transactional branch `B'` from `B`;
//! 2. write the DAG tables into `B'` (each table commit atomic);
//! 3. run data tests / user-defined verifiers on `B'`;
//! 4. only if no code or data error is raised, merge `B'` back into `B`
//!    and delete it.
//!
//! On failure, `B` is untouched (total failure instead of partial
//! failure) and `B'` is retained in `Aborted` state for triage — with
//! the visibility guardrail the Alloy counterexample motivates.
//!
//! [`RunMode::DirectWrite`] is the baseline: the same execution writing
//! straight to `B` (what today's lakehouses do, Fig. 3 top) — it exists
//! so experiments E3/E4/E5 can quantify the difference.
//!
//! When the catalog is durable (opened with
//! [`Catalog::recover`](crate::catalog::Catalog::recover)), every step of
//! the protocol is journaled, so a run killed mid-flight (simulated by
//! [`FailurePlan::kill_after`]) leaves a journal whose replay reconstructs
//! the target branch untouched and the transactional branch `Aborted` —
//! never half-merged. The protocol ↔ journal mapping is specified in
//! `doc/COMMIT_PIPELINE.md`.
#![warn(missing_docs)]

pub mod failure;
pub mod verifier;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cache::{run_cache_key, CacheKey, RunCache};
use crate::catalog::{BranchState, Catalog, Commit};
use crate::dag::Plan;
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::util::id::unique_id;
use crate::worker::Worker;
pub use failure::FailurePlan;
pub use verifier::Verifier;

/// How a run publishes its outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// The paper's protocol: hidden transactional branch + atomic merge.
    Transactional,
    /// Baseline: write each table directly to the target branch.
    DirectWrite,
}

/// Terminal status of a run.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    /// All outputs published atomically.
    Success,
    /// Failed; transactional branch retained (name included).
    Aborted {
        /// The retained `txn/...` branch holding the partial outputs.
        txn_branch: String,
        /// Why the run aborted.
        cause: String,
    },
    /// Failed in DirectWrite mode; target branch may hold partial state.
    FailedPartial {
        /// How many output tables leaked onto the target branch.
        tables_published: usize,
        /// Why the run failed.
        cause: String,
    },
}

/// Immutable record of one run — what `client.get_run(run_id)` returns
/// (Listing 6): enough to reproduce the run (starting commit + code id).
#[derive(Debug, Clone)]
pub struct RunState {
    /// Unique run identifier (`run_...`).
    pub run_id: String,
    /// Name of the pipeline that ran.
    pub pipeline: String,
    /// Target branch name.
    pub target: String,
    /// Commit the target branch pointed at when the run began — the
    /// "data commit" half of reproducibility.
    pub start_commit: String,
    /// Fingerprint of the pipeline code ("code_zip" in Listing 6).
    pub code_hash: String,
    /// Publication mode the run used.
    pub mode: RunMode,
    /// Terminal status.
    pub status: RunStatus,
    /// Tables written, in order.
    pub outputs: Vec<String>,
    /// Nodes served from the run cache (published without executing).
    pub cache_hits: u64,
    /// Nodes that executed because no verified cache entry applied.
    pub cache_misses: u64,
    /// Bytes of output the cache avoided re-producing.
    pub cache_bytes_saved: u64,
}

/// Per-run cache bookkeeping: hit/miss tallies plus the entries that
/// become reusable once (and only once) the step-3 verifiers pass.
#[derive(Default)]
struct CacheRunCtx {
    hits: u64,
    misses: u64,
    bytes_saved: u64,
    /// (key, snapshot id, bytes) for every node this run executed —
    /// staged, not yet visible to other runs.
    pending: Vec<(CacheKey, String, u64)>,
}

/// The run engine: owns the protocol and the run registry.
#[derive(Clone)]
pub struct Runner {
    catalog: Catalog,
    worker: Worker,
    registry: Arc<Mutex<HashMap<String, RunState>>>,
    /// Memoized node executions; `None` = every node executes.
    cache: Option<Arc<RunCache>>,
    /// Latency/counter metrics for the protocol steps.
    pub metrics: Arc<Metrics>,
}

impl Runner {
    /// A run engine over `catalog`, executing node compute on `worker`.
    pub fn new(catalog: Catalog, worker: Worker) -> Runner {
        Runner {
            catalog,
            worker,
            registry: Arc::new(Mutex::new(HashMap::new())),
            cache: None,
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Enable the content-addressed run cache: nodes whose key matches a
    /// verified entry publish the memoized snapshot instead of
    /// executing. See `doc/RUN_CACHE.md`.
    pub fn with_cache(mut self, cache: Arc<RunCache>) -> Runner {
        self.cache = Some(cache);
        self
    }

    /// The attached run cache, if any.
    pub fn cache(&self) -> Option<&Arc<RunCache>> {
        self.cache.as_ref()
    }

    /// Look up the immutable record of a finished run.
    pub fn get_run(&self, run_id: &str) -> Option<RunState> {
        self.registry.lock().unwrap().get(run_id).cloned()
    }

    /// Execute `plan` against branch `target`.
    ///
    /// `failure` injects faults for the experiments; `verifiers` are the
    /// protocol's step-3 data tests. Returns the final [`RunState`]
    /// (also queryable later by run_id).
    pub fn run(
        &self,
        plan: &Plan,
        target: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
    ) -> Result<RunState> {
        let run_id = unique_id("run");
        let start_commit = self.catalog.resolve(target)?;
        let code_hash = plan_fingerprint(plan);

        // durability crash point: arm the journal fault, if requested
        if let Some(n) = failure.journal_fail_after {
            self.catalog.journal_inject_fail_after(n);
        }

        let exec_branch = match mode {
            RunMode::Transactional => {
                let info = self.metrics.time("run.create_txn_branch", || {
                    self.catalog.create_txn_branch(target, &run_id)
                })?;
                info.name
            }
            RunMode::DirectWrite => target.to_string(),
        };

        let mut outputs: Vec<String> = Vec::new();
        let mut cache_ctx = CacheRunCtx::default();
        let result =
            self.execute_nodes(plan, &exec_branch, &run_id, failure, &mut outputs, &mut cache_ctx);
        let result = result.and_then(|_| {
            // step 3: verifiers on B' (or on the target, in direct mode)
            let state = self.catalog.read_ref(&exec_branch)?;
            for v in verifiers {
                v.check(&self.worker, &state).map_err(|e| {
                    BauplanError::RunFailed {
                        run_id: run_id.clone(),
                        node: format!("verifier:{}", v.name),
                        cause: e.to_string(),
                    }
                })?;
            }
            Ok(())
        });

        // kill mode: the "process" dies here — no abort bookkeeping, no
        // registry entry, and crucially no cache populate (the pending
        // entries below die with the process). Only the journal (if
        // durable) witnessed the run; Catalog::recover must reconstruct a
        // consistent state from it.
        let result = match result {
            Err(e) if failure.is_kill() => return Err(e),
            other => other,
        };

        // populate-after-verify: executed nodes become reusable only now
        // that step 3 passed — a cache hit can never skip a check a
        // fresh run would have enforced. Entries are pinned before they
        // are published so GC can never race an entry's snapshot away.
        if result.is_ok() {
            if let Some(cache) = &self.cache {
                for (key, snap_id, bytes) in cache_ctx.pending.drain(..) {
                    if self.catalog.pin_snapshot(&snap_id).is_err() {
                        continue; // snapshot vanished; nothing to cache
                    }
                    let (inserted, displaced) = cache.populate(&key, &snap_id, bytes);
                    if !inserted {
                        self.catalog.unpin_snapshot(&snap_id);
                    }
                    for d in displaced {
                        self.catalog.unpin_snapshot(&d.snapshot_id);
                    }
                }
            }
        }

        let status = match (mode, result) {
            (RunMode::Transactional, Ok(())) => {
                // step 4: atomic publish — merge B' into B, delete B'.
                let merged = self.metrics.time("run.merge_publish", || {
                    self.catalog.merge(&exec_branch, target, false)
                });
                match merged {
                    Ok(_) => {
                        self.catalog.set_branch_state(&exec_branch, BranchState::Merged)?;
                        self.catalog.delete_branch(&exec_branch)?;
                        self.metrics.incr("run.success", 1);
                        RunStatus::Success
                    }
                    Err(e) => {
                        // merge refused (e.g. conflicting concurrent run):
                        // still a *total* failure — target untouched.
                        self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                        self.metrics.incr("run.aborted", 1);
                        RunStatus::Aborted {
                            txn_branch: exec_branch.clone(),
                            cause: e.to_string(),
                        }
                    }
                }
            }
            (RunMode::Transactional, Err(e)) => {
                self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                self.metrics.incr("run.aborted", 1);
                RunStatus::Aborted {
                    txn_branch: exec_branch.clone(),
                    cause: e.to_string(),
                }
            }
            (RunMode::DirectWrite, Ok(())) => {
                self.metrics.incr("run.success", 1);
                RunStatus::Success
            }
            (RunMode::DirectWrite, Err(e)) => {
                // Fig. 3 top: the target now holds a prefix of the outputs.
                self.metrics.incr("run.failed_partial", 1);
                RunStatus::FailedPartial {
                    tables_published: outputs.len(),
                    cause: e.to_string(),
                }
            }
        };

        let state = RunState {
            run_id: run_id.clone(),
            pipeline: plan.pipeline.clone(),
            target: target.to_string(),
            start_commit,
            code_hash,
            mode,
            status,
            outputs,
            cache_hits: cache_ctx.hits,
            cache_misses: cache_ctx.misses,
            cache_bytes_saved: cache_ctx.bytes_saved,
        };
        self.registry.lock().unwrap().insert(run_id, state.clone());
        Ok(state)
    }

    /// Step 2: execute nodes in plan order, committing each output table
    /// to the execution branch (atomic per-table commits).
    ///
    /// With a cache attached, each node first derives its run-cache key
    /// from the branch state it is about to read; a verified entry
    /// publishes the memoized snapshot (zero compute, same commit
    /// protocol), a miss executes and stages the result for
    /// populate-after-verify. Because keys chain through input snapshot
    /// ids, an edited node automatically misses for itself and its
    /// downstream cone while untouched siblings keep hitting.
    fn execute_nodes(
        &self,
        plan: &Plan,
        exec_branch: &str,
        run_id: &str,
        failure: &FailurePlan,
        outputs: &mut Vec<String>,
        cache_ctx: &mut CacheRunCtx,
    ) -> Result<()> {
        let cache_metrics = self.metrics.clone().ns("cache");
        for (i, node) in plan.nodes.iter().enumerate() {
            failure.check_before(&node.output, run_id)?;
            let state = self.catalog.read_ref(exec_branch)?;

            // ---- lookup-before-execute -------------------------------
            let mut staged_key: Option<CacheKey> = None;
            if let Some(cache) = &self.cache {
                if let Some(key) = self.node_cache_key(plan, i, &state) {
                    let mut hit = None;
                    if let Some(entry) = cache.lookup(&key) {
                        match self.catalog.get_snapshot(&entry.snapshot_id) {
                            Ok(snap) => hit = Some(snap),
                            Err(_) => {
                                // stale entry (snapshot no longer in this
                                // catalog): drop it and execute
                                let _ = cache.remove(&key);
                            }
                        }
                    }
                    if let Some(snap) = hit {
                        self.catalog.commit_table(
                            exec_branch,
                            &node.output,
                            snap,
                            "runner",
                            &format!("run {run_id}: cache hit for {}", node.output),
                            Some(run_id.to_string()),
                        )?;
                        let bytes = cache.mark_hit(&key);
                        cache_metrics.incr("hits", 1);
                        cache_metrics.incr("bytes_saved", bytes);
                        cache_ctx.hits += 1;
                        cache_ctx.bytes_saved += bytes;
                        outputs.push(node.output.clone());
                        failure.check_after(&node.output, run_id)?;
                        continue;
                    }
                    cache.mark_miss();
                    cache_metrics.incr("misses", 1);
                    cache_ctx.misses += 1;
                    staged_key = Some(key);
                }
            }

            // ---- execute + stage for populate-after-verify -----------
            let table = self.worker.execute_node(node, &state)?;
            failure.poison_hook(&node.output)?;
            let snap = self.worker.persist_table(&table, run_id)?;
            if let Some(key) = staged_key {
                let bytes: u64 = snap
                    .objects
                    .iter()
                    .filter_map(|o| self.catalog.store().object_size(o))
                    .sum();
                cache_ctx.pending.push((key, snap.id.clone(), bytes));
            }
            self.catalog.commit_table(
                exec_branch,
                &node.output,
                snap,
                "runner",
                &format!("run {run_id}: write {}", node.output),
                Some(run_id.to_string()),
            )?;
            outputs.push(node.output.clone());
            failure.check_after(&node.output, run_id)?;
        }
        Ok(())
    }

    /// Derive the run-cache key for `plan.nodes[idx]` against the lake
    /// state it is about to read: plan-time static fingerprint +
    /// compiled-artifact fingerprint + input snapshot ids (declared
    /// order). `None` when any component is unavailable (unknown op or
    /// missing input — the execute path will surface the real error).
    fn node_cache_key(&self, plan: &Plan, idx: usize, state: &Commit) -> Option<CacheKey> {
        let node = &plan.nodes[idx];
        let static_fp = plan.node_fps.get(idx)?;
        let artifact_fp = self
            .worker
            .runtime()
            .manifest()
            .artifact(&node.op)
            .ok()?
            .fingerprint();
        let mut input_snaps = Vec::with_capacity(node.inputs.len());
        for (t, _) in &node.inputs {
            input_snaps.push(state.snapshot_of(t)?.clone());
        }
        Some(run_cache_key(static_fp, &artifact_fp, &input_snaps))
    }
}

/// Deterministic fingerprint of a plan — the "code_zip" identity that,
/// together with `start_commit`, makes a run reproducible (§3.2).
pub fn plan_fingerprint(plan: &Plan) -> String {
    let mut desc = String::new();
    desc.push_str(&plan.pipeline);
    for n in &plan.nodes {
        desc.push_str(&format!(
            "|{}:{}:{}:{:?}:{:?}",
            n.output, n.out_schema, n.op, n.inputs, n.params
        ));
    }
    crate::util::id::content_hash(desc.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fingerprint_is_stable_and_sensitive() {
        let p1 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        let p2 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        assert_eq!(plan_fingerprint(&p1), plan_fingerprint(&p2));

        let mut spec = crate::dag::PipelineSpec::paper_pipeline();
        spec.nodes[1].params[2] = 0.75; // change child's scale
        let p3 = spec.plan().unwrap();
        assert_ne!(plan_fingerprint(&p1), plan_fingerprint(&p3));
    }
}
