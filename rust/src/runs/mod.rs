//! Transactional pipeline runs (paper §3.3, Fig. 3).
//!
//! The protocol, verbatim from the paper — if `B` is the target branch:
//!
//! 1. automatically create a transactional branch `B'` from `B`;
//! 2. write the DAG tables into `B'` (each table commit atomic);
//! 3. run data tests / user-defined verifiers on `B'`;
//! 4. only if no code or data error is raised, merge `B'` back into `B`
//!    and delete it.
//!
//! On failure, `B` is untouched (total failure instead of partial
//! failure) and `B'` is retained in `Aborted` state for triage — with
//! the visibility guardrail the Alloy counterexample motivates.
//!
//! [`RunMode::DirectWrite`] is the baseline: the same execution writing
//! straight to `B` (what today's lakehouses do, Fig. 3 top) — it exists
//! so experiments E3/E4/E5 can quantify the difference.
//!
//! When the catalog is durable (opened with
//! [`Catalog::recover`](crate::catalog::Catalog::recover)), every step of
//! the protocol is journaled, so a run killed mid-flight (simulated by
//! [`FailurePlan::kill_after`]) leaves a journal whose replay reconstructs
//! the target branch untouched and the transactional branch `Aborted` —
//! never half-merged. The protocol ↔ journal mapping is specified in
//! `doc/COMMIT_PIPELINE.md`. Terminal run states are journaled too
//! ([`run_state_to_json`]), so `get_run` answers across restarts.
//!
//! Step 2 is executed by the **wavefront scheduler** ([`scheduler`]):
//! independent DAG nodes run concurrently (the [`Runner::with_jobs`]
//! knob), each committing its table to the transactional branch as it
//! finishes — ordering is schedule-dependent, the published branch state
//! is not. Spec: `doc/SCHEDULER.md`.
#![warn(missing_docs)]

pub mod failure;
pub mod scheduler;
pub mod verifier;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cache::{CacheKey, RunCache};
use crate::catalog::{BranchState, Catalog};
use crate::dag::Plan;
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::trace::{Trace, TraceConfig, TraceCtx};
use crate::util::id::unique_id;
use crate::util::json::Json;
use crate::worker::Worker;
pub use failure::FailurePlan;
pub use verifier::Verifier;

/// How a run publishes its outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// The paper's protocol: hidden transactional branch + atomic merge.
    Transactional,
    /// Baseline: write each table directly to the target branch.
    DirectWrite,
}

/// Terminal status of a run.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    /// All outputs published atomically.
    Success,
    /// Failed; transactional branch retained (name included).
    Aborted {
        /// The retained `txn/...` branch holding the partial outputs.
        txn_branch: String,
        /// Why the run aborted.
        cause: String,
    },
    /// Failed in DirectWrite mode; target branch may hold partial state.
    FailedPartial {
        /// How many output tables leaked onto the target branch.
        tables_published: usize,
        /// Why the run failed.
        cause: String,
    },
}

/// Immutable record of one run — what `client.get_run(run_id)` returns
/// (Listing 6): enough to reproduce the run (starting commit + code id).
#[derive(Debug, Clone, PartialEq)]
pub struct RunState {
    /// Unique run identifier (`run_...`).
    pub run_id: String,
    /// Name of the pipeline that ran.
    pub pipeline: String,
    /// Target branch name.
    pub target: String,
    /// Commit the target branch pointed at when the run began — the
    /// "data commit" half of reproducibility.
    pub start_commit: String,
    /// Fingerprint of the pipeline code ("code_zip" in Listing 6).
    pub code_hash: String,
    /// Publication mode the run used.
    pub mode: RunMode,
    /// Terminal status.
    pub status: RunStatus,
    /// Tables written, in order.
    pub outputs: Vec<String>,
    /// Nodes served from the run cache (published without executing).
    pub cache_hits: u64,
    /// Nodes that executed because no verified cache entry applied.
    pub cache_misses: u64,
    /// Bytes of output the cache avoided re-producing.
    pub cache_bytes_saved: u64,
}

/// Per-run cache bookkeeping: hit/miss tallies plus the entries that
/// become reusable once (and only once) the step-3 verifiers pass.
#[derive(Default)]
pub(crate) struct CacheRunCtx {
    pub(crate) hits: u64,
    pub(crate) misses: u64,
    pub(crate) bytes_saved: u64,
    /// (key, snapshot id, bytes) for every node this run executed —
    /// staged, not yet visible to other runs.
    pub(crate) pending: Vec<(CacheKey, String, u64)>,
}

/// The run engine: owns the protocol and the run registry.
#[derive(Clone)]
pub struct Runner {
    catalog: Catalog,
    worker: Worker,
    registry: Arc<Mutex<HashMap<String, RunState>>>,
    /// Memoized node executions; `None` = every node executes.
    cache: Option<Arc<RunCache>>,
    /// Wavefront width: how many ready nodes the scheduler dispatches
    /// concurrently (the `--jobs` knob; 1 replays the sequential engine).
    jobs: usize,
    /// Latency/counter metrics for the protocol steps.
    pub metrics: Arc<Metrics>,
    /// Tracing knobs: span cap, or fully disabled (the bench baseline).
    trace_config: TraceConfig,
}

impl Runner {
    /// A run engine over `catalog`, executing node compute on `worker`.
    /// Shares the worker's metrics registry, so protocol (`run.*`),
    /// compute (`worker.*`), and scan (`scan.*`) counters land in one
    /// place — the registry `/metrics` renders.
    pub fn new(catalog: Catalog, worker: Worker) -> Runner {
        let metrics = worker.metrics.clone();
        Runner {
            catalog,
            worker,
            registry: Arc::new(Mutex::new(HashMap::new())),
            cache: None,
            jobs: 1,
            metrics,
            trace_config: TraceConfig::default(),
        }
    }

    /// Set the tracing knobs ([`TraceConfig::disabled`] turns every span
    /// into a no-op — the bench_trace overhead gate's baseline).
    pub fn with_trace_config(mut self, config: TraceConfig) -> Runner {
        self.trace_config = config;
        self
    }

    /// Set the wavefront width: up to `jobs` ready nodes execute
    /// concurrently, each committing its table to the transactional
    /// branch as it finishes (see `doc/SCHEDULER.md`). Clamped to ≥ 1;
    /// the published branch state is identical for every width.
    pub fn with_jobs(mut self, jobs: usize) -> Runner {
        self.jobs = jobs.max(1);
        self
    }

    /// The configured wavefront width.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Enable the content-addressed run cache: nodes whose key matches a
    /// verified entry publish the memoized snapshot instead of
    /// executing. See `doc/RUN_CACHE.md`.
    pub fn with_cache(mut self, cache: Arc<RunCache>) -> Runner {
        self.cache = Some(cache);
        self
    }

    /// The attached run cache, if any.
    pub fn cache(&self) -> Option<&Arc<RunCache>> {
        self.cache.as_ref()
    }

    /// Drop the attached run cache from this engine clone — the
    /// `--no-cache` escape hatch, applied per request by the API server
    /// (the shared engine keeps its cache; only this clone executes
    /// every node).
    pub fn without_cache(mut self) -> Runner {
        self.cache = None;
        self
    }

    /// Look up the immutable record of a finished run — the in-memory
    /// registry first, then the catalog's durable run records (journaled
    /// + checkpointed), so a journaled lake answers `get_run` across
    /// process restarts.
    pub fn get_run(&self, run_id: &str) -> Option<RunState> {
        if let Some(s) = self.registry.lock().unwrap().get(run_id).cloned() {
            return Some(s);
        }
        self.catalog
            .get_run_record(run_id)
            .and_then(|j| run_state_from_json(run_id, &j))
    }

    /// Execute `plan` against branch `target`.
    ///
    /// `failure` injects faults for the experiments; `verifiers` are the
    /// protocol's step-3 data tests. Returns the final [`RunState`]
    /// (also queryable later by run_id).
    pub fn run(
        &self,
        plan: &Plan,
        target: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
    ) -> Result<RunState> {
        self.run_with_id(plan, target, mode, failure, verifiers, &unique_id("run"))
    }

    /// [`Runner::run`] with a caller-chosen run id. Snapshot ids derive
    /// from the run id, so pinning it makes two runs of the same plan on
    /// the same data publish byte-identical states — the determinism
    /// tests compare `--jobs 1` against `--jobs 4` this way. The id must
    /// be unique among *live* transactional branches (the run's
    /// `txn/<run_id>` branch name is derived from it).
    pub fn run_with_id(
        &self,
        plan: &Plan,
        target: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
        run_id: &str,
    ) -> Result<RunState> {
        self.run_traced(plan, target, mode, failure, verifiers, run_id, None)
    }

    /// [`Runner::run_with_id`] continuing a wire-propagated trace
    /// context: the run's root span parents at `ctx.span_id`, so a
    /// loopback client + server produce one stitched trace. The run's
    /// spans are journaled beside its terminal record
    /// ([`Catalog::put_run_trace`](crate::catalog::Catalog::put_run_trace)),
    /// so `bauplan trace <run-id>` answers across restarts.
    pub fn run_traced(
        &self,
        plan: &Plan,
        target: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
        run_id: &str,
        ctx: Option<&TraceCtx>,
    ) -> Result<RunState> {
        let run_id = run_id.to_string();
        let trace = match ctx {
            Some(c) => Trace::with_ctx(c, &self.trace_config),
            None => Trace::new(&self.trace_config),
        };
        let run_span = trace.span("run");
        run_span.attr_str("run_id", &run_id);
        run_span.attr_str("branch", target);
        run_span.attr_str(
            "mode",
            match mode {
                RunMode::Transactional => "transactional",
                RunMode::DirectWrite => "direct_write",
            },
        );
        let start_commit = self.catalog.resolve(target)?;
        let code_hash = plan_fingerprint(plan);

        // durability crash point: arm the journal fault, if requested
        if let Some(n) = failure.journal_fail_after {
            self.catalog.journal_inject_fail_after(n);
        }

        let exec_branch = match mode {
            RunMode::Transactional => {
                let bs = run_span.child("run.create_txn_branch");
                match self.metrics.time("run.create_txn_branch", || {
                    self.catalog.create_txn_branch(target, &run_id)
                }) {
                    Ok(info) => info.name,
                    Err(e) => {
                        bs.fail(e.to_string());
                        run_span.fail(e.to_string());
                        return Err(e);
                    }
                }
            }
            RunMode::DirectWrite => target.to_string(),
        };

        let mut outputs: Vec<String> = Vec::new();
        let mut cache_ctx = CacheRunCtx::default();
        // step 2, wavefront edition: every ready node dispatches
        // concurrently (up to `jobs`), committing per table as results
        // arrive — see runs/scheduler.rs for the invariants.
        let env = scheduler::SchedulerEnv {
            catalog: self.catalog.clone(),
            worker: self.worker.clone(),
            cache: self.cache.clone(),
            metrics: self.metrics.clone(),
            span: run_span.child("scheduler"),
        };
        env.span.attr_str("branch", &exec_branch);
        env.span.attr_u64("jobs", self.jobs as u64);
        let result = scheduler::execute_plan(
            &env,
            plan,
            &exec_branch,
            &run_id,
            failure,
            self.jobs,
            &mut outputs,
            &mut cache_ctx,
        );
        if let Err(e) = &result {
            env.span.fail(e.to_string());
        }
        drop(env); // ends the scheduler span before verification starts
        let result = result.and_then(|_| {
            // step 3: verifiers on B' (or on the target, in direct mode)
            let vs = run_span.child("run.verify");
            vs.attr_u64("verifiers", verifiers.len() as u64);
            let state = self.catalog.read_ref(&exec_branch)?;
            for v in verifiers {
                v.check(&self.worker, &state).map_err(|e| {
                    vs.fail(e.to_string());
                    BauplanError::RunFailed {
                        run_id: run_id.clone(),
                        node: format!("verifier:{}", v.name),
                        cause: e.to_string(),
                    }
                })?;
            }
            Ok(())
        });

        // kill mode: the "process" dies here — no abort bookkeeping, no
        // registry entry, and crucially no cache populate (the pending
        // entries below die with the process). Only the journal (if
        // durable) witnessed the run; Catalog::recover must reconstruct a
        // consistent state from it.
        let result = match result {
            Err(e) if failure.is_kill() => return Err(e),
            other => other,
        };

        // populate-after-verify: executed nodes become reusable only now
        // that step 3 passed — a cache hit can never skip a check a
        // fresh run would have enforced. Entries are pinned before they
        // are published so GC can never race an entry's snapshot away.
        if result.is_ok() {
            if let Some(cache) = &self.cache {
                for (key, snap_id, bytes) in cache_ctx.pending.drain(..) {
                    if self.catalog.pin_snapshot(&snap_id).is_err() {
                        continue; // snapshot vanished; nothing to cache
                    }
                    let (inserted, displaced) = cache.populate(&key, &snap_id, bytes);
                    if !inserted {
                        self.catalog.unpin_snapshot(&snap_id);
                    }
                    for d in displaced {
                        self.catalog.unpin_snapshot(&d.snapshot_id);
                    }
                }
            }
        }

        let status = match (mode, result) {
            (RunMode::Transactional, Ok(())) => {
                // step 4: atomic publish — merge B' into B, delete B'.
                let ps = run_span.child("run.publish");
                let merged = self.metrics.time("run.merge_publish", || {
                    self.catalog.merge(&exec_branch, target, false)
                });
                if let Err(e) = &merged {
                    ps.fail(e.to_string());
                }
                match merged {
                    Ok(_) => {
                        self.catalog.set_branch_state(&exec_branch, BranchState::Merged)?;
                        self.catalog.delete_branch(&exec_branch)?;
                        self.metrics.incr("run.success", 1);
                        RunStatus::Success
                    }
                    Err(e) => {
                        // merge refused (e.g. conflicting concurrent run):
                        // still a *total* failure — target untouched.
                        self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                        self.metrics.incr("run.aborted", 1);
                        RunStatus::Aborted {
                            txn_branch: exec_branch.clone(),
                            cause: e.to_string(),
                        }
                    }
                }
            }
            (RunMode::Transactional, Err(e)) => {
                self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                self.metrics.incr("run.aborted", 1);
                RunStatus::Aborted {
                    txn_branch: exec_branch.clone(),
                    cause: e.to_string(),
                }
            }
            (RunMode::DirectWrite, Ok(())) => {
                self.metrics.incr("run.success", 1);
                RunStatus::Success
            }
            (RunMode::DirectWrite, Err(e)) => {
                // Fig. 3 top: the target now holds a partial subset of the
                // outputs — a plan-order prefix at jobs=1; at higher widths
                // any independent sibling that committed before
                // cancellation (outputs lists exactly which).
                self.metrics.incr("run.failed_partial", 1);
                RunStatus::FailedPartial {
                    tables_published: outputs.len(),
                    cause: e.to_string(),
                }
            }
        };

        let state = RunState {
            run_id: run_id.clone(),
            pipeline: plan.pipeline.clone(),
            target: target.to_string(),
            start_commit,
            code_hash,
            mode,
            status,
            outputs,
            cache_hits: cache_ctx.hits,
            cache_misses: cache_ctx.misses,
            cache_bytes_saved: cache_ctx.bytes_saved,
        };
        self.registry.lock().unwrap().insert(run_id.clone(), state.clone());
        // durable registry: journal the terminal record so `get_run`
        // answers after a restart. Best-effort — the run's outcome is
        // already published (or aborted) by this point, so a failing
        // journal must not turn a finished run into an error.
        if self.catalog.is_durable()
            && self.catalog.put_run_record(&run_id, run_state_to_json(&state)).is_err()
        {
            self.metrics.incr("run.record_journal_failed", 1);
        }
        // close the root span and journal the trace beside the record,
        // under the same best-effort contract
        match &state.status {
            RunStatus::Success => {}
            RunStatus::Aborted { cause, .. } | RunStatus::FailedPartial { cause, .. } => {
                run_span.fail(cause.clone());
            }
        }
        run_span.attr_u64("cache_hits", state.cache_hits);
        run_span.attr_u64("cache_misses", state.cache_misses);
        run_span.finish();
        if self.catalog.is_durable()
            && trace.is_enabled()
            && self.catalog.put_run_trace(&run_id, trace.to_json()).is_err()
        {
            self.metrics.incr("run.trace_journal_failed", 1);
        }
        Ok(state)
    }

    /// Fetch the journaled span trace of a finished run (canonical JSON;
    /// see [`Trace::to_json`]). `None` while tracing is disabled, for
    /// non-durable catalogs, or for runs killed before their terminal
    /// state.
    pub fn get_run_trace(&self, run_id: &str) -> Option<Json> {
        self.catalog.get_run_trace(run_id)
    }
}

/// Deterministic fingerprint of a plan — the "code_zip" identity that,
/// together with `start_commit`, makes a run reproducible (§3.2).
///
/// Canonical byte encoding, never `Debug` formatting: every field is a
/// length-prefixed part (via
/// [`content_hash_parts`](crate::util::id::content_hash_parts)), input
/// and parameter lists carry explicit counts, and `f32` parameters enter
/// as little-endian bit patterns — so the digest is bit-exact in params
/// (`-0.0 != 0.0`, NaN payloads distinct) and stable across Rust
/// versions and processes. Pinned by the golden digest in
/// `tests/properties.rs`.
pub fn plan_fingerprint(plan: &Plan) -> String {
    let mut parts: Vec<Vec<u8>> = Vec::with_capacity(2 + plan.nodes.len() * 6);
    parts.push(b"plan.v2".to_vec());
    parts.push(plan.pipeline.as_bytes().to_vec());
    for n in &plan.nodes {
        parts.push(n.output.as_bytes().to_vec());
        parts.push(n.out_schema.as_bytes().to_vec());
        parts.push(n.op.as_bytes().to_vec());
        parts.push((n.inputs.len() as u64).to_le_bytes().to_vec());
        for (table, schema) in &n.inputs {
            parts.push(table.as_bytes().to_vec());
            parts.push(schema.as_bytes().to_vec());
        }
        let mut bits = Vec::with_capacity(8 + n.params.len() * 4);
        bits.extend_from_slice(&(n.params.len() as u64).to_le_bytes());
        for p in &n.params {
            bits.extend_from_slice(&p.to_bits().to_le_bytes());
        }
        parts.push(bits);
    }
    let refs: Vec<&[u8]> = parts.iter().map(|v| v.as_slice()).collect();
    crate::util::id::content_hash_parts(&refs)
}

/// Serialize a terminal [`RunState`] to the canonical JSON body the
/// catalog journals and checkpoints (the run id is carried as the record
/// key, matching the catalog's commit/snapshot conventions).
pub fn run_state_to_json(s: &RunState) -> Json {
    let status = match &s.status {
        RunStatus::Success => Json::obj(vec![("kind", Json::str("success"))]),
        RunStatus::Aborted { txn_branch, cause } => Json::obj(vec![
            ("kind", Json::str("aborted")),
            ("txn_branch", Json::str(txn_branch)),
            ("cause", Json::str(cause)),
        ]),
        RunStatus::FailedPartial { tables_published, cause } => Json::obj(vec![
            ("kind", Json::str("failed_partial")),
            ("tables_published", Json::num(*tables_published as f64)),
            ("cause", Json::str(cause)),
        ]),
    };
    Json::obj(vec![
        ("pipeline", Json::str(&s.pipeline)),
        ("target", Json::str(&s.target)),
        ("start_commit", Json::str(&s.start_commit)),
        ("code_hash", Json::str(&s.code_hash)),
        (
            "mode",
            Json::str(match s.mode {
                RunMode::Transactional => "transactional",
                RunMode::DirectWrite => "direct_write",
            }),
        ),
        ("status", status),
        ("outputs", Json::Arr(s.outputs.iter().map(Json::str).collect())),
        ("cache_hits", Json::num(s.cache_hits as f64)),
        ("cache_misses", Json::num(s.cache_misses as f64)),
        ("cache_bytes_saved", Json::num(s.cache_bytes_saved as f64)),
    ])
}

/// Inverse of [`run_state_to_json`]. `None` on malformed or
/// unrecognized records (a newer writer's format reads as "not found",
/// never as a panic).
pub fn run_state_from_json(run_id: &str, j: &Json) -> Option<RunState> {
    let mode = match j.get("mode").as_str()? {
        "transactional" => RunMode::Transactional,
        "direct_write" => RunMode::DirectWrite,
        _ => return None,
    };
    let sj = j.get("status");
    let status = match sj.get("kind").as_str()? {
        "success" => RunStatus::Success,
        "aborted" => RunStatus::Aborted {
            txn_branch: sj.get("txn_branch").as_str()?.to_string(),
            cause: sj.get("cause").as_str().unwrap_or("").to_string(),
        },
        "failed_partial" => RunStatus::FailedPartial {
            tables_published: sj.get("tables_published").as_usize()?,
            cause: sj.get("cause").as_str().unwrap_or("").to_string(),
        },
        _ => return None,
    };
    Some(RunState {
        run_id: run_id.to_string(),
        pipeline: j.get("pipeline").as_str()?.to_string(),
        target: j.get("target").as_str()?.to_string(),
        start_commit: j.get("start_commit").as_str()?.to_string(),
        code_hash: j.get("code_hash").as_str()?.to_string(),
        mode,
        status,
        outputs: j
            .get("outputs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|o| o.as_str().map(String::from))
            .collect(),
        cache_hits: j.get("cache_hits").as_f64().unwrap_or(0.0) as u64,
        cache_misses: j.get("cache_misses").as_f64().unwrap_or(0.0) as u64,
        cache_bytes_saved: j.get("cache_bytes_saved").as_f64().unwrap_or(0.0) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fingerprint_is_stable_and_sensitive() {
        let p1 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        let p2 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        assert_eq!(plan_fingerprint(&p1), plan_fingerprint(&p2));

        let mut spec = crate::dag::PipelineSpec::paper_pipeline();
        spec.nodes[1].params[2] = 0.75; // change child's scale
        let p3 = spec.plan().unwrap();
        assert_ne!(plan_fingerprint(&p1), plan_fingerprint(&p3));
    }

    #[test]
    fn plan_fingerprint_is_bit_exact_in_params() {
        let base = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        let mut spec = crate::dag::PipelineSpec::paper_pipeline();
        // -0.0 vs 0.0: equal as floats, distinct bit patterns
        spec.nodes[1].params[0] = -0.0;
        let negz = spec.plan().unwrap();
        assert_ne!(plan_fingerprint(&base), plan_fingerprint(&negz));
    }

    #[test]
    fn run_state_json_roundtrips_every_status() {
        let statuses = vec![
            RunStatus::Success,
            RunStatus::Aborted {
                txn_branch: "txn/run_1".into(),
                cause: "verifier failed".into(),
            },
            RunStatus::FailedPartial { tables_published: 2, cause: "crash".into() },
        ];
        for (i, status) in statuses.into_iter().enumerate() {
            let s = RunState {
                run_id: format!("run_{i}"),
                pipeline: "paper_dag".into(),
                target: "main".into(),
                start_commit: "c0".into(),
                code_hash: "abc".into(),
                mode: if i == 2 {
                    RunMode::DirectWrite
                } else {
                    RunMode::Transactional
                },
                status,
                outputs: vec!["parent_table".into(), "child_table".into()],
                cache_hits: 1,
                cache_misses: 2,
                cache_bytes_saved: 512,
            };
            let back = run_state_from_json(&s.run_id, &run_state_to_json(&s)).unwrap();
            assert_eq!(back, s);
        }
        // malformed records decode to None, never panic
        assert!(run_state_from_json("r", &Json::Null).is_none());
        assert!(run_state_from_json("r", &Json::obj(vec![("mode", Json::str("warp"))])).is_none());
    }
}
