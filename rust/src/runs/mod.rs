//! Transactional pipeline runs (paper §3.3, Fig. 3).
//!
//! The protocol, verbatim from the paper — if `B` is the target branch:
//!
//! 1. automatically create a transactional branch `B'` from `B`;
//! 2. write the DAG tables into `B'` (each table commit atomic);
//! 3. run data tests / user-defined verifiers on `B'`;
//! 4. only if no code or data error is raised, merge `B'` back into `B`
//!    and delete it.
//!
//! On failure, `B` is untouched (total failure instead of partial
//! failure) and `B'` is retained in `Aborted` state for triage — with
//! the visibility guardrail the Alloy counterexample motivates.
//!
//! [`RunMode::DirectWrite`] is the baseline: the same execution writing
//! straight to `B` (what today's lakehouses do, Fig. 3 top) — it exists
//! so experiments E3/E4/E5 can quantify the difference.
//!
//! When the catalog is durable (opened with
//! [`Catalog::recover`](crate::catalog::Catalog::recover)), every step of
//! the protocol is journaled, so a run killed mid-flight (simulated by
//! [`FailurePlan::kill_after`]) leaves a journal whose replay reconstructs
//! the target branch untouched and the transactional branch `Aborted` —
//! never half-merged. The protocol ↔ journal mapping is specified in
//! `doc/COMMIT_PIPELINE.md`.
#![warn(missing_docs)]

pub mod failure;
pub mod verifier;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::catalog::{BranchState, Catalog};
use crate::dag::Plan;
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::util::id::unique_id;
use crate::worker::Worker;
pub use failure::FailurePlan;
pub use verifier::Verifier;

/// How a run publishes its outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// The paper's protocol: hidden transactional branch + atomic merge.
    Transactional,
    /// Baseline: write each table directly to the target branch.
    DirectWrite,
}

/// Terminal status of a run.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    /// All outputs published atomically.
    Success,
    /// Failed; transactional branch retained (name included).
    Aborted {
        /// The retained `txn/...` branch holding the partial outputs.
        txn_branch: String,
        /// Why the run aborted.
        cause: String,
    },
    /// Failed in DirectWrite mode; target branch may hold partial state.
    FailedPartial {
        /// How many output tables leaked onto the target branch.
        tables_published: usize,
        /// Why the run failed.
        cause: String,
    },
}

/// Immutable record of one run — what `client.get_run(run_id)` returns
/// (Listing 6): enough to reproduce the run (starting commit + code id).
#[derive(Debug, Clone)]
pub struct RunState {
    /// Unique run identifier (`run_...`).
    pub run_id: String,
    /// Name of the pipeline that ran.
    pub pipeline: String,
    /// Target branch name.
    pub target: String,
    /// Commit the target branch pointed at when the run began — the
    /// "data commit" half of reproducibility.
    pub start_commit: String,
    /// Fingerprint of the pipeline code ("code_zip" in Listing 6).
    pub code_hash: String,
    /// Publication mode the run used.
    pub mode: RunMode,
    /// Terminal status.
    pub status: RunStatus,
    /// Tables written, in order.
    pub outputs: Vec<String>,
}

/// The run engine: owns the protocol and the run registry.
#[derive(Clone)]
pub struct Runner {
    catalog: Catalog,
    worker: Worker,
    registry: Arc<Mutex<HashMap<String, RunState>>>,
    /// Latency/counter metrics for the protocol steps.
    pub metrics: Arc<Metrics>,
}

impl Runner {
    /// A run engine over `catalog`, executing node compute on `worker`.
    pub fn new(catalog: Catalog, worker: Worker) -> Runner {
        Runner {
            catalog,
            worker,
            registry: Arc::new(Mutex::new(HashMap::new())),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Look up the immutable record of a finished run.
    pub fn get_run(&self, run_id: &str) -> Option<RunState> {
        self.registry.lock().unwrap().get(run_id).cloned()
    }

    /// Execute `plan` against branch `target`.
    ///
    /// `failure` injects faults for the experiments; `verifiers` are the
    /// protocol's step-3 data tests. Returns the final [`RunState`]
    /// (also queryable later by run_id).
    pub fn run(
        &self,
        plan: &Plan,
        target: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
    ) -> Result<RunState> {
        let run_id = unique_id("run");
        let start_commit = self.catalog.resolve(target)?;
        let code_hash = plan_fingerprint(plan);

        // durability crash point: arm the journal fault, if requested
        if let Some(n) = failure.journal_fail_after {
            self.catalog.journal_inject_fail_after(n);
        }

        let exec_branch = match mode {
            RunMode::Transactional => {
                let info = self.metrics.time("run.create_txn_branch", || {
                    self.catalog.create_txn_branch(target, &run_id)
                })?;
                info.name
            }
            RunMode::DirectWrite => target.to_string(),
        };

        let mut outputs: Vec<String> = Vec::new();
        let result = self.execute_nodes(plan, &exec_branch, &run_id, failure, &mut outputs);
        let result = result.and_then(|_| {
            // step 3: verifiers on B' (or on the target, in direct mode)
            let state = self.catalog.read_ref(&exec_branch)?;
            for v in verifiers {
                v.check(&self.worker, &state).map_err(|e| {
                    BauplanError::RunFailed {
                        run_id: run_id.clone(),
                        node: format!("verifier:{}", v.name),
                        cause: e.to_string(),
                    }
                })?;
            }
            Ok(())
        });

        // kill mode: the "process" dies here — no abort bookkeeping, no
        // registry entry. Only the journal (if durable) witnessed the run;
        // Catalog::recover must reconstruct a consistent state from it.
        let result = match result {
            Err(e) if failure.is_kill() => return Err(e),
            other => other,
        };

        let status = match (mode, result) {
            (RunMode::Transactional, Ok(())) => {
                // step 4: atomic publish — merge B' into B, delete B'.
                let merged = self.metrics.time("run.merge_publish", || {
                    self.catalog.merge(&exec_branch, target, false)
                });
                match merged {
                    Ok(_) => {
                        self.catalog.set_branch_state(&exec_branch, BranchState::Merged)?;
                        self.catalog.delete_branch(&exec_branch)?;
                        self.metrics.incr("run.success", 1);
                        RunStatus::Success
                    }
                    Err(e) => {
                        // merge refused (e.g. conflicting concurrent run):
                        // still a *total* failure — target untouched.
                        self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                        self.metrics.incr("run.aborted", 1);
                        RunStatus::Aborted {
                            txn_branch: exec_branch.clone(),
                            cause: e.to_string(),
                        }
                    }
                }
            }
            (RunMode::Transactional, Err(e)) => {
                self.catalog.set_branch_state(&exec_branch, BranchState::Aborted)?;
                self.metrics.incr("run.aborted", 1);
                RunStatus::Aborted {
                    txn_branch: exec_branch.clone(),
                    cause: e.to_string(),
                }
            }
            (RunMode::DirectWrite, Ok(())) => {
                self.metrics.incr("run.success", 1);
                RunStatus::Success
            }
            (RunMode::DirectWrite, Err(e)) => {
                // Fig. 3 top: the target now holds a prefix of the outputs.
                self.metrics.incr("run.failed_partial", 1);
                RunStatus::FailedPartial {
                    tables_published: outputs.len(),
                    cause: e.to_string(),
                }
            }
        };

        let state = RunState {
            run_id: run_id.clone(),
            pipeline: plan.pipeline.clone(),
            target: target.to_string(),
            start_commit,
            code_hash,
            mode,
            status,
            outputs,
        };
        self.registry.lock().unwrap().insert(run_id, state.clone());
        Ok(state)
    }

    /// Step 2: execute nodes in plan order, committing each output table
    /// to the execution branch (atomic per-table commits).
    fn execute_nodes(
        &self,
        plan: &Plan,
        exec_branch: &str,
        run_id: &str,
        failure: &FailurePlan,
        outputs: &mut Vec<String>,
    ) -> Result<()> {
        for node in &plan.nodes {
            failure.check_before(&node.output, run_id)?;
            let state = self.catalog.read_ref(exec_branch)?;
            let table = self.worker.execute_node(node, &state)?;
            failure.poison_hook(&node.output)?;
            let snap = self.worker.persist_table(&table, run_id)?;
            self.catalog.commit_table(
                exec_branch,
                &node.output,
                snap,
                "runner",
                &format!("run {run_id}: write {}", node.output),
                Some(run_id.to_string()),
            )?;
            outputs.push(node.output.clone());
            failure.check_after(&node.output, run_id)?;
        }
        Ok(())
    }
}

/// Deterministic fingerprint of a plan — the "code_zip" identity that,
/// together with `start_commit`, makes a run reproducible (§3.2).
pub fn plan_fingerprint(plan: &Plan) -> String {
    let mut desc = String::new();
    desc.push_str(&plan.pipeline);
    for n in &plan.nodes {
        desc.push_str(&format!(
            "|{}:{}:{}:{:?}:{:?}",
            n.output, n.out_schema, n.op, n.inputs, n.params
        ));
    }
    crate::util::id::content_hash(desc.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_fingerprint_is_stable_and_sensitive() {
        let p1 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        let p2 = crate::dag::PipelineSpec::paper_pipeline().plan().unwrap();
        assert_eq!(plan_fingerprint(&p1), plan_fingerprint(&p2));

        let mut spec = crate::dag::PipelineSpec::paper_pipeline();
        spec.nodes[1].params[2] = 0.75; // change child's scale
        let p3 = spec.plan().unwrap();
        assert_ne!(plan_fingerprint(&p1), plan_fingerprint(&p3));
    }
}
