//! The wavefront scheduler: dependency-aware, concurrent execution of
//! step 2 of the run protocol.
//!
//! The sequential engine executed nodes one at a time in plan order,
//! leaving the executor pool idle while independent nodes queued behind
//! each other. This module replaces that loop with a ready-set
//! scheduler over the plan's explicit dependency edges
//! ([`Plan::deps`](crate::dag::Plan), [`Plan::dependents`](crate::dag::Plan::dependents)):
//! every node whose producers have committed is dispatched immediately
//! onto its own worker thread (bounded by the `--jobs` knob), kernels
//! reach the compute backend through the non-blocking
//! [`ExecHandle::submit`](crate::runtime::ExecHandle::submit) API, and
//! each finished table is committed to the transactional branch the
//! moment it is ready via the catalog's optimistic rebase path
//! ([`Catalog::commit`](crate::catalog::Catalog::commit) under
//! `RetryPolicy::Rebase`).
//!
//! Concurrency must not weaken the paper's protocol; the invariants
//! (spec: `doc/SCHEDULER.md`, enforced by `tests/integration_scheduler.rs`):
//!
//! - **per-node sequence is unchanged** — lookup-before-execute cache
//!   hits, poison hooks, M3 validation before persist, staged
//!   populate-after-verify entries, and the `check_before`/`check_after`
//!   failure points all run in the same order *within* a node as the
//!   sequential engine ran them;
//! - **commit order may vary, the published state may not** — every node
//!   writes a distinct table, so whatever order the CAS loop serializes
//!   commits in, the branch's final table map is schedule-independent
//!   (`--jobs 1` and `--jobs 4` publish byte-identical states);
//! - **failure injection stays deterministic per node name** — a
//!   [`FailurePlan`] keyed on a node fires no matter which thread or
//!   wavefront runs it;
//! - **first error cancels in-flight siblings** — dispatch stops, running
//!   nodes abandon their work at the next cancellation point (before
//!   their commit), and the first error aborts the run exactly as the
//!   sequential engine did;
//! - **`--jobs 1` replays the sequential engine exactly** — the ready
//!   set is drained smallest-topological-index first (plan order), and
//!   each node runs inline on the calling thread, so the default path
//!   pays no spawn overhead and panics propagate raw, as before.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::cache::{run_cache_key, CacheKey, RunCache};
use crate::catalog::{Catalog, Commit, CommitRequest, RetryPolicy, Snapshot};
use crate::dag::{NodeSpec, Plan};
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::runs::failure::FailurePlan;
use crate::runs::CacheRunCtx;
use crate::trace::Span;
use crate::worker::Worker;

/// The shared services a scheduled node needs — cheap clones of the run
/// engine's handles.
pub(crate) struct SchedulerEnv {
    /// The catalog the run commits into.
    pub catalog: Catalog,
    /// Node compute + M3 validation.
    pub worker: Worker,
    /// The run cache, if attached.
    pub cache: Option<Arc<RunCache>>,
    /// The runner's metrics registry.
    pub metrics: Arc<Metrics>,
    /// The run's scheduler span; each dispatched node opens a
    /// `node:<table>` child under it (a no-op span when tracing is off).
    pub span: Span,
}

/// Everything one node task owns (moved onto its worker thread).
struct NodeCtx {
    catalog: Catalog,
    worker: Worker,
    cache: Option<Arc<RunCache>>,
    metrics: Arc<Metrics>,
    node: NodeSpec,
    /// Plan-time static cache fingerprint of the node.
    static_fp: Option<String>,
    idx: usize,
    exec_branch: String,
    run_id: String,
    failure: FailurePlan,
    /// The node's `node:<table>` span — records when the ctx drops.
    span: Span,
    /// Set by the scheduler when a sibling failed: abandon before commit.
    cancel: Arc<AtomicBool>,
    /// Set the instant this node's table commit lands. Shared with the
    /// panic guard so `RunState.outputs` / `tables_published` stay
    /// accurate even if the node panics *after* its commit.
    committed: Arc<Mutex<Option<String>>>,
}

/// Drop guard armed for the whole life of a node task: if the task
/// panics anywhere (a poisoned lock, an indexing bug), unwinding drops
/// the guard, which reports the node as failed — so the scheduler
/// aborts the run instead of blocking forever on a completion that will
/// never arrive.
struct PanicGuard {
    tx: mpsc::Sender<NodeDone>,
    idx: usize,
    run_id: String,
    node: String,
    /// The node's shared commit slot — read on drop so a panic after
    /// the commit still reports the table as published.
    committed: Arc<Mutex<Option<String>>>,
    armed: bool,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if self.armed {
            // the slot's lock is held only for an assignment, but if the
            // panic poisoned it anyway, degrade to "not committed"
            let committed = self.committed.lock().map(|g| g.clone()).unwrap_or(None);
            let _ = self.tx.send(NodeDone {
                idx: self.idx,
                committed,
                result: Err(BauplanError::RunFailed {
                    run_id: self.run_id.clone(),
                    node: self.node.clone(),
                    cause: "node task panicked".into(),
                }),
                hit: false,
                miss: false,
                bytes_saved: 0,
                staged: None,
            });
        }
    }
}

/// Terminal report of one node task (exactly one per dispatched node).
struct NodeDone {
    idx: usize,
    /// Output table name, present iff the node's commit landed — kept
    /// separate from `result` because `check_after` fires *after* the
    /// commit (a failed node may still have published its table).
    committed: Option<String>,
    result: Result<()>,
    hit: bool,
    miss: bool,
    bytes_saved: u64,
    /// `(key, snapshot id, bytes)` staged for populate-after-verify.
    staged: Option<(CacheKey, String, u64)>,
}

/// Step 2, wavefront edition: execute every node of `plan` against
/// `exec_branch`, dispatching up to `jobs` ready nodes concurrently.
/// Appends table names to `outputs` in commit-completion order (plan
/// order when `jobs == 1`) and merges cache accounting into `cache_ctx`.
pub(crate) fn execute_plan(
    env: &SchedulerEnv,
    plan: &Plan,
    exec_branch: &str,
    run_id: &str,
    failure: &FailurePlan,
    jobs: usize,
    outputs: &mut Vec<String>,
    cache_ctx: &mut CacheRunCtx,
) -> Result<()> {
    let n = plan.nodes.len();
    if n == 0 {
        return Ok(());
    }
    let jobs = jobs.max(1);
    let dependents = plan.dependents();
    let mut remaining: Vec<usize> = plan.deps.iter().map(|d| d.len()).collect();
    // ready nodes, kept sorted descending so pop() yields the smallest
    // topological index — with jobs == 1 this replays plan order exactly
    let mut ready: Vec<usize> = (0..n).filter(|&i| remaining[i] == 0).collect();
    ready.sort_unstable_by_key(|&i| std::cmp::Reverse(i));

    let (tx, rx) = mpsc::channel::<NodeDone>();
    let cancel = Arc::new(AtomicBool::new(false));
    let mut in_flight = 0usize;
    let mut finished = 0usize;
    let mut peak = 0usize;
    let mut first_err: Option<BauplanError> = None;

    while finished < n {
        // dispatch every ready node up to the jobs bound — unless a
        // sibling already failed, in which case we only drain
        while first_err.is_none() && in_flight < jobs {
            let Some(idx) = ready.pop() else { break };
            let committed = Arc::new(Mutex::new(None));
            let node_span = env.span.child(&format!("node:{}", plan.nodes[idx].output));
            node_span.attr_str("node", &plan.nodes[idx].output);
            let ctx = NodeCtx {
                catalog: env.catalog.clone(),
                worker: env.worker.clone(),
                cache: env.cache.clone(),
                metrics: env.metrics.clone(),
                node: plan.nodes[idx].clone(),
                static_fp: plan.node_fps.get(idx).cloned(),
                idx,
                exec_branch: exec_branch.to_string(),
                run_id: run_id.to_string(),
                failure: failure.clone(),
                span: node_span,
                cancel: cancel.clone(),
                committed: committed.clone(),
            };
            if jobs == 1 {
                // sequential fast path: run on the calling thread like the
                // old engine — no spawn, and a panic propagates raw
                let _ = tx.send(run_node(&ctx));
            } else {
                let mut guard = PanicGuard {
                    tx: tx.clone(),
                    idx,
                    run_id: run_id.to_string(),
                    node: plan.nodes[idx].output.clone(),
                    committed,
                    armed: true,
                };
                std::thread::spawn(move || {
                    let done = run_node(&ctx);
                    guard.armed = false;
                    let _ = guard.tx.send(done);
                });
            }
            in_flight += 1;
            peak = peak.max(in_flight);
        }
        if in_flight == 0 {
            break; // error path drained; undispatched nodes never run
        }
        let done = rx.recv().expect("scheduler completion channel closed");
        in_flight -= 1;
        finished += 1;
        if let Some(output) = done.committed {
            outputs.push(output);
        }
        if done.hit {
            cache_ctx.hits += 1;
            cache_ctx.bytes_saved += done.bytes_saved;
        }
        if done.miss {
            cache_ctx.misses += 1;
        }
        if let Some(staged) = done.staged {
            cache_ctx.pending.push(staged);
        }
        match done.result {
            Ok(()) => {
                let mut unlocked = false;
                for &d in &dependents[done.idx] {
                    remaining[d] -= 1;
                    if remaining[d] == 0 {
                        ready.push(d);
                        unlocked = true;
                    }
                }
                if unlocked {
                    ready.sort_unstable_by_key(|&i| std::cmp::Reverse(i));
                }
            }
            Err(e) => {
                // first error wins; cancellation stops dispatch above and
                // makes in-flight siblings abandon before their commit
                if first_err.is_none() {
                    cancel.store(true, Ordering::SeqCst);
                    first_err = Some(e);
                }
            }
        }
    }

    env.metrics.incr("run.wavefronts", plan.levels().len() as u64);
    env.metrics.record("run.parallelism", peak as u64);
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Run one node start to finish; never panics on the protocol path —
/// every fallible step folds into the returned report.
fn run_node(ctx: &NodeCtx) -> NodeDone {
    let mut done = NodeDone {
        idx: ctx.idx,
        committed: None,
        result: Ok(()),
        hit: false,
        miss: false,
        bytes_saved: 0,
        staged: None,
    };
    done.result = run_node_inner(ctx, &mut done);
    done.committed = ctx.committed.lock().unwrap().clone();
    if let Err(e) = &done.result {
        ctx.span.fail(e.to_string());
    }
    done
}

/// The per-node protocol, step for step the sequence the sequential
/// engine ran: check_before → read state → lookup-before-execute →
/// execute → poison hook → M3-validate + persist → commit (CAS retry) →
/// check_after.
fn run_node_inner(ctx: &NodeCtx, done: &mut NodeDone) -> Result<()> {
    let output = ctx.node.output.clone();
    if ctx.cancel.load(Ordering::SeqCst) {
        return Err(cancelled(ctx, &output));
    }
    // pause point: lets a harness interleave catalog ops before this
    // node reads its input state (sim mid-run interleaving control)
    ctx.failure.at_pause(crate::runs::failure::FailurePoint::BeforeNode, &output);
    ctx.failure.check_before(&output, &ctx.run_id)?;
    let state = ctx.catalog.read_ref(&ctx.exec_branch)?;

    // ---- lookup-before-execute -------------------------------------
    let mut staged_key: Option<CacheKey> = None;
    if let Some(cache) = &ctx.cache {
        if let Some(key) =
            node_cache_key(&ctx.worker, &ctx.node, ctx.static_fp.as_deref(), &state)
        {
            let cache_metrics = ctx.metrics.clone().ns("cache");
            let mut hit_snap = None;
            if let Some(entry) = cache.lookup_traced(&key, &ctx.span) {
                match ctx.catalog.get_snapshot(&entry.snapshot_id) {
                    Ok(snap) => hit_snap = Some(snap),
                    Err(_) => {
                        // stale entry (snapshot no longer in this
                        // catalog): drop it and execute
                        let _ = cache.remove(&key);
                    }
                }
            }
            if let Some(snap) = hit_snap {
                if ctx.cancel.load(Ordering::SeqCst) {
                    return Err(cancelled(ctx, &output));
                }
                commit_output(ctx, snap, &format!("run {}: cache hit for {output}", ctx.run_id))?;
                *ctx.committed.lock().unwrap() = Some(output.clone());
                ctx.failure
                    .at_pause(crate::runs::failure::FailurePoint::AfterCommit, &output);
                let bytes = cache.mark_hit(&key);
                cache_metrics.incr("hits", 1);
                cache_metrics.incr("bytes_saved", bytes);
                ctx.span.attr_bool("cache_hit", true);
                ctx.span.attr_u64("bytes_saved", bytes);
                done.hit = true;
                done.bytes_saved = bytes;
                ctx.failure.check_after(&output, &ctx.run_id)?;
                return Ok(());
            }
            cache.mark_miss();
            cache_metrics.incr("misses", 1);
            ctx.span.attr_bool("cache_hit", false);
            done.miss = true;
            staged_key = Some(key);
        }
    }

    // ---- execute + stage for populate-after-verify -----------------
    let table = {
        let es = ctx.span.child("execute");
        match ctx.worker.execute_node_traced(&ctx.node, &state, &es) {
            Ok(t) => {
                es.attr_u64("rows", t.row_count() as u64);
                t
            }
            Err(e) => {
                es.fail(e.to_string());
                return Err(e);
            }
        }
    };
    ctx.failure.poison_hook(&output)?;
    let snap = ctx.worker.persist_table(&table, &ctx.run_id)?;
    if let Some(key) = staged_key {
        let bytes: u64 = snap
            .objects
            .iter()
            .filter_map(|o| ctx.catalog.store().object_size(o))
            .sum();
        done.staged = Some((key, snap.id.clone(), bytes));
    }
    if ctx.cancel.load(Ordering::SeqCst) {
        // a sibling failed while we computed: abandon before the commit
        return Err(cancelled(ctx, &output));
    }
    commit_output(ctx, snap, &format!("run {}: write {output}", ctx.run_id))?;
    *ctx.committed.lock().unwrap() = Some(output.clone());
    ctx.failure.at_pause(crate::runs::failure::FailurePoint::AfterCommit, &output);
    ctx.failure.check_after(&output, &ctx.run_id)?;
    Ok(())
}

/// Commit one output table through the catalog's optimistic rebase path.
fn commit_output(ctx: &NodeCtx, snap: Snapshot, message: &str) -> Result<()> {
    let cs = ctx.span.child(&format!("commit:{}", ctx.node.output));
    cs.attr_str("table", &ctx.node.output);
    cs.attr_str("snapshot", &snap.id);
    let req = CommitRequest::new(&ctx.exec_branch, &ctx.node.output, snap)
        .author("runner")
        .message(message)
        .run_id(Some(ctx.run_id.clone()))
        .retry(RetryPolicy::rebase());
    match ctx.catalog.commit(req) {
        Ok(out) => {
            cs.attr_u64("cas_retries", out.retries);
            if out.retries > 0 {
                ctx.metrics.incr("run.commit_cas_retries", out.retries);
            }
            Ok(())
        }
        Err(e) => {
            cs.fail(e.to_string());
            Err(e)
        }
    }
}

/// The error an in-flight node reports when a sibling's failure
/// cancelled it. Never surfaces as the run's cause: the scheduler keeps
/// only the *first* error, and cancellation is by construction later.
fn cancelled(ctx: &NodeCtx, node: &str) -> BauplanError {
    BauplanError::RunFailed {
        run_id: ctx.run_id.clone(),
        node: node.to_string(),
        cause: "cancelled: a sibling node failed".into(),
    }
}

/// Derive the run-cache key for `node` against the lake state it is
/// about to read: plan-time static fingerprint + compiled-artifact
/// fingerprint + input snapshot ids (declared order). `None` when any
/// component is unavailable (unknown op or missing input — the execute
/// path will surface the real error).
fn node_cache_key(
    worker: &Worker,
    node: &NodeSpec,
    static_fp: Option<&str>,
    state: &Commit,
) -> Option<CacheKey> {
    let static_fp = static_fp?;
    let artifact_fp = worker
        .runtime()
        .manifest()
        .artifact(&node.op)
        .ok()?
        .fingerprint();
    let mut input_snaps = Vec::with_capacity(node.inputs.len());
    for (t, _) in &node.inputs {
        input_snaps.push(state.snapshot_of(t)?.clone());
    }
    Some(run_cache_key(static_fp, &artifact_fp, &input_snaps))
}
