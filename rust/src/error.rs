//! Unified error type. Variants mirror the paper's failure taxonomy (§2):
//! schema failures, collaboration failures, correctness failures — plus the
//! infrastructure errors a real system needs.
//!
//! `Display` + `std::error::Error` are hand-implemented (`thiserror` is
//! not in the offline crate set); the rendered messages are part of the
//! test surface, so keep them stable.

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, BauplanError>;

/// All the ways a lakehouse operation can fail.
///
/// The contract/plan/runtime split matters: the paper's fail-fast principle
/// says a failure must surface at the earliest *moment* able to detect it,
/// and tests assert on the variant to prove the moment.
#[derive(Debug)]
pub enum BauplanError {
    // -- schema / contract failures (paper §2 failure mode 1) --------------
    /// A contract violation detected from declarations alone (moment M1).
    ContractLocal(String),
    /// A contract violation detected by the control plane while composing
    /// the DAG, before any execution is scheduled (moment M2).
    ContractPlan(String),
    /// Physical data failed validation at the worker, before persisting
    /// anything (moment M3).
    ContractRuntime(String),

    // -- collaboration failures (paper §2 failure mode 2) -------------------
    /// A ref (branch, tag, or commit id) that does not exist.
    UnknownRef(String),
    /// Attempt to create a ref whose name is already taken.
    RefExists(String),
    /// Optimistic-concurrency check failed: the ref moved past the head
    /// the caller read.
    CasConflict {
        /// The branch whose head moved.
        reference: String,
        /// The head the caller expected.
        expected: String,
        /// The head actually found.
        found: String,
    },
    /// Three-way merge found a table changed differently on both sides.
    MergeConflict(String),
    /// The visibility guardrail from the Alloy counterexample (Fig. 4):
    /// aborted transactional branches cannot be forked or merged without an
    /// explicit capability.
    Visibility(String),

    // -- correctness failures (paper §2 failure mode 3) ----------------------
    /// A pipeline run died at a node (compute error or injected crash).
    RunFailed {
        /// The run that failed.
        run_id: String,
        /// The node at which it failed.
        node: String,
        /// Human-readable cause.
        cause: String,
    },
    /// A transactional run was aborted; its branch is retained for triage.
    RunAborted(String),

    // -- infrastructure ------------------------------------------------------
    /// Object-store key (or snapshot id) not found.
    ObjectNotFound(String),
    /// Table absent from the commit it was looked up in.
    TableNotFound(String),
    /// Batch encode/decode failure.
    Codec(String),
    /// `manifest.json` missing, malformed, or inconsistent.
    Manifest(String),
    /// PJRT runtime failure (or the runtime is stubbed out, see
    /// `runtime::pjrt`).
    Pjrt(String),
    /// Pipeline DAG is malformed (cycles, unknown inputs, bad ops).
    Dag(String),
    /// Parse failure (JSON, project text, persisted catalog, journal).
    Parse(String),
    /// The durable catalog is poisoned: a group-commit leader's fsync
    /// failed, so the in-memory state may be ahead of what the journal
    /// can reproduce. Mutations are refused until the lake is reopened
    /// with `Catalog::recover`.
    Poisoned(String),
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// Anything else.
    Other(String),
}

impl fmt::Display for BauplanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use BauplanError::*;
        match self {
            ContractLocal(m) => write!(f, "contract error (local): {m}"),
            ContractPlan(m) => write!(f, "contract error (plan): {m}"),
            ContractRuntime(m) => write!(f, "contract error (runtime): {m}"),
            UnknownRef(r) => write!(f, "unknown ref: {r}"),
            RefExists(r) => write!(f, "ref already exists: {r}"),
            CasConflict { reference, expected, found } => write!(
                f,
                "concurrent update on ref {reference}: expected head {expected}, found {found}"
            ),
            MergeConflict(m) => write!(f, "merge conflict: {m}"),
            Visibility(m) => write!(f, "visibility: {m}"),
            RunFailed { run_id, node, cause } => {
                write!(f, "run {run_id} failed at node {node}: {cause}")
            }
            RunAborted(r) => write!(
                f,
                "run {r} was aborted; transactional branch retained for triage"
            ),
            ObjectNotFound(k) => write!(f, "object not found: {k}"),
            TableNotFound(t) => write!(f, "table not found: {t}"),
            Codec(m) => write!(f, "codec error: {m}"),
            Manifest(m) => write!(f, "manifest error: {m}"),
            Pjrt(m) => write!(f, "runtime (PJRT) error: {m}"),
            Dag(m) => write!(f, "dag error: {m}"),
            Parse(m) => write!(f, "parse error: {m}"),
            Poisoned(m) => write!(f, "catalog poisoned: {m}"),
            Io(e) => write!(f, "io error: {e}"),
            Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for BauplanError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BauplanError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for BauplanError {
    fn from(e: std::io::Error) -> Self {
        BauplanError::Io(e)
    }
}

impl From<crate::runtime::pjrt::Error> for BauplanError {
    fn from(e: crate::runtime::pjrt::Error) -> Self {
        BauplanError::Pjrt(e.to_string())
    }
}

impl BauplanError {
    /// The fail-fast *moment* at which this error surfaced, if it is a
    /// contract error: 1 = local, 2 = plan, 3 = runtime. Used by the E6
    /// experiment to report the detection-moment distribution.
    pub fn contract_moment(&self) -> Option<u8> {
        match self {
            BauplanError::ContractLocal(_) => Some(1),
            BauplanError::ContractPlan(_) => Some(2),
            BauplanError::ContractRuntime(_) => Some(3),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        assert_eq!(
            BauplanError::ContractRuntime("x".into()).to_string(),
            "contract error (runtime): x"
        );
        assert_eq!(
            BauplanError::CasConflict {
                reference: "main".into(),
                expected: "a".into(),
                found: "b".into()
            }
            .to_string(),
            "concurrent update on ref main: expected head a, found b"
        );
        assert_eq!(BauplanError::UnknownRef("dev".into()).to_string(), "unknown ref: dev");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: BauplanError = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
