//! Unified error type. Variants mirror the paper's failure taxonomy (§2):
//! schema failures, collaboration failures, correctness failures — plus the
//! infrastructure errors a real system needs.

use thiserror::Error;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, BauplanError>;

/// All the ways a lakehouse operation can fail.
///
/// The contract/plan/runtime split matters: the paper's fail-fast principle
/// says a failure must surface at the earliest *moment* able to detect it,
/// and tests assert on the variant to prove the moment.
#[derive(Debug, Error)]
pub enum BauplanError {
    // -- schema / contract failures (paper §2 failure mode 1) --------------
    /// A contract violation detected from declarations alone (moment M1).
    #[error("contract error (local): {0}")]
    ContractLocal(String),
    /// A contract violation detected by the control plane while composing
    /// the DAG, before any execution is scheduled (moment M2).
    #[error("contract error (plan): {0}")]
    ContractPlan(String),
    /// Physical data failed validation at the worker, before persisting
    /// anything (moment M3).
    #[error("contract error (runtime): {0}")]
    ContractRuntime(String),

    // -- collaboration failures (paper §2 failure mode 2) -------------------
    #[error("unknown ref: {0}")]
    UnknownRef(String),
    #[error("ref already exists: {0}")]
    RefExists(String),
    #[error("concurrent update on ref {reference}: expected head {expected}, found {found}")]
    CasConflict { reference: String, expected: String, found: String },
    #[error("merge conflict: {0}")]
    MergeConflict(String),
    /// The visibility guardrail from the Alloy counterexample (Fig. 4):
    /// aborted transactional branches cannot be forked or merged without an
    /// explicit capability.
    #[error("visibility: {0}")]
    Visibility(String),

    // -- correctness failures (paper §2 failure mode 3) ----------------------
    #[error("run {run_id} failed at node {node}: {cause}")]
    RunFailed { run_id: String, node: String, cause: String },
    #[error("run {0} was aborted; transactional branch retained for triage")]
    RunAborted(String),

    // -- infrastructure ------------------------------------------------------
    #[error("object not found: {0}")]
    ObjectNotFound(String),
    #[error("table not found: {0}")]
    TableNotFound(String),
    #[error("codec error: {0}")]
    Codec(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("runtime (PJRT) error: {0}")]
    Pjrt(String),
    #[error("dag error: {0}")]
    Dag(String),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("{0}")]
    Other(String),
}

impl BauplanError {
    /// The fail-fast *moment* at which this error surfaced, if it is a
    /// contract error: 1 = local, 2 = plan, 3 = runtime. Used by the E6
    /// experiment to report the detection-moment distribution.
    pub fn contract_moment(&self) -> Option<u8> {
        match self {
            BauplanError::ContractLocal(_) => Some(1),
            BauplanError::ContractPlan(_) => Some(2),
            BauplanError::ContractRuntime(_) => Some(3),
            _ => None,
        }
    }
}

impl From<xla::Error> for BauplanError {
    fn from(e: xla::Error) -> Self {
        BauplanError::Pjrt(e.to_string())
    }
}
