//! Typed table contracts (paper §3.1 + Appendix A).
//!
//! "Schema failures are interface bugs, so pipeline boundaries must be
//! explicit and checkable." Every DAG node declares the schema of each
//! input and its output; this module provides the type system, the schema
//! objects (with column-level lineage annotations), and the checker that
//! enforces contracts at the three fail-fast *moments*:
//!
//! - **M1 (local)** — declarations alone: schemas well-formed, inherited
//!   columns exist upstream, narrowings are marked with explicit casts.
//! - **M2 (plan)** — the control plane proves adjacent nodes compose
//!   before scheduling anything.
//! - **M3 (runtime)** — the worker validates physical data (via the AOT
//!   stats kernel) against the declared schema before anything persists.

pub mod types;
pub mod schema;
pub mod checker;
pub mod lineage;

pub use checker::{check_local, check_plan, check_runtime, ColumnStats};
pub use schema::{Field, Schema, SchemaRegistry};
pub use types::{FieldType, LogicalType};
