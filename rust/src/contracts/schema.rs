//! Schema objects: the `BauplanSchema` classes of the paper as data.
//!
//! A [`Schema`] is an ordered set of [`Field`]s. Each field optionally
//! carries a **lineage annotation** — `inherited_from: (schema, column)` —
//! mirroring Listing 10's `col2 = ChildSchema.col2`. The M1 local check
//! resolves these against a [`SchemaRegistry`] and verifies the inherited
//! type is compatible (identity, or a narrowing flagged `with_cast`, or a
//! nullability strip flagged `not_null`).

use std::collections::BTreeMap;

use crate::contracts::types::{FieldType, LogicalType};
use crate::error::{BauplanError, Result};

/// One column declaration in a contract.
#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    pub name: String,
    pub ty: FieldType,
    /// `Some((schema_name, column_name))` if declared as inherited.
    pub inherited_from: Option<(String, String)>,
    /// The declaration includes an explicit cast (legal narrowing).
    pub with_cast: bool,
    /// The declaration includes an explicit `[NotNull]` filter.
    pub not_null_filter: bool,
    /// Column values must be unique across valid, non-null rows
    /// (Appendix-A style column-level data-quality annotation).
    pub unique: bool,
}

impl Field {
    pub fn new(name: &str, ty: FieldType) -> Field {
        Field {
            name: name.into(),
            ty,
            inherited_from: None,
            with_cast: false,
            not_null_filter: false,
            unique: false,
        }
    }

    pub fn inherited(mut self, schema: &str, column: &str) -> Field {
        self.inherited_from = Some((schema.into(), column.into()));
        self
    }

    pub fn cast(mut self) -> Field {
        self.with_cast = true;
        self
    }

    pub fn not_null(mut self) -> Field {
        self.not_null_filter = true;
        self
    }

    pub fn unique(mut self) -> Field {
        self.unique = true;
        self
    }
}

/// A named, ordered table contract.
#[derive(Debug, Clone, PartialEq)]
pub struct Schema {
    pub name: String,
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(name: &str, fields: Vec<Field>) -> Schema {
        Schema { name: name.into(), fields }
    }

    pub fn field(&self, name: &str) -> Option<&Field> {
        self.fields.iter().find(|f| f.name == name)
    }

    pub fn field_names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }

    /// Structural fingerprint used by the catalog to detect schema drift
    /// between what a snapshot was written as and what a contract expects.
    pub fn fingerprint(&self) -> String {
        let desc: Vec<String> = self
            .fields
            .iter()
            .map(|f| format!("{}:{}:{}", f.name, f.ty.logical, f.ty.nullable))
            .collect();
        crate::util::id::content_hash(desc.join(",").as_bytes())
    }

    /// The paper's running-example schemas (Listing 3 + Appendix A),
    /// registered under their paper names. Used by examples and tests.
    pub fn paper_schemas() -> Vec<Schema> {
        use LogicalType::*;
        vec![
            Schema::new("RawSchema", vec![
                Field::new("col1", FieldType::new(Str)),
                Field::new("col2", FieldType::new(Timestamp)),
                Field::new("col3", FieldType::new(Float).bounded(0.0, 1e6)),
            ]),
            Schema::new("ParentSchema", vec![
                Field::new("col1", FieldType::new(Str)).inherited("RawSchema", "col1"),
                Field::new("col2", FieldType::new(Timestamp)).inherited("RawSchema", "col2"),
                Field::new("_S", FieldType::new(Float)),
            ]),
            Schema::new("ChildSchema", vec![
                Field::new("col2", FieldType::new(Timestamp)).inherited("ParentSchema", "col2"),
                Field::new("col4", FieldType::new(Float)),
                Field::new("col5", FieldType::new(Float).nullable()),
            ]),
            Schema::new("Grand", vec![
                Field::new("col2", FieldType::new(Timestamp)).inherited("ChildSchema", "col2"),
                Field::new("col4", FieldType::new(Int)).inherited("ChildSchema", "col4").cast(),
            ]),
            Schema::new("FriendSchema", vec![
                Field::new("col2", FieldType::new(Timestamp)).inherited("ChildSchema", "col2"),
                Field::new("col4", FieldType::new(Int)).inherited("Grand", "col4"),
                Field::new("col5", FieldType::new(Float))
                    .inherited("ChildSchema", "col5")
                    .not_null(),
            ]),
        ]
    }
}

/// All schemas known to a project — what the control plane consults.
#[derive(Debug, Default, Clone)]
pub struct SchemaRegistry {
    schemas: BTreeMap<String, Schema>,
}

impl SchemaRegistry {
    pub fn new() -> SchemaRegistry {
        SchemaRegistry::default()
    }

    pub fn with_paper_schemas() -> SchemaRegistry {
        let mut r = SchemaRegistry::new();
        for s in Schema::paper_schemas() {
            r.register(s).unwrap();
        }
        r
    }

    pub fn register(&mut self, schema: Schema) -> Result<()> {
        if self.schemas.contains_key(&schema.name) {
            return Err(BauplanError::ContractLocal(format!(
                "schema '{}' already registered",
                schema.name
            )));
        }
        self.schemas.insert(schema.name.clone(), schema);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Schema> {
        self.schemas.get(name).ok_or_else(|| {
            BauplanError::ContractLocal(format!("unknown schema '{name}'"))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.schemas.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.schemas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schemas.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schemas_register() {
        let r = SchemaRegistry::with_paper_schemas();
        assert_eq!(r.len(), 5);
        assert!(r.get("ChildSchema").is_ok());
        assert!(r.get("Nope").is_err());
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut r = SchemaRegistry::with_paper_schemas();
        let err = r.register(Schema::new("Grand", vec![]));
        assert!(matches!(err, Err(BauplanError::ContractLocal(_))));
    }

    #[test]
    fn fingerprint_detects_drift() {
        let a = Schema::new("S", vec![
            Field::new("x", FieldType::new(LogicalType::Int)),
        ]);
        let b = Schema::new("S", vec![
            Field::new("x", FieldType::new(LogicalType::Float)),
        ]);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), a.clone().fingerprint());
    }

    #[test]
    fn grand_narrows_col4_with_cast() {
        let r = SchemaRegistry::with_paper_schemas();
        let g = r.get("Grand").unwrap();
        let f = g.field("col4").unwrap();
        assert!(f.with_cast);
        assert_eq!(f.ty.logical, LogicalType::Int);
        assert!(f.inherited_from.is_some());
    }
}
