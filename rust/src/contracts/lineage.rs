//! Column lineage across a DAG (paper Appendix A).
//!
//! Two uses: (1) *insight* — where did this column come from, where is its
//! type changed; (2) *optimization* — the "Dafny-style" pre/post-condition
//! propagation: once a worker has validated that a column has no NULLs,
//! downstream nodes whose transformation provably preserves nullability
//! can skip re-validating it. [`LineageGraph::can_skip_validation`]
//! implements the sound (conservative) version of that rule.

use std::collections::BTreeMap;

use crate::contracts::schema::SchemaRegistry;
use crate::error::Result;

/// Full provenance of one column occurrence.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnOrigin {
    /// (schema, column) chain from the occurrence back to its root, e.g.
    /// `Grand.col2 -> ChildSchema.col2 -> ParentSchema.col2 -> RawSchema.col2`.
    pub chain: Vec<(String, String)>,
    /// Schemas along the chain where the logical type changed.
    pub type_changes: Vec<String>,
    /// Schemas along the chain where nullability changed.
    pub nullability_changes: Vec<String>,
}

/// Lineage derived from schema declarations alone.
#[derive(Debug, Default)]
pub struct LineageGraph {
    /// (schema, column) -> (parent schema, parent column)
    edges: BTreeMap<(String, String), (String, String)>,
    /// (schema, column) -> (logical type display, nullable)
    types: BTreeMap<(String, String), (String, bool)>,
}

impl LineageGraph {
    /// Build the lineage graph from every schema in the registry.
    pub fn from_registry(registry: &SchemaRegistry) -> Result<LineageGraph> {
        let mut g = LineageGraph::default();
        for name in registry.names() {
            let schema = registry.get(name)?;
            for f in &schema.fields {
                g.types.insert(
                    (schema.name.clone(), f.name.clone()),
                    (f.ty.logical.to_string(), f.ty.nullable),
                );
                if let Some((ps, pc)) = &f.inherited_from {
                    g.edges.insert(
                        (schema.name.clone(), f.name.clone()),
                        (ps.clone(), pc.clone()),
                    );
                }
            }
        }
        Ok(g)
    }

    /// Trace a column occurrence back to its root.
    pub fn origin(&self, schema: &str, column: &str) -> ColumnOrigin {
        let mut chain = vec![(schema.to_string(), column.to_string())];
        let mut type_changes = Vec::new();
        let mut nullability_changes = Vec::new();
        let mut cur = (schema.to_string(), column.to_string());
        // Schemas cannot be mutually recursive (registration is acyclic in
        // practice), but guard against malformed input with a depth cap.
        for _ in 0..64 {
            let Some(parent) = self.edges.get(&cur) else { break };
            if let (Some(ct), Some(pt)) = (self.types.get(&cur), self.types.get(parent)) {
                if ct.0 != pt.0 {
                    type_changes.push(cur.0.clone());
                }
                if ct.1 != pt.1 {
                    nullability_changes.push(cur.0.clone());
                }
            }
            chain.push(parent.clone());
            cur = parent.clone();
        }
        ColumnOrigin { chain, type_changes, nullability_changes }
    }

    /// Appendix-A optimization: may the worker skip re-validating
    /// `schema.column` given its parent was already validated?
    ///
    /// Sound rule: skip only if the column is inherited AND neither its
    /// type nor its nullability changed at this hop (a pure propagation —
    /// the transformation can only filter rows, which preserves both
    /// "no NULLs" and bounds).
    pub fn can_skip_validation(&self, schema: &str, column: &str) -> bool {
        let key = (schema.to_string(), column.to_string());
        let Some(parent) = self.edges.get(&key) else { return false };
        match (self.types.get(&key), self.types.get(parent)) {
            (Some(ct), Some(pt)) => ct == pt,
            _ => false,
        }
    }

    /// All columns of `schema` that reach back to `root_schema` — "how is
    /// this raw table used downstream".
    pub fn columns_reaching(&self, schema: &str, root_schema: &str) -> Vec<String> {
        self.types
            .keys()
            .filter(|(s, _)| s == schema)
            .filter(|(s, c)| {
                self.origin(s, c).chain.iter().any(|(cs, _)| cs == root_schema)
            })
            .map(|(_, c)| c.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph() -> LineageGraph {
        LineageGraph::from_registry(&SchemaRegistry::with_paper_schemas()).unwrap()
    }

    #[test]
    fn col2_traces_to_raw() {
        let g = graph();
        let o = g.origin("Grand", "col2");
        assert_eq!(o.chain.len(), 4); // Grand -> Child -> Parent -> Raw
        assert_eq!(o.chain.last().unwrap().0, "RawSchema");
        assert!(o.type_changes.is_empty());
    }

    #[test]
    fn col4_type_change_is_recorded() {
        let g = graph();
        let o = g.origin("Grand", "col4");
        assert_eq!(o.type_changes, vec!["Grand".to_string()]); // float -> int here
    }

    #[test]
    fn col5_notnull_is_a_nullability_change() {
        let g = graph();
        let o = g.origin("FriendSchema", "col5");
        assert_eq!(o.nullability_changes, vec!["FriendSchema".to_string()]);
    }

    #[test]
    fn skip_validation_only_for_pure_propagation() {
        let g = graph();
        // col2 Grand <- Child: same type, same nullability => skippable
        assert!(g.can_skip_validation("Grand", "col2"));
        // col4 Grand <- Child: type narrowed => must revalidate
        assert!(!g.can_skip_validation("Grand", "col4"));
        // col5 Friend <- Child: nullability stripped => must revalidate
        assert!(!g.can_skip_validation("FriendSchema", "col5"));
        // fresh column: no parent => must validate
        assert!(!g.can_skip_validation("ChildSchema", "col4"));
    }

    #[test]
    fn reachability_query() {
        let g = graph();
        let cols = g.columns_reaching("FriendSchema", "RawSchema");
        // col2 reaches Raw via Child->Parent->Raw; col4 via Grand->Child (fresh there)
        assert!(cols.contains(&"col2".to_string()));
        assert!(!cols.contains(&"col4".to_string()) || cols.contains(&"col4".to_string()));
        // col5 is fresh at ChildSchema, so it must NOT reach RawSchema
        assert!(!g
            .columns_reaching("FriendSchema", "RawSchema")
            .contains(&"col5".to_string()));
    }
}
