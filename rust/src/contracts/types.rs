//! The contract type lattice.
//!
//! Logical types are deliberately small (the paper's snippets use str,
//! datetime, int, float, and a nullable union); what matters is the
//! *compatibility relation*: which flows are implicit, which require an
//! explicit cast (narrowing), and which are errors. Physical layout is a
//! separate concern — strings are dictionary-encoded to i32 and
//! timestamps are epoch-second f32 on the compute path.

use std::fmt;

/// Logical column types visible in contracts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LogicalType {
    Int,
    Float,
    Timestamp,
    Str,
    Bool,
}

impl LogicalType {
    pub fn parse(s: &str) -> Option<LogicalType> {
        match s {
            "int" => Some(LogicalType::Int),
            "float" => Some(LogicalType::Float),
            "timestamp" | "datetime" => Some(LogicalType::Timestamp),
            "str" | "string" => Some(LogicalType::Str),
            "bool" => Some(LogicalType::Bool),
            _ => None,
        }
    }

    /// Is a value of `self` acceptable where `target` is expected without
    /// any cast? (identity, or lossless widening int -> float)
    pub fn flows_implicitly_to(self, target: LogicalType) -> bool {
        self == target
            || matches!((self, target), (LogicalType::Int, LogicalType::Float))
    }

    /// Is `self -> target` a *narrowing* that is legal only with an
    /// explicit cast (paper: "Node 3 can legally narrow a type when the
    /// transformation includes an explicit cast")?
    pub fn narrows_to_with_cast(self, target: LogicalType) -> bool {
        matches!(
            (self, target),
            (LogicalType::Float, LogicalType::Int)
                | (LogicalType::Timestamp, LogicalType::Int)
                | (LogicalType::Timestamp, LogicalType::Float)
        )
    }
}

impl fmt::Display for LogicalType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            LogicalType::Int => "int",
            LogicalType::Float => "float",
            LogicalType::Timestamp => "timestamp",
            LogicalType::Str => "str",
            LogicalType::Bool => "bool",
        };
        write!(f, "{s}")
    }
}

/// Full field type: logical type + nullability + optional value bounds
/// (the column-level data-quality annotations of Appendix A).
#[derive(Debug, Clone, PartialEq)]
pub struct FieldType {
    pub logical: LogicalType,
    pub nullable: bool,
    /// Inclusive (lo, hi) bounds enforced by the M3 runtime check.
    pub bounds: Option<(f64, f64)>,
}

impl FieldType {
    pub fn new(logical: LogicalType) -> FieldType {
        FieldType { logical, nullable: false, bounds: None }
    }

    pub fn nullable(mut self) -> FieldType {
        self.nullable = true;
        self
    }

    pub fn bounded(mut self, lo: f64, hi: f64) -> FieldType {
        self.bounds = Some((lo, hi));
        self
    }

    /// Compatibility verdict for a value of `self` flowing into a slot
    /// declared as `target`.
    pub fn flow_into(&self, target: &FieldType, has_cast: bool) -> FlowVerdict {
        // nullability: nullable -> non-null needs an explicit NotNull
        // filter, which parses as a cast-like annotation.
        if self.nullable && !target.nullable && !has_cast {
            return FlowVerdict::NeedsNotNull;
        }
        if self.logical.flows_implicitly_to(target.logical) {
            FlowVerdict::Ok
        } else if self.logical.narrows_to_with_cast(target.logical) {
            if has_cast {
                FlowVerdict::Ok
            } else {
                FlowVerdict::NeedsCast
            }
        } else {
            FlowVerdict::Incompatible
        }
    }
}

impl fmt::Display for FieldType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.nullable {
            write!(f, "UNION({}, None)", self.logical)?;
        } else {
            write!(f, "{}", self.logical)?;
        }
        if let Some((lo, hi)) = self.bounds {
            write!(f, " in [{lo}, {hi}]")?;
        }
        Ok(())
    }
}

/// Result of a type-flow check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowVerdict {
    Ok,
    /// Narrowing requires an explicit cast annotation.
    NeedsCast,
    /// Nullable -> non-null requires an explicit NotNull filter.
    NeedsNotNull,
    Incompatible,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_widens_to_float_implicitly() {
        assert!(LogicalType::Int.flows_implicitly_to(LogicalType::Float));
        assert!(!LogicalType::Float.flows_implicitly_to(LogicalType::Int));
    }

    #[test]
    fn float_to_int_needs_cast() {
        let f = FieldType::new(LogicalType::Float);
        let i = FieldType::new(LogicalType::Int);
        assert_eq!(f.flow_into(&i, false), FlowVerdict::NeedsCast);
        assert_eq!(f.flow_into(&i, true), FlowVerdict::Ok);
    }

    #[test]
    fn str_to_int_is_incompatible_even_with_cast() {
        let s = FieldType::new(LogicalType::Str);
        let i = FieldType::new(LogicalType::Int);
        assert_eq!(s.flow_into(&i, true), FlowVerdict::Incompatible);
    }

    #[test]
    fn nullable_to_non_null_needs_filter() {
        let n = FieldType::new(LogicalType::Float).nullable();
        let nn = FieldType::new(LogicalType::Float);
        assert_eq!(n.flow_into(&nn, false), FlowVerdict::NeedsNotNull);
        assert_eq!(n.flow_into(&nn, true), FlowVerdict::Ok);
        // widening nullability is always fine
        assert_eq!(nn.flow_into(&n, false), FlowVerdict::Ok);
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for t in ["int", "float", "timestamp", "str", "bool"] {
            let lt = LogicalType::parse(t).unwrap();
            assert_eq!(LogicalType::parse(&lt.to_string()), Some(lt));
        }
        assert_eq!(LogicalType::parse("datetime"), Some(LogicalType::Timestamp));
        assert!(LogicalType::parse("decimal").is_none());
    }
}
