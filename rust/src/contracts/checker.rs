//! The contract checker: one function per fail-fast moment.
//!
//! The division of labor follows the paper exactly (§3.1):
//! - [`check_local`] needs only declarations (+ the registry) — it is what
//!   an IDE/type-checker can run while the human or agent is authoring.
//! - [`check_plan`] needs the DAG wiring — the control plane runs it on
//!   DAG metadata before scheduling any distributed execution.
//! - [`check_runtime`] needs physical data — the worker runs it on the
//!   stats the AOT validation kernel computed, *before persisting*.

use crate::contracts::schema::{Schema, SchemaRegistry};
use crate::contracts::types::FlowVerdict;
use crate::error::{BauplanError, Result};

/// M1 — validate a schema's declarations against the registry.
///
/// Checks: no duplicate columns; inherited columns exist upstream; the
/// inherited type flows (identity / widening / cast-flagged narrowing /
/// NotNull-flagged nullability strip).
pub fn check_local(schema: &Schema, registry: &SchemaRegistry) -> Result<()> {
    let mut seen = std::collections::BTreeSet::new();
    for f in &schema.fields {
        if !seen.insert(&f.name) {
            return Err(BauplanError::ContractLocal(format!(
                "schema '{}': duplicate column '{}'",
                schema.name, f.name
            )));
        }
        if let Some((src_schema, src_col)) = &f.inherited_from {
            let src = registry.get(src_schema).map_err(|_| {
                BauplanError::ContractLocal(format!(
                    "schema '{}': column '{}' inherits from unknown schema '{}'",
                    schema.name,
                    f.name,
                    src_schema
                ))
            })?;
            let src_field = src.field(src_col).ok_or_else(|| {
                BauplanError::ContractLocal(format!(
                    "schema '{}': column '{}' inherits missing column '{}.{}'",
                    schema.name,
                    f.name,
                    src_schema,
                    src_col
                ))
            })?;
            let has_annotation = f.with_cast || f.not_null_filter;
            match src_field.ty.flow_into(&f.ty, has_annotation) {
                FlowVerdict::Ok => {}
                FlowVerdict::NeedsCast => {
                    return Err(BauplanError::ContractLocal(format!(
                        "schema '{}': '{}' narrows {} -> {} without an explicit cast",
                        schema.name,
                        f.name,
                        src_field.ty.logical,
                        f.ty.logical
                    )));
                }
                FlowVerdict::NeedsNotNull => {
                    return Err(BauplanError::ContractLocal(format!(
                        "schema '{}': '{}' drops nullability of '{}.{}' without [NotNull]",
                        schema.name,
                        f.name,
                        src_schema,
                        src_col
                    )));
                }
                FlowVerdict::Incompatible => {
                    return Err(BauplanError::ContractLocal(format!(
                        "schema '{}': '{}' declares {} but inherits {} from '{}.{}'",
                        schema.name, f.name, f.ty.logical, src_field.ty.logical,
                        src_schema, src_col)));
                }
            }
        }
    }
    Ok(())
}

/// M2 — validate that an upstream node's output composes with a
/// downstream node's declared input: every column the input schema
/// mentions must exist upstream with a compatible type.
pub fn check_plan(upstream_out: &Schema, downstream_in: &Schema) -> Result<()> {
    for f in &downstream_in.fields {
        // Fresh (non-inherited) columns are produced by the downstream
        // node itself; only inherited/propagated columns constrain the
        // upstream boundary.
        let wants_upstream = f
            .inherited_from
            .as_ref()
            .map(|(s, _)| s == &upstream_out.name)
            .unwrap_or(false);
        if !wants_upstream {
            continue;
        }
        let (_, src_col) = f.inherited_from.as_ref().unwrap();
        let src_field = upstream_out.field(src_col).ok_or_else(|| {
            BauplanError::ContractPlan(format!(
                "node boundary {} -> {}: column '{}' not produced upstream",
                upstream_out.name,
                downstream_in.name,
                src_col
            ))
        })?;
        let has_annotation = f.with_cast || f.not_null_filter;
        match src_field.ty.flow_into(&f.ty, has_annotation) {
            FlowVerdict::Ok => {}
            v => {
                return Err(BauplanError::ContractPlan(format!(
                    "node boundary {} -> {}: column '{}' flow {:?} ({} -> {})",
                    upstream_out.name, downstream_in.name, src_col, v,
                    src_field.ty, f.ty)));
            }
        }
    }
    Ok(())
}

/// Physical statistics for one column, as produced by the AOT `validate`
/// kernel (stats.py layout: count/excluded/min/max/nan/sum).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColumnStats {
    pub included: f64,
    pub excluded: f64,
    pub min: f64,
    pub max: f64,
    pub nan_count: f64,
    pub sum: f64,
    /// Nulls observed among valid rows (computed against the null mask).
    pub null_count: f64,
}

impl ColumnStats {
    /// Decode the kernel's f32[8] output; `null_count` is supplied by the
    /// caller (a second kernel invocation over the null mask).
    pub fn from_kernel(out: &[f32], null_count: f64) -> Result<ColumnStats> {
        if out.len() < 6 {
            return Err(BauplanError::ContractRuntime(format!(
                "stats vector too short: {}",
                out.len()
            )));
        }
        Ok(ColumnStats {
            included: out[0] as f64,
            excluded: out[1] as f64,
            min: out[2] as f64,
            max: out[3] as f64,
            nan_count: out[4] as f64,
            sum: out[5] as f64,
            null_count,
        })
    }
}

/// M3 — validate physical column statistics against a field declaration.
///
/// Enforces: non-nullable columns have zero nulls; NaNs are contract
/// violations for every float column; declared bounds hold for the
/// observed min/max. Returns `ContractRuntime` — the *last* acceptable
/// moment; anything later would leak inconsistent state into storage.
pub fn check_runtime(
    schema_name: &str,
    field_name: &str,
    declared: &crate::contracts::types::FieldType,
    stats: &ColumnStats,
) -> Result<()> {
    if !declared.nullable && stats.null_count > 0.0 {
        return Err(BauplanError::ContractRuntime(format!(
            "{schema_name}.{field_name}: {} NULLs in non-nullable column",
            stats.null_count
        )));
    }
    if stats.nan_count > 0.0 {
        return Err(BauplanError::ContractRuntime(format!(
            "{schema_name}.{field_name}: {} NaNs observed",
            stats.nan_count
        )));
    }
    if let Some((lo, hi)) = declared.bounds {
        // Empty columns (min=+inf/max=-inf) are vacuously in bounds.
        if stats.included > 0.0 && (stats.min < lo || stats.max > hi) {
            return Err(BauplanError::ContractRuntime(format!(
                "{schema_name}.{field_name}: observed [{}, {}] outside declared [{lo}, {hi}]",
                stats.min,
                stats.max
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::contracts::schema::Field;
    use crate::contracts::types::{FieldType, LogicalType};

    fn registry() -> SchemaRegistry {
        SchemaRegistry::with_paper_schemas()
    }

    #[test]
    fn paper_schemas_pass_local_check() {
        let r = registry();
        for name in ["ParentSchema", "ChildSchema", "Grand", "FriendSchema"] {
            check_local(r.get(name).unwrap(), &r).unwrap();
        }
    }

    #[test]
    fn local_rejects_unmarked_narrowing() {
        let r = registry();
        // Grand without the cast flag: float col4 -> int col4
        let bad = Schema::new("BadGrand", vec![
            Field::new("col4", FieldType::new(LogicalType::Int))
                .inherited("ChildSchema", "col4"),
        ]);
        let err = check_local(&bad, &r).unwrap_err();
        assert_eq!(err.contract_moment(), Some(1));
        assert!(err.to_string().contains("without an explicit cast"));
    }

    #[test]
    fn local_rejects_missing_upstream_column() {
        let r = registry();
        let bad = Schema::new("Bad", vec![
            Field::new("ghost", FieldType::new(LogicalType::Int))
                .inherited("ParentSchema", "ghost"),
        ]);
        assert!(check_local(&bad, &r).is_err());
    }

    #[test]
    fn local_rejects_dropped_nullability() {
        let r = registry();
        let bad = Schema::new("Bad", vec![
            Field::new("col5", FieldType::new(LogicalType::Float))
                .inherited("ChildSchema", "col5"), // nullable upstream, no [NotNull]
        ]);
        let err = check_local(&bad, &r).unwrap_err();
        assert!(err.to_string().contains("[NotNull]"));
    }

    #[test]
    fn local_rejects_duplicate_columns() {
        let r = registry();
        let bad = Schema::new("Dup", vec![
            Field::new("x", FieldType::new(LogicalType::Int)),
            Field::new("x", FieldType::new(LogicalType::Int)),
        ]);
        assert!(check_local(&bad, &r).is_err());
    }

    #[test]
    fn plan_check_accepts_paper_boundaries() {
        let r = registry();
        check_plan(r.get("ParentSchema").unwrap(), r.get("ChildSchema").unwrap()).unwrap();
        check_plan(r.get("ChildSchema").unwrap(), r.get("Grand").unwrap()).unwrap();
    }

    #[test]
    fn plan_check_catches_type_shift() {
        // the paper's §2 example: col3 becomes a float upstream while the
        // child still assumes int — but at the parent/child boundary this
        // surfaces as col2's type changing.
        let changed_parent = Schema::new("ParentSchema", vec![
            Field::new("col1", FieldType::new(LogicalType::Str)),
            Field::new("col2", FieldType::new(LogicalType::Str)), // was timestamp!
            Field::new("_S", FieldType::new(LogicalType::Float)),
        ]);
        let r = registry();
        let err = check_plan(&changed_parent, r.get("ChildSchema").unwrap()).unwrap_err();
        assert_eq!(err.contract_moment(), Some(2));
    }

    #[test]
    fn plan_check_catches_dropped_column() {
        let r = registry();
        let dropped = Schema::new("ParentSchema", vec![
            Field::new("col1", FieldType::new(LogicalType::Str)),
            Field::new("_S", FieldType::new(LogicalType::Float)),
        ]);
        let err = check_plan(&dropped, r.get("ChildSchema").unwrap()).unwrap_err();
        assert!(err.to_string().contains("not produced upstream"));
    }

    #[test]
    fn runtime_rejects_nulls_in_non_nullable() {
        let stats = ColumnStats {
            included: 10.0, excluded: 0.0, min: 0.0, max: 1.0,
            nan_count: 0.0, sum: 5.0, null_count: 2.0,
        };
        let ty = FieldType::new(LogicalType::Float);
        let err = check_runtime("S", "c", &ty, &stats).unwrap_err();
        assert_eq!(err.contract_moment(), Some(3));
    }

    #[test]
    fn runtime_allows_nulls_in_nullable() {
        let stats = ColumnStats {
            included: 10.0, excluded: 0.0, min: 0.0, max: 1.0,
            nan_count: 0.0, sum: 5.0, null_count: 2.0,
        };
        let ty = FieldType::new(LogicalType::Float).nullable();
        check_runtime("S", "c", &ty, &stats).unwrap();
    }

    #[test]
    fn runtime_rejects_nan_and_bounds() {
        let ty = FieldType::new(LogicalType::Float).bounded(0.0, 100.0);
        let nan = ColumnStats {
            included: 5.0, excluded: 0.0, min: 0.0, max: 1.0,
            nan_count: 1.0, sum: 0.0, null_count: 0.0,
        };
        assert!(check_runtime("S", "c", &ty, &nan).is_err());
        let oob = ColumnStats {
            included: 5.0, excluded: 0.0, min: -1.0, max: 1.0,
            nan_count: 0.0, sum: 0.0, null_count: 0.0,
        };
        assert!(check_runtime("S", "c", &ty, &oob).is_err());
        // empty column is vacuously in bounds
        let empty = ColumnStats {
            included: 0.0, excluded: 5.0, min: f64::INFINITY,
            max: f64::NEG_INFINITY, nan_count: 0.0, sum: 0.0, null_count: 0.0,
        };
        check_runtime("S", "c", &ty, &empty).unwrap();
    }
}
