//! The control plane (paper Fig. 1, step 2): turns DAG code into a
//! validated physical plan *before* any distributed execution.
//!
//! The fail-fast pipeline:
//! 1. parse the project (moment M0, syntax);
//! 2. M1 + M2 via [`PipelineSpec::plan`] (schemas compose);
//! 3. physical validation against the loaded runtime: every node's `op`
//!    must be a compiled artifact with the right arity — the compute
//!    analogue of "inconsistent plans should not be run".

use std::sync::Arc;

use crate::dag::{parser::parse_pipeline, Plan, PipelineSpec};
use crate::error::{BauplanError, Result};
use crate::runtime::ExecHandle;

/// Arity (input tensors) each op contributes per input table; used to
/// sanity-check specs against compiled artifacts.
fn known_op(op: &str) -> bool {
    matches!(
        op,
        "parent" | "child" | "grand_child" | "family_friend"
            | "transform_n" | "transform_g" | "join_n"
    )
}

/// The control plane: validation + planning service.
#[derive(Clone)]
pub struct ControlPlane {
    runtime: Arc<ExecHandle>,
}

impl ControlPlane {
    pub fn new(runtime: Arc<ExecHandle>) -> ControlPlane {
        ControlPlane { runtime }
    }

    /// Full validation path from project text to executable plan.
    pub fn plan_from_text(&self, text: &str) -> Result<Plan> {
        let spec = parse_pipeline(text)?;
        self.plan_from_spec(&spec)
    }

    /// M1/M2 + physical checks for an in-memory spec.
    pub fn plan_from_spec(&self, spec: &PipelineSpec) -> Result<Plan> {
        let plan = spec.plan()?; // M1 + M2
        // Physical moment: ops must exist as compiled artifacts.
        for node in &plan.nodes {
            if !known_op(&node.op) {
                return Err(BauplanError::ContractPlan(format!(
                    "node '{}': unknown op '{}'",
                    node.output,
                    node.op
                )));
            }
            self.runtime.manifest().artifact(&node.op).map_err(|_| {
                BauplanError::ContractPlan(format!(
                    "node '{}': op '{}' has no compiled artifact \
                     (run `make artifacts`)",
                    node.output,
                    node.op
                ))
            })?;
            // binary nodes need exactly 2 inputs, unary exactly 1
            let expected_inputs = if node.op == "family_friend" || node.op == "join_n" {
                2
            } else {
                1
            };
            if node.inputs.len() != expected_inputs {
                return Err(BauplanError::ContractPlan(format!(
                    "node '{}': op '{}' takes {} input table(s), got {}",
                    node.output,
                    node.op,
                    expected_inputs,
                    node.inputs.len()
                )));
            }
        }
        Ok(plan)
    }
}
