//! Synthetic data + workload generators.
//!
//! The paper evaluates on production traffic we do not have ("millions of
//! branches" of real usage); per the substitution rule we generate
//! NYC-taxi-flavoured event tables and configurable concurrent-run
//! workloads that exercise the same code paths at laptop scale.

use crate::storage::columnar::{Batch, Column};
use crate::testing::Rng;

/// Shape constants mirroring the compiled artifacts (kernels/__init__.py).
pub const N: usize = 2048;
pub const G: usize = 64;

/// Generate one raw-table batch (RawSchema: col1 str-code, col2 timestamp,
/// col3 measure), `rows <= N` valid rows padded to `N`.
pub fn raw_batch(rng: &mut Rng, rows: usize) -> Batch {
    assert!(rows <= N);
    let mut col1 = Vec::with_capacity(N);
    let mut col2 = Vec::with_capacity(N);
    let mut col3 = Vec::with_capacity(N);
    let mut valid = Vec::with_capacity(N);
    // zipf-ish skew over group keys: a few hot vendors, long tail —
    // data skew is the paper's §2 example of dev/prod divergence.
    for i in 0..N {
        if i < rows {
            let hot = rng.bool(0.6);
            let key = if hot { rng.below(4) } else { rng.below(G) };
            col1.push(key as i32);
            col2.push(1.7e9_f32 + rng.f32() * 8.64e4);
            col3.push(rng.f32() * 100.0);
            valid.push(1.0);
        } else {
            col1.push(0);
            col2.push(0.0);
            col3.push(0.0);
            valid.push(0.0);
        }
    }
    Batch::new(
        vec![
            Column::i32("col1", col1),
            Column::f32("col2", col2),
            Column::f32("col3", col3),
        ],
        valid,
    )
    .unwrap()
}

/// A raw table of `batches` batches, each `rows_per_batch` valid rows.
pub fn raw_table(seed: u64, batches: usize, rows_per_batch: usize) -> Vec<Batch> {
    let mut rng = Rng::new(seed);
    (0..batches).map(|_| raw_batch(&mut rng, rows_per_batch)).collect()
}

/// A raw batch with contract-violating rows injected: NaNs in col3 and/or
/// out-of-bounds values — used to prove the M3 runtime check fires.
pub fn poisoned_batch(rng: &mut Rng, rows: usize, nan_rows: usize, oob_rows: usize) -> Batch {
    let mut b = raw_batch(rng, rows);
    let col3 = match &mut b.columns[2].data {
        crate::storage::columnar::ColumnData::F32(v) => v,
        _ => unreachable!(),
    };
    for i in 0..nan_rows.min(rows) {
        col3[i] = f32::NAN;
    }
    for i in 0..oob_rows.min(rows) {
        col3[rows - 1 - i] = 9e8; // outside RawSchema's [0, 1e6]
    }
    b
}

/// Workload descriptor for the consistency experiment (E3/E4): a stream
/// of runs with an injected failure probability, plus concurrent readers.
#[derive(Debug, Clone)]
pub struct Workload {
    pub runs: usize,
    pub failure_probability: f64,
    pub readers: usize,
    pub reads_per_reader: usize,
    pub seed: u64,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            runs: 20,
            failure_probability: 0.3,
            readers: 4,
            reads_per_reader: 200,
            seed: 7,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_batch_is_padded_and_in_domain() {
        let mut rng = Rng::new(1);
        let b = raw_batch(&mut rng, 100);
        assert_eq!(b.width(), N);
        assert_eq!(b.row_count(), 100);
        for (i, &k) in b.column("col1").unwrap().data.as_i32().unwrap().iter().enumerate() {
            assert!((k as usize) < G, "row {i} key {k}");
        }
        for &x in b.column("col3").unwrap().data.as_f32().unwrap() {
            assert!((0.0..=1e6).contains(&x));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        assert_eq!(raw_table(5, 2, 64), raw_table(5, 2, 64));
    }

    #[test]
    fn poisoned_batch_has_nans_and_oob() {
        let mut rng = Rng::new(2);
        let b = poisoned_batch(&mut rng, 50, 3, 2);
        let col3 = b.column("col3").unwrap().data.as_f32().unwrap();
        assert_eq!(col3.iter().filter(|x| x.is_nan()).count(), 3);
        assert_eq!(col3.iter().filter(|&&x| x > 1e6).count(), 2);
    }
}
