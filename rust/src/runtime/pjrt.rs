//! PJRT compatibility shim.
//!
//! The executor was written against the external `xla` crate (PJRT C-API
//! bindings). That crate is not in the offline build set, so this module
//! reproduces the exact slice of its API the executor compiles against.
//! Every entry point that would touch a real PJRT client returns
//! [`Error`] instead — [`PjRtClient::cpu`] fails first, so the stub
//! bodies further down the call chain are never reached at runtime.
//!
//! To link the real runtime: add the `xla` crate to `Cargo.toml` and
//! replace the `use crate::runtime::pjrt as xla;` alias in
//! `runtime/executor.rs` with `use xla;`. Nothing else changes — the
//! executor, worker, and every test compiled against this shim use the
//! same call signatures.

use std::fmt;

/// Error from the (stubbed) PJRT layer. Converts into
/// [`BauplanError::Pjrt`](crate::error::BauplanError::Pjrt) via `?`.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT unavailable: built without the external `xla` crate \
             (see runtime::pjrt module docs)"
                .into(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub of `xla::Literal` — a host tensor handed to/from an executable.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Stub of `xla::PjRtBuffer` — a device buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer to host memory as a [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (the AOT artifact interchange format).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module as a compilable computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with `args`; shaped like the real crate's
    /// per-device-per-output nesting.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Stub of `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client. Always fails in the stub — this is the
    /// first PJRT call on every load path, so nothing downstream runs.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_open_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn shim_errors_convert_to_bauplan_pjrt() {
        let e: crate::error::BauplanError = Error::unavailable().into();
        assert!(matches!(e, crate::error::BauplanError::Pjrt(_)));
    }
}
