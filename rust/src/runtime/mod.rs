//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The compile path (`make artifacts`) runs python/jax once; from then on
//! the rust binary is self-contained: [`Runtime::load`] parses each
//! `*.hlo.txt` with the XLA text parser (which reassigns instruction ids —
//! the reason text, not serialized protos, is the interchange format),
//! compiles on the PJRT CPU client, and caches one executable per
//! artifact. Call sites are validated against `manifest.json` at load
//! time — a mis-shaped call is a bug caught before any request runs.

pub mod manifest;
pub mod pjrt;
pub mod executor;
pub mod sim;

pub use executor::{ExecCompletion, ExecHandle, Runtime, TensorArg, TensorOut};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
