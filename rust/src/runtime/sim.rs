//! Simulated runtime backend: pure-rust reference implementations of the
//! AOT artifacts, with the same call signatures the PJRT path serves.
//!
//! The offline build cannot start a PJRT client (`runtime::pjrt` is a
//! stub), which used to mean nothing above the catalog could run end to
//! end without `make artifacts` + the external `xla` crate. This module
//! closes that gap: [`ExecHandle::sim`](crate::runtime::ExecHandle::sim)
//! serves every kernel from the reference semantics documented in
//! `python/compile/model.py` / `kernels/ref.py` — the same oracles pytest
//! holds the Pallas kernels to — so runs, verifiers, and the run cache
//! are exercised bit-deterministically on any machine.
//!
//! The sim is *not* a performance model (no MXU, no tiling); it exists so
//! correctness machinery (transactional protocol, M3 validation, cache
//! hit/miss behaviour) has a real compute path everywhere. Benches that
//! measure kernel latency still require the PJRT artifacts.

use crate::error::{BauplanError, Result};
use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
use crate::runtime::{TensorArg, TensorOut};

/// Batch width the sim artifacts are "compiled" for (mirrors
/// `python/compile/kernels/__init__.py`).
pub const SIM_N: usize = 2048;
/// Group domain of the grouped aggregation.
pub const SIM_G: usize = 64;

fn spec(shape: usize, dtype: &str) -> TensorSpec {
    TensorSpec { shape: vec![shape], dtype: dtype.into() }
}

fn artifact(name: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>) -> ArtifactSpec {
    ArtifactSpec {
        name: name.into(),
        file: format!("sim://{name}"),
        inputs,
        outputs,
        sha256_16: Some(format!("sim_{name}")),
    }
}

/// The manifest the sim backend serves: same artifact inventory and
/// tensor boundaries as `aot.py` writes for the compiled HLO modules.
pub fn sim_manifest() -> Manifest {
    let n = SIM_N;
    let g = SIM_G;
    let f = "float32";
    let i = "int32";
    let arts = vec![
        artifact(
            "parent",
            vec![spec(n, i), spec(n, f), spec(n, f), spec(n, f)],
            vec![spec(g, i), spec(g, f), spec(g, f), spec(g, f)],
        ),
        artifact(
            "child",
            vec![spec(g, f), spec(g, f), spec(g, f), spec(4, f)],
            vec![spec(g, f), spec(g, f), spec(g, f), spec(g, f), spec(g, f)],
        ),
        artifact(
            "grand_child",
            vec![spec(g, f), spec(g, f), spec(g, f), spec(4, f)],
            vec![spec(g, f), spec(g, i), spec(g, f)],
        ),
        artifact(
            "family_friend",
            vec![
                spec(n, i), spec(n, f), spec(n, f), spec(n, f), spec(n, f),
                spec(n, f), spec(g, i), spec(g, i), spec(g, f), spec(4, f),
            ],
            vec![spec(n, f), spec(n, f), spec(n, f), spec(n, f)],
        ),
        artifact(
            "validate_n",
            vec![spec(n, f), spec(n, f)],
            vec![spec(8, f)],
        ),
        artifact(
            "validate_g",
            vec![spec(g, f), spec(g, f)],
            vec![spec(8, f)],
        ),
        artifact(
            "transform_n",
            vec![spec(n, f), spec(n, f), spec(4, f)],
            vec![spec(n, f), spec(n, i), spec(n, f)],
        ),
        artifact(
            "transform_g",
            vec![spec(g, f), spec(g, f), spec(4, f)],
            vec![spec(g, f), spec(g, i), spec(g, f)],
        ),
    ];
    Manifest {
        n,
        g,
        artifacts: arts.into_iter().map(|a| (a.name.clone(), a)).collect(),
    }
}

fn f32_arg(args: &[TensorArg], idx: usize, name: &str) -> Result<&[f32]> {
    match args.get(idx) {
        Some(TensorArg::F32(v)) => Ok(v),
        _ => Err(BauplanError::Pjrt(format!("{name}: arg {idx} must be f32"))),
    }
}

fn i32_arg(args: &[TensorArg], idx: usize, name: &str) -> Result<&[i32]> {
    match args.get(idx) {
        Some(TensorArg::I32(v)) => Ok(v),
        _ => Err(BauplanError::Pjrt(format!("{name}: arg {idx} must be i32"))),
    }
}

/// Validate `args` against the manifest spec (same checks the PJRT
/// executor performs at the call site).
fn check_args(spec: &ArtifactSpec, args: &[TensorArg]) -> Result<()> {
    if args.len() != spec.inputs.len() {
        return Err(BauplanError::Pjrt(format!(
            "{}: expected {} args, got {}",
            spec.name,
            spec.inputs.len(),
            args.len()
        )));
    }
    for (i, (a, s)) in args.iter().zip(&spec.inputs).enumerate() {
        if a.len() != s.element_count() {
            return Err(BauplanError::Pjrt(format!(
                "{}: arg {i} has {} elements, expected {}",
                spec.name,
                a.len(),
                s.element_count()
            )));
        }
        let dtype = match a {
            TensorArg::F32(_) => "float32",
            TensorArg::I32(_) => "int32",
        };
        if dtype != s.dtype {
            return Err(BauplanError::Pjrt(format!(
                "{}: arg {i} is {dtype}, expected {}",
                spec.name, s.dtype
            )));
        }
    }
    Ok(())
}

/// `grouped_agg_ref`: grouped SUM + COUNT + per-group MAX over valid rows.
fn grouped_agg(
    values: &[f32],
    gid: &[i32],
    valid: &[f32],
    g: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut sums = vec![0f32; g];
    let mut counts = vec![0f32; g];
    let mut rep = vec![f32::NEG_INFINITY; g];
    for idx in 0..values.len() {
        if valid[idx] <= 0.0 {
            continue;
        }
        let k = gid[idx];
        if k < 0 || k as usize >= g {
            continue;
        }
        let k = k as usize;
        sums[k] += values[idx];
        counts[k] += 1.0;
        rep[k] = rep[k].max(values[idx]);
    }
    for k in 0..g {
        if counts[k] <= 0.0 {
            rep[k] = 0.0;
        }
    }
    (sums, counts, rep)
}

/// `transform_ref` / `filter_project_cast`: filter to [lo, hi], affine
/// project, truncating int cast.
fn filter_project_cast(x: &[f32], valid: &[f32], params: &[f32]) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let (lo, hi, scale, offset) = (params[0], params[1], params[2], params[3]);
    let mut y = Vec::with_capacity(x.len());
    let mut y_int = Vec::with_capacity(x.len());
    let mut keep = Vec::with_capacity(x.len());
    for idx in 0..x.len() {
        let k = x[idx] >= lo && x[idx] <= hi && valid[idx] > 0.0;
        let v = if k { x[idx] * scale + offset } else { 0.0 };
        y.push(v);
        y_int.push(v.trunc() as i32);
        keep.push(if k { 1.0 } else { 0.0 });
    }
    (y, y_int, keep)
}

/// `stats_ref` padded to the kernel's f32[8] layout:
/// (count, excluded, min, max, nan_count, sum, 0, 0).
fn column_stats(x: &[f32], include: &[f32]) -> Vec<f32> {
    let mut cnt = 0.0;
    let mut exc = 0.0;
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    let mut nans = 0.0;
    let mut sum = 0.0;
    for (&v, &inc) in x.iter().zip(include) {
        if inc > 0.0 {
            cnt += 1.0;
            if v.is_nan() {
                nans += 1.0;
            } else {
                mn = mn.min(v);
                mx = mx.max(v);
                sum += v;
            }
        } else {
            exc += 1.0;
        }
    }
    vec![cnt, exc, mn, mx, nans, sum, 0.0, 0.0]
}

/// `join_ref`: for each left row, payload of the first matching valid
/// right row (integer key equality).
fn equi_join(
    lkey: &[i32],
    lvalid: &[f32],
    rkey: &[i32],
    rval: &[f32],
    rvalid: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut out = Vec::with_capacity(lkey.len());
    let mut matched = Vec::with_capacity(lkey.len());
    for idx in 0..lkey.len() {
        let mut hit = None;
        if lvalid[idx] > 0.0 {
            for j in 0..rkey.len() {
                if rvalid[j] > 0.0 && rkey[j] == lkey[idx] {
                    hit = Some(rval[j]);
                    break;
                }
            }
        }
        out.push(hit.unwrap_or(0.0));
        matched.push(if hit.is_some() { 1.0 } else { 0.0 });
    }
    (out, matched)
}

/// Execute `name` with the reference semantics of `compile/model.py`.
pub fn execute_sim(manifest: &Manifest, name: &str, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
    let spec = manifest.artifact(name)?;
    check_args(spec, args)?;
    let g = manifest.g;
    match name {
        "parent" => {
            let col1 = i32_arg(args, 0, name)?;
            let col2 = f32_arg(args, 1, name)?;
            let col3 = f32_arg(args, 2, name)?;
            let valid = f32_arg(args, 3, name)?;
            let (sums, counts, _) = grouped_agg(col3, col1, valid, g);
            let (_, _, rep2) = grouped_agg(col2, col1, valid, g);
            let keys: Vec<i32> = (0..g as i32).collect();
            let valid_out: Vec<f32> =
                counts.iter().map(|&c| if c > 0.0 { 1.0 } else { 0.0 }).collect();
            Ok(vec![
                TensorOut::I32(keys),
                TensorOut::F32(rep2),
                TensorOut::F32(sums),
                TensorOut::F32(valid_out),
            ])
        }
        "child" => {
            let col2 = f32_arg(args, 0, name)?;
            let s = f32_arg(args, 1, name)?;
            let valid = f32_arg(args, 2, name)?;
            let p = f32_arg(args, 3, name)?;
            let (lo, hi, scale, offset) = (p[0], p[1], p[2], p[3]);
            let mut col4 = Vec::with_capacity(g);
            let mut col5 = Vec::with_capacity(g);
            let mut col5_null = Vec::with_capacity(g);
            for idx in 0..g {
                col4.push(if valid[idx] > 0.0 {
                    s[idx] * scale + offset
                } else {
                    0.0
                });
                let in_range = s[idx] >= lo && s[idx] <= hi && valid[idx] > 0.0;
                col5.push(if in_range { s[idx] - lo } else { 0.0 });
                col5_null.push(if in_range { 0.0 } else { 1.0 });
            }
            Ok(vec![
                TensorOut::F32(col2.to_vec()),
                TensorOut::F32(col4),
                TensorOut::F32(col5),
                TensorOut::F32(col5_null),
                TensorOut::F32(valid.to_vec()),
            ])
        }
        "grand_child" => {
            let col2 = f32_arg(args, 0, name)?;
            let col4 = f32_arg(args, 1, name)?;
            let valid = f32_arg(args, 2, name)?;
            let p = f32_arg(args, 3, name)?;
            let (_, y_int, keep) = filter_project_cast(col4, valid, p);
            Ok(vec![
                TensorOut::F32(col2.to_vec()),
                TensorOut::I32(y_int),
                TensorOut::F32(keep),
            ])
        }
        "family_friend" => {
            let c_key = i32_arg(args, 0, name)?;
            let c_col2 = f32_arg(args, 1, name)?;
            let c_col4 = f32_arg(args, 2, name)?;
            let c_col5 = f32_arg(args, 3, name)?;
            let c_col5_null = f32_arg(args, 4, name)?;
            let c_valid = f32_arg(args, 5, name)?;
            let g_key = i32_arg(args, 6, name)?;
            let g_col4i = i32_arg(args, 7, name)?;
            let g_valid = f32_arg(args, 8, name)?;
            let p = f32_arg(args, 9, name)?;
            let eps = p[0];
            let g4: Vec<f32> = g_col4i.iter().map(|&x| x as f32).collect();
            let (g4f, matched) = equi_join(c_key, c_valid, g_key, &g4, g_valid);
            let w = c_key.len();
            let mut o2 = Vec::with_capacity(w);
            let mut o4 = Vec::with_capacity(w);
            let mut o5 = Vec::with_capacity(w);
            let mut keep = Vec::with_capacity(w);
            for idx in 0..w {
                let k = matched[idx] > 0.0
                    && c_col5_null[idx] < 1.0
                    && (g4f[idx] - c_col4[idx]).abs() < eps
                    && c_valid[idx] > 0.0;
                o2.push(if k { c_col2[idx] } else { 0.0 });
                o4.push(if k { g4f[idx] } else { 0.0 });
                o5.push(if k { c_col5[idx] } else { 0.0 });
                keep.push(if k { 1.0 } else { 0.0 });
            }
            Ok(vec![
                TensorOut::F32(o2),
                TensorOut::F32(o4),
                TensorOut::F32(o5),
                TensorOut::F32(keep),
            ])
        }
        "validate_n" | "validate_g" => {
            let x = f32_arg(args, 0, name)?;
            let include = f32_arg(args, 1, name)?;
            Ok(vec![TensorOut::F32(column_stats(x, include))])
        }
        "transform_n" | "transform_g" => {
            let x = f32_arg(args, 0, name)?;
            let valid = f32_arg(args, 1, name)?;
            let p = f32_arg(args, 2, name)?;
            let (y, y_int, keep) = filter_project_cast(x, valid, p);
            Ok(vec![TensorOut::F32(y), TensorOut::I32(y_int), TensorOut::F32(keep)])
        }
        other => Err(BauplanError::Pjrt(format!("sim: unknown artifact '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_covers_every_pipeline_op() {
        let m = sim_manifest();
        for op in [
            "parent",
            "child",
            "grand_child",
            "family_friend",
            "validate_n",
            "validate_g",
            "transform_n",
            "transform_g",
        ] {
            assert!(m.artifact(op).is_ok(), "missing {op}");
        }
        assert_eq!(m.n, SIM_N);
        assert_eq!(m.g, SIM_G);
    }

    #[test]
    fn grouped_agg_matches_reference_semantics() {
        let values = [1.0, 2.0, 4.0, 100.0];
        let gid = [0, 1, 0, 1];
        let valid = [1.0, 1.0, 1.0, 0.0]; // last row is padding
        let (sums, counts, rep) = grouped_agg(&values, &gid, &valid, 3);
        assert_eq!(sums, vec![5.0, 2.0, 0.0]);
        assert_eq!(counts, vec![2.0, 1.0, 0.0]);
        assert_eq!(rep, vec![4.0, 2.0, 0.0]); // empty group reps as 0
    }

    #[test]
    fn filter_project_cast_filters_and_truncates() {
        let (y, y_int, keep) =
            filter_project_cast(&[1.0, 5.0, -3.0], &[1.0, 1.0, 1.0], &[0.0, 4.0, 2.0, 0.5]);
        assert_eq!(keep, vec![1.0, 0.0, 0.0]);
        assert_eq!(y[0], 2.5);
        assert_eq!(y_int[0], 2);
        assert_eq!(y[1], 0.0); // filtered rows zeroed
    }

    #[test]
    fn equi_join_takes_first_valid_match() {
        let (out, matched) = equi_join(
            &[7, 9, 7],
            &[1.0, 1.0, 0.0],
            &[9, 7, 7],
            &[90.0, 70.0, 71.0],
            &[1.0, 1.0, 1.0],
        );
        assert_eq!(out, vec![70.0, 90.0, 0.0]);
        assert_eq!(matched, vec![1.0, 1.0, 0.0]);
    }

    #[test]
    fn execute_validates_call_shape() {
        let m = sim_manifest();
        let err = execute_sim(&m, "validate_g", &[TensorArg::F32(vec![0.0; 3])]);
        assert!(err.is_err()); // wrong arity
        let err = execute_sim(
            &m,
            "validate_g",
            &[TensorArg::F32(vec![0.0; 3]), TensorArg::F32(vec![0.0; 3])],
        );
        assert!(err.is_err()); // wrong width
    }

    #[test]
    fn stats_layout_matches_kernel_contract() {
        let m = sim_manifest();
        let mut x = vec![0.0f32; SIM_G];
        let mut inc = vec![0.0f32; SIM_G];
        x[0] = 1.0;
        x[1] = f32::NAN;
        x[2] = 3.0;
        inc[0] = 1.0;
        inc[1] = 1.0;
        inc[2] = 1.0;
        let out = execute_sim(&m, "validate_g", &[TensorArg::F32(x), TensorArg::F32(inc)])
            .unwrap();
        let s = out[0].as_f32().unwrap();
        assert_eq!(s.len(), 8);
        assert_eq!(s[0], 3.0); // included
        assert_eq!(s[1], (SIM_G - 3) as f32); // excluded
        assert_eq!(s[2], 1.0); // min skips NaN
        assert_eq!(s[3], 3.0); // max
        assert_eq!(s[4], 1.0); // NaN counted
        assert_eq!(s[5], 4.0); // sum skips NaN
    }
}
