//! `manifest.json` — the contract between the compile path and the rust
//! runtime. aot.py records every artifact's input/output shapes+dtypes;
//! the runtime refuses to execute a call that does not match. (The same
//! fail-fast philosophy as the data contracts, applied to the compute
//! layer.)
//!
//! The same boundary also carries the *scan* manifest: before a kernel
//! touches a row, [`ScanManifest::build`] fetches each object of a
//! snapshot and reads its zone-map footer from the tail
//! ([`crate::storage::codec::decode_stats`]), so the execution layer can
//! decide per batch whether the kernel needs to run at all
//! (`doc/DATA_PLANE.md`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::error::{BauplanError, Result};
use crate::storage::codec::{decode_stats, BatchStats};
use crate::storage::ObjectStore;
use crate::util::json::Json;

/// Shape + dtype of one tensor boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    /// "float32" | "int32"
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| BauplanError::Manifest("missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let dtype = j
            .get("dtype")
            .as_str()
            .ok_or_else(|| BauplanError::Manifest("missing dtype".into()))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT artifact's interface.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Content hash of the lowered HLO text, when the compile path
    /// recorded one (`aot.py` writes `sha256_16`). The sim backend
    /// stamps a fixed marker instead.
    pub sha256_16: Option<String>,
}

impl ArtifactSpec {
    /// Deterministic fingerprint of the compute this artifact performs:
    /// the op identity half of a run-cache key. Covers the recorded HLO
    /// content hash (when present) plus the full tensor interface, so a
    /// recompiled kernel or a reshaped boundary invalidates cached
    /// results.
    pub fn fingerprint(&self) -> String {
        let mut desc = String::new();
        desc.push_str(&self.name);
        desc.push('|');
        desc.push_str(self.sha256_16.as_deref().unwrap_or("-"));
        for (tag, specs) in [("i", &self.inputs), ("o", &self.outputs)] {
            for s in specs {
                desc.push('|');
                desc.push_str(tag);
                desc.push(':');
                desc.push_str(&s.dtype);
                for d in &s.shape {
                    desc.push_str(&format!(":{d}"));
                }
            }
        }
        crate::util::id::content_hash(desc.as_bytes())
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Fixed batch row count the artifacts were compiled for.
    pub n: usize,
    /// Group domain.
    pub g: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let n = j
            .get("N")
            .as_usize()
            .ok_or_else(|| BauplanError::Manifest("missing N".into()))?;
        let g = j
            .get("G")
            .as_usize()
            .ok_or_else(|| BauplanError::Manifest("missing G".into()))?;
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| BauplanError::Manifest("missing artifacts".into()))?;
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .as_str()
                .ok_or_else(|| BauplanError::Manifest(format!("{name}: missing file")))?
                .to_string();
            let inputs = spec
                .get("inputs")
                .as_arr()
                .ok_or_else(|| BauplanError::Manifest(format!("{name}: missing inputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .get("outputs")
                .as_arr()
                .ok_or_else(|| BauplanError::Manifest(format!("{name}: missing outputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let sha256_16 = spec.get("sha256_16").as_str().map(String::from);
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, inputs, outputs, sha256_16 },
            );
        }
        Ok(Manifest { n, g, artifacts })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Manifest::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| BauplanError::Manifest(format!("unknown artifact '{name}'")))
    }
}

/// One encoded batch object of a table scan: the bytes handle (shared
/// with the block cache — no copy) plus whatever zone map the codec
/// footer carried. `stats: None` means a legacy `BPB1` object or an
/// unreadable footer — always scanned, never pruned.
#[derive(Debug, Clone)]
pub struct ScanEntry {
    /// Content address of the object.
    pub key: String,
    /// The encoded object (zero-copy handle from the store).
    pub data: Arc<[u8]>,
    /// Zone map parsed from the object's tail, if present.
    pub stats: Option<BatchStats>,
}

/// Everything a scan knows about a snapshot's objects *before* decoding
/// any row payload — the per-table sidecar that predicate pushdown
/// consults.
#[derive(Debug, Clone, Default)]
pub struct ScanManifest {
    /// Table the snapshot belongs to.
    pub table: String,
    /// One entry per snapshot object, in snapshot order.
    pub entries: Vec<ScanEntry>,
}

impl ScanManifest {
    /// Fetch every object of `keys` (through the store's block cache)
    /// and parse each zone-map footer.
    pub fn build(table: &str, store: &ObjectStore, keys: &[String]) -> Result<ScanManifest> {
        let mut entries = Vec::with_capacity(keys.len());
        for key in keys {
            let data = store.get(key)?;
            let stats = decode_stats(&data);
            entries.push(ScanEntry { key: key.clone(), data, stats });
        }
        Ok(ScanManifest { table: table.to_string(), entries })
    }

    /// How many entries carry a zone map (candidates for pruning).
    pub fn with_stats(&self) -> usize {
        self.entries.iter().filter(|e| e.stats.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "N": 2048, "G": 64, "STATS_W": 8, "version": 1,
      "artifacts": {
        "parent": {
          "file": "parent.hlo.txt",
          "sha256_16": "abc",
          "inputs": [
            {"shape": [2048], "dtype": "int32"},
            {"shape": [2048], "dtype": "float32"}
          ],
          "outputs": [
            {"shape": [64], "dtype": "int32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n, 2048);
        assert_eq!(m.g, 64);
        let a = m.artifact("parent").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, "int32");
        assert_eq!(a.outputs[0].shape, vec![64]);
        assert_eq!(a.inputs[0].element_count(), 2048);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"N": 1, "G": 1}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn scan_manifest_surfaces_zone_maps() {
        use crate::storage::codec::encode_batch;
        use crate::storage::{Batch, Column};

        let store = ObjectStore::new();
        let b = Batch::new(vec![Column::f32("x", vec![1.0, 5.0])], vec![1.0, 1.0]).unwrap();
        let k_v2 = store.put(encode_batch(&b));
        // a legacy BPB1 object: no footer, so no stats
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"BPB1");
        v1.extend_from_slice(&0u32.to_le_bytes());
        v1.extend_from_slice(&0u32.to_le_bytes());
        let k_v1 = store.put(v1);

        let m = ScanManifest::build("t", &store, &[k_v2.clone(), k_v1]).unwrap();
        assert_eq!(m.table, "t");
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.with_stats(), 1);
        let s = m.entries[0].stats.as_ref().unwrap();
        assert_eq!((s.columns[0].min, s.columns[0].max), (1.0, 5.0));
        assert!(m.entries[1].stats.is_none());
        assert_eq!(m.entries[0].key, k_v2);

        // a missing object fails the build, not the kernel
        assert!(ScanManifest::build("t", &store, &["absent".into()]).is_err());
    }

    #[test]
    fn fingerprint_covers_hlo_hash_and_interface() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("parent").unwrap();
        assert_eq!(a.sha256_16.as_deref(), Some("abc"));
        assert_eq!(a.fingerprint(), a.fingerprint());
        // a recompiled kernel (new HLO hash) changes the fingerprint
        let mut b = a.clone();
        b.sha256_16 = Some("def".into());
        assert_ne!(a.fingerprint(), b.fingerprint());
        // so does a reshaped boundary
        let mut c = a.clone();
        c.outputs[0].shape = vec![128];
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
