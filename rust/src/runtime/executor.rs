//! The executor: PJRT CPU client + compiled executable cache.
//!
//! The `xla` crate's client/executables are thread-confined (`Rc` +
//! raw pointers, `!Send`), so the architecture mirrors the paper's
//! worker model (Fig. 1): [`Runtime`] is owned by dedicated executor
//! threads, and the coordinator talks to them through [`ExecHandle`] —
//! a cloneable, `Sync` channel front. `ExecHandle::start_pool` spawns K
//! workers, each with its own PJRT client, consuming a shared request
//! queue (K-way compute parallelism with zero shared mutable state).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};

use crate::error::{BauplanError, Result};
use crate::runtime::manifest::{Manifest, TensorSpec};
// The PJRT bindings: the offline build compiles against the stub shim in
// `runtime::pjrt`; swap this alias for the real `xla` crate to link PJRT.
use crate::runtime::pjrt as xla;

/// A tensor argument for an artifact call.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorArg {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorArg {
    pub fn len(&self) -> usize {
        match self {
            TensorArg::F32(v) => v.len(),
            TensorArg::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> &'static str {
        match self {
            TensorArg::F32(_) => "float32",
            TensorArg::I32(_) => "int32",
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            TensorArg::F32(v) => xla::Literal::vec1(v),
            TensorArg::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// A tensor result from an artifact call.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorOut::F32(v) => Ok(v),
            _ => Err(BauplanError::Pjrt("expected f32 output".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorOut::I32(v) => Ok(v),
            _ => Err(BauplanError::Pjrt("expected i32 output".into())),
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: crate::runtime::manifest::ArtifactSpec,
}

/// The runtime: loads every artifact in a directory, validates against
/// the manifest, and serves execute calls from the coordinator hot path.
pub struct Runtime {
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
    dir: PathBuf,
}

impl Runtime {
    /// Compile every artifact in `dir` (must contain `manifest.json`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| {
                    BauplanError::Manifest(format!("bad path {path:?}"))
                })?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            compiled.insert(name.clone(), Compiled { exe, spec: spec.clone() });
        }
        Ok(Runtime { manifest, compiled, dir: dir.to_path_buf() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    /// Execute `artifact` with `args`; returns one [`TensorOut`] per
    /// declared output. Shapes and dtypes are validated before the call.
    pub fn execute(&self, artifact: &str, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        let c = self.compiled.get(artifact).ok_or_else(|| {
            BauplanError::Manifest(format!("artifact '{artifact}' not loaded"))
        })?;
        // -- call-site validation ------------------------------------------
        if args.len() != c.spec.inputs.len() {
            return Err(BauplanError::Pjrt(format!(
                "{artifact}: expected {} args, got {}",
                c.spec.inputs.len(),
                args.len()
            )));
        }
        for (i, (a, s)) in args.iter().zip(&c.spec.inputs).enumerate() {
            if a.len() != s.element_count() {
                return Err(BauplanError::Pjrt(format!(
                    "{artifact}: arg {i} has {} elements, expected {}",
                    a.len(),
                    s.element_count()
                )));
            }
            if a.dtype() != s.dtype {
                return Err(BauplanError::Pjrt(format!(
                    "{artifact}: arg {i} is {}, expected {}",
                    a.dtype(),
                    s.dtype
                )));
            }
        }
        // -- literal conversion + execute ----------------------------------
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&c.spec.inputs)
            .map(|(a, s)| a.to_literal(s))
            .collect::<Result<_>>()?;
        let result = c.exe.execute::<xla::Literal>(&literals)?;
        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| BauplanError::Pjrt("empty result".into()))?
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let elems = root.to_tuple()?;
        if elems.len() != c.spec.outputs.len() {
            return Err(BauplanError::Pjrt(format!(
                "{artifact}: got {} outputs, manifest says {}",
                elems.len(),
                c.spec.outputs.len()
            )));
        }
        let mut outs = Vec::with_capacity(elems.len());
        for (lit, spec) in elems.into_iter().zip(&c.spec.outputs) {
            let out = match spec.dtype.as_str() {
                "float32" => TensorOut::F32(lit.to_vec::<f32>()?),
                "int32" => TensorOut::I32(lit.to_vec::<i32>()?),
                other => {
                    return Err(BauplanError::Pjrt(format!(
                        "{artifact}: unsupported output dtype {other}"
                    )));
                }
            };
            outs.push(out);
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// ExecHandle: the Sync front the coordinator uses.
// ---------------------------------------------------------------------------

struct Request {
    artifact: String,
    args: Vec<TensorArg>,
    reply: mpsc::Sender<Result<Vec<TensorOut>>>,
}

/// How an [`ExecHandle`] actually runs kernels.
enum Backend {
    /// The real path: a pool of executor threads, each owning a
    /// thread-confined PJRT [`Runtime`].
    Pool(Mutex<mpsc::Sender<Request>>),
    /// Pure-rust reference semantics (`runtime::sim`) — no PJRT, no
    /// artifacts directory; executes synchronously on the caller thread.
    Sim,
}

/// A pending kernel execution — the completion half of
/// [`ExecHandle::submit`]. Dropping it abandons the result (the executor
/// thread's send fails harmlessly).
pub struct ExecCompletion {
    rx: mpsc::Receiver<Result<Vec<TensorOut>>>,
}

impl ExecCompletion {
    /// Block until the kernel result arrives.
    pub fn wait(self) -> Result<Vec<TensorOut>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(BauplanError::Pjrt("executor dropped request".into())),
        }
    }

    /// Non-blocking poll: `Some(result)` once the kernel finished,
    /// `None` while it is still in flight. A dead executor (reply sender
    /// dropped without answering — and any poll after the result was
    /// already taken) reports the dropped-request error rather than
    /// blending into "still in flight", so pollers can't spin forever.
    pub fn try_wait(&self) -> Option<Result<Vec<TensorOut>>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(BauplanError::Pjrt("executor dropped request".into())))
            }
        }
    }
}

/// Cloneable, `Send + Sync` handle to the compute backend. All
/// coordinator code (worker, benches, examples) talks to kernels through
/// this — either a pool of PJRT executor threads or the in-process sim.
pub struct ExecHandle {
    backend: Backend,
    manifest: Manifest,
    workers: usize,
}

impl ExecHandle {
    /// Single executor thread.
    pub fn start(dir: &Path) -> Result<ExecHandle> {
        Self::start_pool(dir, 1)
    }

    /// The simulated backend: every artifact served by the pure-rust
    /// reference implementations in [`crate::runtime::sim`]. Needs no
    /// artifacts directory and no PJRT — the offline path for end-to-end
    /// runs, the run cache, and CI smoke benches.
    pub fn sim() -> ExecHandle {
        ExecHandle {
            backend: Backend::Sim,
            manifest: crate::runtime::sim::sim_manifest(),
            workers: 0,
        }
    }

    /// `workers` executor threads, each with its own PJRT client and
    /// compiled executable cache, pulling from one shared queue.
    pub fn start_pool(dir: &Path, workers: usize) -> Result<ExecHandle> {
        let workers = workers.max(1);
        let manifest = Manifest::load(dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        for _ in 0..workers {
            let dir = dir.to_path_buf();
            let rx = rx.clone();
            let init_tx = init_tx.clone();
            std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                loop {
                    // hold the lock only while dequeueing
                    let req = match rx.lock().unwrap().recv() {
                        Ok(r) => r,
                        Err(_) => break, // all senders dropped: shut down
                    };
                    let out = rt.execute(&req.artifact, &req.args);
                    let _ = req.reply.send(out);
                }
            });
        }
        drop(init_tx);
        for _ in 0..workers {
            init_rx
                .recv()
                .map_err(|_| BauplanError::Pjrt("executor init lost".into()))??;
        }
        Ok(ExecHandle { backend: Backend::Pool(Mutex::new(tx)), manifest, workers })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.manifest.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Enqueue `artifact` on the backend without blocking: returns an
    /// [`ExecCompletion`] the caller waits on when it needs the result.
    /// This is the fan-out primitive the wavefront scheduler and the
    /// worker's multi-batch ops use to keep every executor busy: submit
    /// all independent kernels first, then collect.
    ///
    /// On the pool backend the request is queued and picked up by the
    /// next free executor thread. On the sim backend (no queue, pure
    /// rust) the kernel runs eagerly on the calling thread and the
    /// completion is immediately ready — concurrency across sim kernels
    /// comes from calling `submit` on multiple scheduler threads.
    pub fn submit(&self, artifact: &str, args: &[TensorArg]) -> Result<ExecCompletion> {
        let (reply, rx) = mpsc::channel();
        match &self.backend {
            Backend::Sim => {
                let out = crate::runtime::sim::execute_sim(&self.manifest, artifact, args);
                let _ = reply.send(out);
            }
            Backend::Pool(tx) => {
                let tx = tx.lock().unwrap();
                tx.send(Request {
                    artifact: artifact.to_string(),
                    args: args.to_vec(),
                    reply,
                })
                .map_err(|_| BauplanError::Pjrt("executor pool is down".into()))?;
            }
        }
        Ok(ExecCompletion { rx })
    }

    /// Execute `artifact` on the backend; blocks for the result
    /// (`submit` + wait).
    pub fn execute(&self, artifact: &str, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        self.submit(artifact, args)?.wait()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full end-to-end runtime tests live in rust/tests/ (they need the
    // artifacts directory built by `make artifacts`). Here: arg checks.

    #[test]
    fn tensor_arg_reports_len_and_dtype() {
        let a = TensorArg::F32(vec![1.0; 8]);
        assert_eq!(a.len(), 8);
        assert_eq!(a.dtype(), "float32");
        let b = TensorArg::I32(vec![1; 4]);
        assert_eq!(b.dtype(), "int32");
    }

    #[test]
    fn tensor_out_accessors() {
        let o = TensorOut::F32(vec![1.0]);
        assert!(o.as_f32().is_ok());
        assert!(o.as_i32().is_err());
    }

    #[test]
    fn sim_submit_completion_is_ready_and_matches_execute() {
        let h = ExecHandle::sim();
        let n = h.manifest().n;
        let args = [TensorArg::F32(vec![2.0; n]), TensorArg::F32(vec![1.0; n])];
        let pending = h.submit("validate_n", &args).unwrap();
        // sim runs eagerly: the completion is already resolved
        let polled = pending.try_wait().expect("sim completion must be ready");
        assert_eq!(polled.unwrap(), h.execute("validate_n", &args).unwrap());
        // wait() after a fresh submit returns the same result
        let again = h.submit("validate_n", &args).unwrap().wait().unwrap();
        assert_eq!(again, h.execute("validate_n", &args).unwrap());
    }

    #[test]
    fn submit_surfaces_kernel_errors_at_wait() {
        let h = ExecHandle::sim();
        let err = h
            .submit("validate_n", &[TensorArg::F32(vec![1.0])])
            .unwrap()
            .wait();
        assert!(err.is_err(), "arity/shape error must surface through wait()");
    }
}
