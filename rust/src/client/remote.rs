//! `RemoteClient` — the wire twin of [`Client`](crate::client::Client).
//!
//! Speaks the API server's JSON protocol (`doc/SERVER.md`) over a
//! keep-alive TCP connection, using the crate's canonical JSON
//! ([`crate::util::json`]) on both sides. Method names and error
//! behaviour mirror the in-process `Client`/`Catalog` surface, so call
//! sites are backend-agnostic: the server's
//! [`ApiError`](crate::server::ApiError) shape decodes back into the
//! *same* [`BauplanError`] variants an in-process caller
//! would see (`CasConflict`, `Visibility`, `MergeConflict`, ...), and
//! the PR 4 simulator exploits exactly that to run its oracle suite
//! unchanged through a real loopback socket.
//!
//! Concurrency contract: CAS conflicts arrive as retryable 409s whose
//! structured details name the branch, the `expected_head` the request
//! pinned, and the `actual_head` that beat it. [`RemoteClient::commit`]
//! with [`RemoteCommit::retrying`] runs the *informed* CAS loop: pin
//! the observed head, and on conflict rebase directly onto the 409's
//! `actual_head` — one round-trip per conflict round, no re-read. This
//! is the same optimistic-concurrency discipline `Catalog::commit`
//! enforces in its per-branch critical section (`doc/CONCURRENCY.md`).
//! Blind resubmission of a failed CAS would loop forever; the carried
//! live head is what the `retryable` flag licenses.
//!
//! Transport errors on a cached keep-alive connection (server restart,
//! idle-timeout close) trigger exactly one transparent reconnect per
//! request; a failure on the fresh connection propagates.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::catalog::{persist, BranchInfo, BranchState, Commit, TableDiff};
use crate::error::{BauplanError, Result};
use crate::runs::{run_state_from_json, RunState};
use crate::server::http::{read_line_capped, ReadError, FRAME_MAGIC};
use crate::storage::Table;
use crate::trace::{TraceCtx, TRACE_HEADER};
use crate::util::json::Json;

/// How long a response read may stall before the client gives up.
const READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Pooled connections idle longer than this are dropped *before* the
/// next request instead of reused: the server's default idle timeout is
/// 5s, so reusing an older connection would race its close — and for a
/// non-idempotent request that race is unretryable (see [`RemoteClient`]).
const POOL_IDLE_MAX: Duration = Duration::from_millis(2500);

/// Client-side conflict policy for [`RemoteClient::commit`] — the wire
/// twin of the catalog's `RetryPolicy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemoteRetryPolicy {
    /// Send the request once; a moved head (with `expected_head`
    /// pinned) surfaces as the retryable 409
    /// [`CasConflict`](BauplanError::CasConflict) for the caller.
    OneShot,
    /// Informed CAS loop: pin the observed head, and on each conflict
    /// rebase directly onto the `actual_head` the 409 carries — one
    /// round-trip per round, no re-read.
    InformedCas,
}

/// One remote table commit (`POST /v1/commit`). Public fields; build
/// with [`RemoteCommit::new`] and override what you need.
#[derive(Debug, Clone)]
pub struct RemoteCommit<'a> {
    /// Branch to commit to.
    pub branch: &'a str,
    /// Table the commit writes.
    pub table: &'a str,
    /// Object payload (stored content-addressed server-side).
    pub content: &'a str,
    /// Schema name recorded on the snapshot.
    pub schema: &'a str,
    /// Schema fingerprint recorded on the snapshot.
    pub fingerprint: &'a str,
    /// Row count recorded on the snapshot.
    pub rows: u64,
    /// `run_id` recorded on the snapshot (part of its content address).
    pub snap_run_id: &'a str,
    /// Commit author.
    pub author: &'a str,
    /// Commit message.
    pub message: &'a str,
    /// `run_id` recorded on the commit, if any.
    pub run_id: Option<&'a str>,
    /// CAS guard: fail with a retryable 409 if the head moved past this.
    pub expected_head: Option<&'a str>,
    /// Client-side conflict policy (see [`RemoteRetryPolicy`]).
    pub retry: RemoteRetryPolicy,
}

impl<'a> RemoteCommit<'a> {
    /// A minimal commit of `content` to `branch`/`table`; every other
    /// field takes a neutral default.
    pub fn new(branch: &'a str, table: &'a str, content: &'a str) -> RemoteCommit<'a> {
        RemoteCommit {
            branch,
            table,
            content,
            schema: "RemoteTable",
            fingerprint: "remote_fp",
            rows: 1,
            snap_run_id: "remote",
            author: "remote",
            message: "remote write",
            run_id: None,
            expected_head: None,
            retry: RemoteRetryPolicy::OneShot,
        }
    }

    /// Opt into the informed CAS retry loop
    /// ([`RemoteRetryPolicy::InformedCas`]).
    pub fn retrying(mut self) -> RemoteCommit<'a> {
        self.retry = RemoteRetryPolicy::InformedCas;
        self
    }
}

/// What a successful [`RemoteClient::commit`] produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteCommitOutcome {
    /// Id of the commit that now heads the branch.
    pub commit: String,
    /// Id of the snapshot the commit published.
    pub snapshot: String,
    /// Conflict rounds the *server* absorbed before the commit landed
    /// (its rebase loop; 0 whenever `expected_head` was pinned).
    pub server_retries: u64,
    /// Conflict rounds *this client* absorbed via the informed CAS
    /// loop (always 0 under [`RemoteRetryPolicy::OneShot`]).
    pub client_retries: u64,
}

/// Options for [`RemoteClient::submit_run`].
#[derive(Debug, Clone, Default)]
pub struct RemoteRunOpts {
    /// `true` = the DirectWrite baseline; `false` = transactional.
    pub mode_direct: bool,
    /// Wavefront width (`--jobs`); 0 reads as 1.
    pub jobs: usize,
    /// Pin the run id (deterministic replay); `None` = server-assigned.
    pub run_id: Option<String>,
    /// Serializable fault injection: `("crash_before"|"crash_after", node)`.
    pub fault: Option<(String, String)>,
    /// Step-3 verifier: `(table, min rows)`.
    pub min_rows: Option<(String, u64)>,
    /// `--no-cache`: execute every node even when the server has a
    /// verified cache entry.
    pub no_cache: bool,
    /// Pin the trace context sent on the `x-bauplan-trace` header, so
    /// the server-side run trace continues *this* caller's trace id.
    /// `None` = a fresh context per request (the default for every
    /// [`RemoteClient`] call).
    pub trace: Option<TraceCtx>,
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    last_used: Instant,
}

/// Percent-encode a ref/key for use in a request path or query value.
/// `/` stays literal (the server rejoins path segments on it — branch
/// names like `txn/run_1` route as-is); everything else outside the
/// unreserved set is `%XX`-encoded, so names with spaces, `?`, `#`,
/// `&`, or `=` survive the wire instead of corrupting the request line.
fn urlenc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// A lakehouse client speaking the wire protocol to a `bauplan serve`
/// endpoint. Cheap to create; holds at most one pooled connection.
pub struct RemoteClient {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

impl RemoteClient {
    /// A client for `addr` — `host:port`, with or without an `http://`
    /// prefix. No I/O happens until the first request.
    pub fn new(addr: &str) -> RemoteClient {
        let addr = addr.trim_start_matches("http://").trim_end_matches('/').to_string();
        RemoteClient { addr, conn: Mutex::new(None) }
    }

    /// The `host:port` this client talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn connect(&self) -> Result<Conn> {
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
        let writer = stream.try_clone()?;
        Ok(Conn { reader: BufReader::new(stream), writer, last_used: Instant::now() })
    }

    /// One request/response exchange over the pooled connection.
    ///
    /// Retry discipline (the non-idempotency rule): a failure while
    /// *writing* the request is always retryable once — a request the
    /// server never fully received cannot have executed. A failure
    /// while *reading the response* means the server may already have
    /// applied the request, so only idempotent methods (GET) retry;
    /// for a POST the error propagates rather than risking a duplicate
    /// commit or run. Stale pooled connections are dropped proactively
    /// ([`POOL_IDLE_MAX`]) so the write-phase race stays rare.
    fn roundtrip(&self, method: &str, path: &str, body: Option<&str>) -> Result<(u16, Vec<u8>)> {
        self.roundtrip_traced(method, path, body, None)
    }

    fn roundtrip_traced(
        &self,
        method: &str,
        path: &str,
        body: Option<&str>,
        trace: Option<&TraceCtx>,
    ) -> Result<(u16, Vec<u8>)> {
        // One logical request = one trace context, even across the
        // single transparent retry: a fresh context is minted here (not
        // per attempt) so a retried request is recognizably the same
        // operation in the server's flight recorder.
        let trace_header = match trace {
            Some(c) => c.header_value(),
            None => TraceCtx::new().header_value(),
        };
        for attempt in 0..2 {
            let mut guard = self.conn.lock().unwrap();
            let stale = guard
                .as_ref()
                .map(|c| c.last_used.elapsed() > POOL_IDLE_MAX)
                .unwrap_or(false);
            if stale {
                *guard = None;
            }
            let had_pooled = guard.is_some();
            if guard.is_none() {
                *guard = Some(self.connect()?);
            }
            let conn = guard.as_mut().expect("just ensured");
            if let Err(e) = Self::write_request(conn, method, path, body, &trace_header) {
                *guard = None;
                // the request never fully left: safe to retry any method
                if attempt == 1 || !had_pooled {
                    return Err(e);
                }
                continue;
            }
            match Self::read_response(conn) {
                Ok((status, bytes, keep)) => {
                    if keep {
                        conn.last_used = Instant::now();
                    } else {
                        *guard = None;
                    }
                    return Ok((status, bytes));
                }
                Err(e) => {
                    *guard = None;
                    // the server may have executed the request — only
                    // idempotent reads earn a transparent retry
                    if attempt == 1 || !had_pooled || method != "GET" {
                        return Err(e);
                    }
                }
            }
        }
        unreachable!("loop returns on success or error")
    }

    fn write_request(
        conn: &mut Conn,
        method: &str,
        path: &str,
        body: Option<&str>,
        trace_header: &str,
    ) -> Result<()> {
        let payload = body.unwrap_or("");
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: bauplan\r\ncontent-length: {}\r\n",
            payload.len()
        );
        if body.is_some() {
            head.push_str("content-type: application/json\r\n");
        }
        head.push_str(&format!("{TRACE_HEADER}: {trace_header}\r\n"));
        head.push_str("connection: keep-alive\r\n\r\n");
        conn.writer.write_all(head.as_bytes())?;
        conn.writer.write_all(payload.as_bytes())?;
        conn.writer.flush()?;
        Ok(())
    }

    fn read_response(conn: &mut Conn) -> Result<(u16, Vec<u8>, bool)> {
        let status_line = Self::read_line(&mut conn.reader)?;
        let mut parts = status_line.split_whitespace();
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            return Err(BauplanError::Parse(format!("bad response line {status_line:?}")));
        }
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| BauplanError::Parse(format!("bad status in {status_line:?}")))?;
        let mut content_length = 0usize;
        let mut keep = true;
        loop {
            let line = Self::read_line(&mut conn.reader)?;
            if line.is_empty() {
                break;
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length = value
                    .parse()
                    .map_err(|_| BauplanError::Parse(format!("bad content-length {value:?}")))?;
            } else if name == "connection" && value.eq_ignore_ascii_case("close") {
                keep = false;
            }
        }
        let mut bytes = vec![0u8; content_length];
        conn.reader.read_exact(&mut bytes)?;
        Ok((status, bytes, keep))
    }

    fn read_line(r: &mut BufReader<TcpStream>) -> Result<String> {
        match read_line_capped(r, 16 * 1024, None) {
            Ok(Some(l)) => Ok(l),
            Ok(None) => Err(BauplanError::Io(std::io::Error::new(
                std::io::ErrorKind::ConnectionAborted,
                "server closed the connection",
            ))),
            Err(ReadError::Closed) => Err(BauplanError::Io(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "read timed out",
            ))),
            Err(ReadError::TooLarge) => {
                Err(BauplanError::Parse("response header too large".into()))
            }
            Err(ReadError::Malformed(m)) => Err(BauplanError::Parse(m)),
        }
    }

    /// JSON request/response; non-2xx decodes back into the matching
    /// [`BauplanError`] variant via the structured `ApiError` payload.
    fn call(&self, method: &str, path: &str, body: Option<&Json>) -> Result<Json> {
        self.call_traced(method, path, body, None)
    }

    fn call_traced(
        &self,
        method: &str,
        path: &str,
        body: Option<&Json>,
        trace: Option<&TraceCtx>,
    ) -> Result<Json> {
        let body_s = body.map(|j| j.to_string());
        let (status, bytes) = self.roundtrip_traced(method, path, body_s.as_deref(), trace)?;
        let text = String::from_utf8(bytes)
            .map_err(|_| BauplanError::Parse("non-utf8 response body".into()))?;
        let j = if text.trim().is_empty() { Json::Null } else { Json::parse(&text)? };
        if (200..300).contains(&status) {
            return Ok(j);
        }
        Err(Self::decode_error(status, &j))
    }

    /// Inverse of the server's `api_error` mapping.
    fn decode_error(status: u16, j: &Json) -> BauplanError {
        let e = j.get("error");
        let code = e.get("code").as_str().unwrap_or("");
        let message = e.get("message").as_str().unwrap_or("").to_string();
        let d = e.get("details");
        let detail = |key: &str| d.get(key).as_str().unwrap_or(&message).to_string();
        match code {
            "unknown_ref" => BauplanError::UnknownRef(detail("ref")),
            "ref_exists" => BauplanError::RefExists(detail("ref")),
            "cas_conflict" => {
                // Prefer the PR 9 enriched keys; fall back to the
                // pre-PR-9 names so an older server still decodes. An
                // absent detail decodes as "" (not the message) so the
                // informed retry loop can tell "no live head on the
                // wire" apart from a real head.
                let pick = |new: &str, old: &str| {
                    d.get(new)
                        .as_str()
                        .or_else(|| d.get(old).as_str())
                        .unwrap_or("")
                        .to_string()
                };
                BauplanError::CasConflict {
                    reference: pick("branch", "reference"),
                    expected: pick("expected_head", "expected"),
                    found: pick("actual_head", "found"),
                }
            }
            "merge_conflict" => BauplanError::MergeConflict(detail("message")),
            "visibility" => BauplanError::Visibility(detail("message")),
            "object_not_found" => BauplanError::ObjectNotFound(detail("key")),
            "table_not_found" => BauplanError::TableNotFound(detail("table")),
            "parse" => BauplanError::Parse(message.clone()),
            "poisoned" => BauplanError::Poisoned(detail("message")),
            _ => BauplanError::Other(format!("api error {status} {code}: {message}")),
        }
    }

    // ------------------------------------------------------------ health

    /// `GET /healthz`.
    pub fn healthz(&self) -> Result<Json> {
        self.call("GET", "/healthz", None)
    }

    /// `GET /v1/status` — the readiness document: version, uptime,
    /// poisoned flag, recovery stats, and the background auditor's
    /// summary. Served even on a poisoned server.
    pub fn status(&self) -> Result<Json> {
        self.call("GET", "/v1/status", None)
    }

    /// `GET /metrics` — Prometheus text exposition.
    pub fn metrics_text(&self) -> Result<String> {
        let (status, bytes) = self.roundtrip("GET", "/metrics", None)?;
        if status != 200 {
            return Err(BauplanError::Other(format!("metrics: status {status}")));
        }
        String::from_utf8(bytes).map_err(|_| BauplanError::Parse("non-utf8 metrics".into()))
    }

    /// `GET /v1/export` — the catalog's canonical whole-state export.
    pub fn export(&self) -> Result<Json> {
        self.call("GET", "/v1/export", None)
    }

    // ------------------------------------------------------------ branches

    fn branch_from_json(j: &Json) -> Result<BranchInfo> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("branch: missing name".into()))?;
        persist::branch_from_json(name, j)
    }

    /// `POST /v1/branches`.
    pub fn create_branch(&self, name: &str, from: &str, allow_aborted: bool) -> Result<BranchInfo> {
        let body = Json::obj(vec![
            ("name", Json::str(name)),
            ("from", Json::str(from)),
            ("allow_aborted", Json::Bool(allow_aborted)),
        ]);
        Self::branch_from_json(&self.call("POST", "/v1/branches", Some(&body))?)
    }

    /// `POST /v1/txn-branches` — the run engine's namespaced branch.
    pub fn create_txn_branch(&self, target: &str, run_id: &str) -> Result<BranchInfo> {
        let body =
            Json::obj(vec![("target", Json::str(target)), ("run_id", Json::str(run_id))]);
        Self::branch_from_json(&self.call("POST", "/v1/txn-branches", Some(&body))?)
    }

    /// `GET /v1/branches/{name}`.
    pub fn branch_info(&self, name: &str) -> Result<BranchInfo> {
        Self::branch_from_json(&self.call("GET", &format!("/v1/branches/{}", urlenc(name)), None)?)
    }

    /// `GET /v1/branches`.
    pub fn list_branches(&self) -> Result<Vec<BranchInfo>> {
        let j = self.call("GET", "/v1/branches", None)?;
        j.get("branches")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(Self::branch_from_json)
            .collect()
    }

    /// `DELETE /v1/branches/{name}`.
    pub fn delete_branch(&self, name: &str) -> Result<()> {
        self.call("DELETE", &format!("/v1/branches/{}", urlenc(name)), None).map(|_| ())
    }

    /// `POST /v1/branches/{name}/state` — transactional lifecycle move.
    pub fn set_branch_state(&self, name: &str, state: BranchState) -> Result<()> {
        let body = Json::obj(vec![("state", Json::str(persist::branch_state_str(state)))]);
        self.call("POST", &format!("/v1/branches/{}/state", urlenc(name)), Some(&body)).map(|_| ())
    }

    // ------------------------------------------------------------ merge ops

    /// `POST /v1/merge`; returns the resulting commit id.
    pub fn merge(&self, src: &str, dst: &str, allow_aborted: bool) -> Result<String> {
        let body = Json::obj(vec![
            ("src", Json::str(src)),
            ("dst", Json::str(dst)),
            ("allow_aborted", Json::Bool(allow_aborted)),
        ]);
        let j = self.call("POST", "/v1/merge", Some(&body))?;
        Ok(j.get("commit").as_str().unwrap_or_default().to_string())
    }

    /// `POST /v1/rebase`; returns the new branch head.
    pub fn rebase(&self, branch: &str, onto: &str) -> Result<String> {
        let body = Json::obj(vec![("branch", Json::str(branch)), ("onto", Json::str(onto))]);
        let j = self.call("POST", "/v1/rebase", Some(&body))?;
        Ok(j.get("commit").as_str().unwrap_or_default().to_string())
    }

    /// `POST /v1/cherry-pick`; returns the new head of `onto`.
    pub fn cherry_pick(&self, commit_ref: &str, onto: &str) -> Result<String> {
        let body = Json::obj(vec![
            ("commit_ref", Json::str(commit_ref)),
            ("onto", Json::str(onto)),
        ]);
        let j = self.call("POST", "/v1/cherry-pick", Some(&body))?;
        Ok(j.get("commit").as_str().unwrap_or_default().to_string())
    }

    /// `POST /v1/tags`; returns the tagged commit id.
    pub fn tag(&self, name: &str, target: &str) -> Result<String> {
        let body = Json::obj(vec![("name", Json::str(name)), ("target", Json::str(target))]);
        let j = self.call("POST", "/v1/tags", Some(&body))?;
        Ok(j.get("commit").as_str().unwrap_or_default().to_string())
    }

    // ------------------------------------------------------------ reads

    fn commit_from_wire(j: &Json) -> Result<Commit> {
        let id = j
            .get("id")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("commit: missing id".into()))?;
        Ok(persist::commit_from_json(id, j.get("commit")))
    }

    /// `GET /v1/refs/{ref}` — the full commit a ref points at.
    pub fn read_ref(&self, r: &str) -> Result<Commit> {
        Self::commit_from_wire(&self.call("GET", &format!("/v1/refs/{}", urlenc(r)), None)?)
    }

    /// `GET /v1/log/{ref}?limit=N` — first-parent history, newest first.
    pub fn log(&self, r: &str, limit: usize) -> Result<Vec<Commit>> {
        let j = self.call("GET", &format!("/v1/log/{}?limit={limit}", urlenc(r)), None)?;
        j.get("commits")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(Self::commit_from_wire)
            .collect()
    }

    /// `GET /v1/diff?from=..&to=..` — table-level diff.
    pub fn diff(&self, from: &str, to: &str) -> Result<Vec<TableDiff>> {
        let j = self.call("GET", &format!("/v1/diff?from={}&to={}", urlenc(from), urlenc(to)), None)?;
        let mut out = Vec::new();
        for d in j.get("diffs").as_arr().unwrap_or(&[]) {
            let table = d.get("table").as_str().unwrap_or_default().to_string();
            let from_s = d.get("from").as_str().unwrap_or_default().to_string();
            let to_s = d.get("to").as_str().unwrap_or_default().to_string();
            out.push(match d.get("kind").as_str() {
                Some("added") => TableDiff::Added(table, to_s),
                Some("removed") => TableDiff::Removed(table, from_s),
                Some("changed") => TableDiff::Changed { table, from: from_s, to: to_s },
                other => return Err(BauplanError::Parse(format!("diff: bad kind {other:?}"))),
            });
        }
        Ok(out)
    }

    /// `GET /v1/table?ref=..&name=..` — snapshot metadata of one table.
    pub fn get_table(&self, r: &str, name: &str) -> Result<Json> {
        self.call("GET", &format!("/v1/table?ref={}&name={}", urlenc(r), urlenc(name)), None)
    }

    /// `GET /v1/objects/{key}` — raw object bytes.
    pub fn get_object(&self, key: &str) -> Result<Vec<u8>> {
        let (status, bytes) = self.roundtrip("GET", &format!("/v1/objects/{}", urlenc(key)), None)?;
        if status == 200 {
            return Ok(bytes);
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let j = Json::parse(&text).unwrap_or(Json::Null);
        Err(Self::decode_error(status, &j))
    }

    /// `GET /v1/table/{name}/data?ref=..` — the streamed binary read
    /// path. The body is a frame stream (frame 0 = snapshot metadata
    /// JSON, every later frame one raw codec object), decoded here into
    /// a [`Table`]. This replaces reassembling a table from per-object
    /// `GET /v1/objects/{key}` JSON roundtrips. A mid-stream disconnect
    /// surfaces as the transport's `Io` error (the content-length read
    /// comes up short); a corrupt body as a structured `Parse` error.
    pub fn get_table_data(&self, r: &str, name: &str) -> Result<Table> {
        let path = format!("/v1/table/{}/data?ref={}", urlenc(name), urlenc(r));
        let (status, bytes) = self.roundtrip("GET", &path, None)?;
        if status != 200 {
            let text = String::from_utf8_lossy(&bytes).into_owned();
            let j = Json::parse(&text).unwrap_or(Json::Null);
            return Err(Self::decode_error(status, &j));
        }
        decode_table_frames(&bytes)
    }

    /// `GET /v1/table/{name}/data?format=json` — the JSON comparison
    /// path of the same route (`bench_server` measures it against the
    /// frame stream; prefer [`RemoteClient::get_table_data`]).
    pub fn get_table_data_json(&self, r: &str, name: &str) -> Result<Json> {
        let path =
            format!("/v1/table/{}/data?ref={}&format=json", urlenc(name), urlenc(r));
        self.call("GET", &path, None)
    }

    /// `POST /v1/objects` — content-addressed put; returns the key.
    pub fn put_object(&self, content: &str) -> Result<String> {
        let body = Json::obj(vec![("content", Json::str(content))]);
        let j = self.call("POST", "/v1/objects", Some(&body))?;
        Ok(j.get("key").as_str().unwrap_or_default().to_string())
    }

    // ------------------------------------------------------------ writes

    /// `POST /v1/commit` behind the PR 9 unified commit API: one
    /// request type, one method, conflict behaviour on the request.
    ///
    /// Under [`RemoteRetryPolicy::OneShot`] the request is sent once;
    /// with [`RemoteCommit::expected_head`] pinned, a moved head fails
    /// with the retryable 409 [`BauplanError::CasConflict`], whose
    /// `found` field carries the live head. Under
    /// [`RemoteRetryPolicy::InformedCas`] the client runs the informed
    /// loop: seed the head from `expected_head` (or one read), and on
    /// each conflict rebase directly onto the 409's `actual_head` —
    /// one round-trip per conflict round.
    pub fn commit(&self, c: &RemoteCommit<'_>) -> Result<RemoteCommitOutcome> {
        match c.retry {
            RemoteRetryPolicy::OneShot => self.commit_once(c, 0),
            RemoteRetryPolicy::InformedCas => {
                let mut head = match c.expected_head {
                    Some(h) => h.to_string(),
                    None => self.branch_info(c.branch)?.head,
                };
                let mut client_retries = 0u64;
                loop {
                    let mut attempt = c.clone();
                    attempt.expected_head = Some(&head);
                    match self.commit_once(&attempt, client_retries) {
                        Err(BauplanError::CasConflict { found, .. }) => {
                            client_retries += 1;
                            // Informed rebase: the 409 already carries
                            // the head that beat us. Only a legacy
                            // server (empty `found`) costs a re-read.
                            head = if found.is_empty() {
                                self.branch_info(c.branch)?.head
                            } else {
                                found
                            };
                        }
                        Err(e) => return Err(e),
                        Ok(out) => return Ok(out),
                    }
                }
            }
        }
    }

    /// One `POST /v1/commit` exchange (no client-side retry).
    fn commit_once(
        &self,
        c: &RemoteCommit<'_>,
        client_retries: u64,
    ) -> Result<RemoteCommitOutcome> {
        let mut fields = vec![
            ("branch", Json::str(c.branch)),
            ("table", Json::str(c.table)),
            ("content", Json::str(c.content)),
            ("schema", Json::str(c.schema)),
            ("fingerprint", Json::str(c.fingerprint)),
            ("rows", Json::num(c.rows as f64)),
            ("snap_run_id", Json::str(c.snap_run_id)),
            ("author", Json::str(c.author)),
            ("message", Json::str(c.message)),
        ];
        if let Some(r) = c.run_id {
            fields.push(("run_id", Json::str(r)));
        }
        if let Some(h) = c.expected_head {
            fields.push(("expected_head", Json::str(h)));
        }
        let j = self.call("POST", "/v1/commit", Some(&Json::obj(fields)))?;
        Ok(RemoteCommitOutcome {
            commit: j.get("commit").as_str().unwrap_or_default().to_string(),
            snapshot: j.get("snapshot").as_str().unwrap_or_default().to_string(),
            server_retries: j.get("cas_retries").as_f64().unwrap_or(0.0) as u64,
            client_retries,
        })
    }

    /// Pre-PR-9 shim: one-shot commit returning
    /// `(commit id, snapshot id, server-side cas retries)`.
    #[deprecated(note = "build a RemoteCommit and call RemoteClient::commit")]
    pub fn commit_table(&self, c: &RemoteCommit<'_>) -> Result<(String, String, u64)> {
        let mut once = c.clone();
        once.retry = RemoteRetryPolicy::OneShot;
        let o = self.commit(&once)?;
        Ok((o.commit, o.snapshot, o.server_retries))
    }

    /// Pre-PR-9 shim: informed CAS loop returning
    /// `(commit id, snapshot id, client retries)`. Historically this
    /// re-read the branch head before *every* round; the unified loop
    /// re-reads at most once, then rides the 409's `actual_head`.
    #[deprecated(note = "build a RemoteCommit::retrying and call RemoteClient::commit")]
    pub fn commit_table_retrying(&self, c: &RemoteCommit<'_>) -> Result<(String, String, u64)> {
        let mut informed = c.clone();
        informed.retry = RemoteRetryPolicy::InformedCas;
        let o = self.commit(&informed)?;
        Ok((o.commit, o.snapshot, o.client_retries))
    }

    /// `POST /v1/seed` — seed `raw_table` with synthetic demo data.
    pub fn seed_raw_table(&self, branch: &str, batches: usize, rows: usize) -> Result<()> {
        let body = Json::obj(vec![
            ("branch", Json::str(branch)),
            ("batches", Json::num(batches as f64)),
            ("rows", Json::num(rows as f64)),
        ]);
        self.call("POST", "/v1/seed", Some(&body)).map(|_| ())
    }

    // ------------------------------------------------------------ runs

    /// `POST /v1/runs` — plan + execute a pipeline project text with the
    /// full transactional protocol; blocks until the run is terminal.
    pub fn submit_run(
        &self,
        project: &str,
        branch: &str,
        opts: &RemoteRunOpts,
    ) -> Result<RunState> {
        let mut fields = vec![
            ("project", Json::str(project)),
            ("branch", Json::str(branch)),
            (
                "mode",
                Json::str(if opts.mode_direct { "direct_write" } else { "transactional" }),
            ),
            ("jobs", Json::num(opts.jobs.max(1) as f64)),
        ];
        if opts.no_cache {
            fields.push(("no_cache", Json::Bool(true)));
        }
        if let Some(rid) = &opts.run_id {
            fields.push(("run_id", Json::str(rid)));
        }
        if let Some((point, node)) = &opts.fault {
            fields.push((
                "fault",
                Json::obj(vec![("point", Json::str(point)), ("node", Json::str(node))]),
            ));
        }
        if let Some((table, rows)) = &opts.min_rows {
            fields.push((
                "min_rows",
                Json::obj(vec![
                    ("table", Json::str(table)),
                    ("rows", Json::num(*rows as f64)),
                ]),
            ));
        }
        let j = self.call_traced("POST", "/v1/runs", Some(&Json::obj(fields)), opts.trace.as_ref())?;
        Self::run_from_wire(&j)
    }

    /// `GET /v1/trace/{run_id}` — the journaled run trace. `Ok(None)`
    /// when the server kept no trace (tracing disabled, in-memory lake,
    /// or a run that never reached a terminal state).
    pub fn get_trace(&self, run_id: &str) -> Result<Option<Json>> {
        match self.call("GET", &format!("/v1/trace/{}", urlenc(run_id)), None) {
            Ok(j) => Ok(Some(j)),
            Err(BauplanError::ObjectNotFound(_)) => Ok(None),
            Err(e) => Err(e),
        }
    }

    /// `GET /v1/trace/flight` — the server's live flight-recorder ring
    /// (recent catalog/server spans). Served even on a poisoned server.
    pub fn trace_flight(&self) -> Result<Json> {
        self.call("GET", "/v1/trace/flight", None)
    }

    /// `GET /v1/metrics/json` — counters plus histogram summaries as
    /// canonical JSON (`bauplan metrics --remote`).
    pub fn metrics_json(&self) -> Result<Json> {
        self.call("GET", "/v1/metrics/json", None)
    }

    fn run_from_wire(j: &Json) -> Result<RunState> {
        let run_id = j
            .get("run_id")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("run: missing run_id".into()))?;
        run_state_from_json(run_id, j)
            .ok_or_else(|| BauplanError::Parse("run: unrecognized record shape".into()))
    }

    /// `GET /v1/runs/{id}` — the durable run registry. `Ok(None)` when
    /// the server has no record (mirrors `Client::get_run`).
    pub fn get_run(&self, run_id: &str) -> Result<Option<RunState>> {
        match self.call("GET", &format!("/v1/runs/{}", urlenc(run_id)), None) {
            Ok(j) => Self::run_from_wire(&j).map(Some),
            Err(BauplanError::ObjectNotFound(_)) => Ok(None),
            Err(e) => Err(e),
        }
    }

    // ------------------------------------------------------------ admin

    /// `GET /v1/cache/stats` — run-cache counters (`attached: false`
    /// when the server has no cache).
    pub fn cache_stats(&self) -> Result<Json> {
        self.call("GET", "/v1/cache/stats", None)
    }

    /// `GET /v1/admin/fsck` — the server-side integrity report: the
    /// background auditor's latest full report, or a synchronous
    /// shallow online walk when auditing is disabled.
    pub fn fsck(&self) -> Result<Json> {
        self.call("GET", "/v1/admin/fsck", None)
    }

    /// `POST /v1/admin/checkpoint`; returns the covered journal seq.
    pub fn checkpoint(&self) -> Result<u64> {
        let j = self.call("POST", "/v1/admin/checkpoint", None)?;
        Ok(j.get("seq").as_f64().unwrap_or(0.0) as u64)
    }

    /// `POST /v1/admin/compact`: fold the snapshot delta chain into a
    /// base and retire covered journal segments; returns the covered seq.
    pub fn compact(&self) -> Result<u64> {
        let j = self.call("POST", "/v1/admin/compact", None)?;
        Ok(j.get("seq").as_f64().unwrap_or(0.0) as u64)
    }

    /// `POST /v1/admin/gc`; returns
    /// `(commits, snapshots, objects, bytes)` dropped.
    pub fn gc(&self) -> Result<(usize, usize, usize, u64)> {
        let j = self.call("POST", "/v1/admin/gc", None)?;
        Ok((
            j.get("commits").as_usize().unwrap_or(0),
            j.get("snapshots").as_usize().unwrap_or(0),
            j.get("objects").as_usize().unwrap_or(0),
            j.get("bytes").as_f64().unwrap_or(0.0) as u64,
        ))
    }
}

/// Decode one `application/x-bauplan-frames` body into a [`Table`].
///
/// Wire layout (see `server::http::write_frame_response`): the `BPW1`
/// magic, then frames as `u32 LE length | payload`, closed by a
/// zero-length terminator. Frame 0 is snapshot-metadata JSON; every
/// later frame is one codec object. Anything off — bad magic, a length
/// prefix that overruns the body or is implausibly large, a missing
/// terminator, trailing bytes — is a structured `Parse` error naming
/// what broke, never a panic or a silently short table.
pub fn decode_table_frames(body: &[u8]) -> Result<Table> {
    // Far above any real object, far below usize abuse: a corrupt
    // length prefix fails fast instead of driving a huge allocation.
    const MAX_FRAME: usize = 1 << 28;
    if body.len() < 4 || &body[..4] != FRAME_MAGIC {
        return Err(BauplanError::Parse("frame stream: bad magic".into()));
    }
    let mut rest = &body[4..];
    let mut frames: Vec<&[u8]> = Vec::new();
    loop {
        if rest.len() < 4 {
            return Err(BauplanError::Parse(
                "frame stream: truncated (missing terminator)".into(),
            ));
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
        rest = &rest[4..];
        if len == 0 {
            break;
        }
        if len > MAX_FRAME {
            return Err(BauplanError::Parse(format!(
                "frame stream: implausible frame length {len}"
            )));
        }
        if len > rest.len() {
            return Err(BauplanError::Parse(format!(
                "frame stream: truncated frame ({len} declared, {} left)",
                rest.len()
            )));
        }
        frames.push(&rest[..len]);
        rest = &rest[len..];
    }
    if !rest.is_empty() {
        return Err(BauplanError::Parse(format!(
            "frame stream: {} trailing bytes after terminator",
            rest.len()
        )));
    }
    let Some((meta, objects)) = frames.split_first() else {
        return Err(BauplanError::Parse("frame stream: missing metadata frame".into()));
    };
    let meta_text = std::str::from_utf8(meta)
        .map_err(|_| BauplanError::Parse("frame stream: metadata frame is not utf-8".into()))?;
    let meta = Json::parse(meta_text)?;
    let schema_name = meta.get("schema_name").as_str().unwrap_or("RemoteTable").to_string();
    let mut batches = Vec::with_capacity(objects.len());
    for obj in objects {
        batches.push(crate::storage::codec::decode_batch(obj)?);
    }
    Ok(Table::new(&schema_name, batches))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_body(frames: &[&[u8]]) -> Vec<u8> {
        let mut out = FRAME_MAGIC.to_vec();
        for f in frames {
            out.extend_from_slice(&(f.len() as u32).to_le_bytes());
            out.extend_from_slice(f);
        }
        out.extend_from_slice(&0u32.to_le_bytes());
        out
    }

    fn one_batch() -> crate::storage::Batch {
        crate::storage::Batch::new(
            vec![crate::storage::Column::f32("x", vec![1.0, 2.0])],
            vec![1.0, 1.0],
        )
        .unwrap()
    }

    #[test]
    fn frame_stream_decodes_to_a_table() {
        let obj = crate::storage::codec::encode_batch(&one_batch());
        let meta = br#"{"schema_name":"RawTable"}"#;
        let t = decode_table_frames(&frame_body(&[meta, &obj, &obj])).unwrap();
        assert_eq!(t.schema_name, "RawTable");
        assert_eq!(t.batches.len(), 2);
        assert_eq!(t.row_count(), 4);
    }

    #[test]
    fn frame_stream_rejects_corruption_with_structured_errors() {
        let obj = crate::storage::codec::encode_batch(&one_batch());
        let meta = br#"{"schema_name":"RawTable"}"#;
        let good = frame_body(&[meta, &obj]);

        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        let e = decode_table_frames(&bad).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("bad magic")), "{e}");

        // Truncated mid-frame: chop the tail off the last object frame.
        let e = decode_table_frames(&good[..good.len() - 10]).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("truncated")), "{e}");

        // Corrupt length prefix: implausibly large.
        let mut huge = good.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = decode_table_frames(&huge).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("implausible")), "{e}");

        // Missing terminator.
        let e = decode_table_frames(&good[..good.len() - 4]).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("terminator")), "{e}");

        // Trailing garbage after the terminator.
        let mut trailing = good.clone();
        trailing.push(0xFF);
        let e = decode_table_frames(&trailing).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("trailing")), "{e}");

        // No frames at all — not even metadata.
        let e = decode_table_frames(&frame_body(&[])).unwrap_err();
        assert!(matches!(&e, BauplanError::Parse(m) if m.contains("metadata")), "{e}");

        // A non-batch payload in an object frame fails batch decoding.
        let e = decode_table_frames(&frame_body(&[meta, b"not a batch"])).unwrap_err();
        assert!(matches!(e, BauplanError::Codec(_)), "{e}");
    }

    #[test]
    fn addr_normalizes_scheme_and_slash() {
        assert_eq!(RemoteClient::new("http://127.0.0.1:80/").addr(), "127.0.0.1:80");
        assert_eq!(RemoteClient::new("10.0.0.1:8787").addr(), "10.0.0.1:8787");
    }

    #[test]
    fn remote_commit_defaults_to_one_shot() {
        let c = RemoteCommit::new("main", "t", "x");
        assert_eq!(c.retry, RemoteRetryPolicy::OneShot);
        assert_eq!(c.retrying().retry, RemoteRetryPolicy::InformedCas);
    }

    #[test]
    fn decode_error_prefers_enriched_cas_details() {
        // A PR 9 server sends both key generations; the new ones win.
        let j = Json::parse(
            r#"{"error":{"code":"cas_conflict","message":"m","retryable":true,
                "details":{"reference":"main","expected":"a","found":"b",
                           "branch":"dev","expected_head":"x","actual_head":"y"}}}"#,
        )
        .unwrap();
        match RemoteClient::decode_error(409, &j) {
            BauplanError::CasConflict { reference, expected, found } => {
                assert_eq!((reference.as_str(), expected.as_str()), ("dev", "x"));
                assert_eq!(found, "y");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // No details at all: fields decode empty, never as the message
        // (the informed loop keys its fallback re-read off that).
        let j = Json::parse(r#"{"error":{"code":"cas_conflict","message":"m","retryable":true}}"#)
            .unwrap();
        match RemoteClient::decode_error(409, &j) {
            BauplanError::CasConflict { reference, expected, found } => {
                assert_eq!((reference.as_str(), expected.as_str()), ("", ""));
                assert!(found.is_empty());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn decode_error_reconstructs_variants() {
        let j = Json::parse(
            r#"{"error":{"code":"cas_conflict","message":"m","retryable":true,
                "details":{"reference":"main","expected":"a","found":"b"}}}"#,
        )
        .unwrap();
        match RemoteClient::decode_error(409, &j) {
            BauplanError::CasConflict { reference, expected, found } => {
                assert_eq!((reference.as_str(), expected.as_str()), ("main", "a"));
                assert_eq!(found, "b");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        let j = Json::parse(
            r#"{"error":{"code":"visibility","message":"x","retryable":false,
                "details":{"message":"guarded"}}}"#,
        )
        .unwrap();
        let decoded = RemoteClient::decode_error(403, &j);
        assert!(matches!(decoded, BauplanError::Visibility(m) if m == "guarded"));
        let j = Json::parse(r#"{"error":{"code":"mystery","message":"?","retryable":false}}"#)
            .unwrap();
        assert!(matches!(RemoteClient::decode_error(500, &j), BauplanError::Other(_)));
    }
}
