//! The client API — Listing 6 of the paper, in rust.
//!
//! ```ignore
//! let client = Client::open("artifacts")?;
//! client.seed_raw_table("main", 4, 1500)?;               // demo data
//! let feature = client.create_branch("feature", "main")?;
//! let run = client.run_text(PAPER_PIPELINE_TEXT, &feature)?;
//! assert!(run.is_success());
//! client.merge(&feature, "main")?;
//! // later: reproduce a production issue
//! let prod = client.get_run(&run.run_id).unwrap();
//! let debug = client.create_branch("repro", &prod.start_commit)?;
//! ```
//!
//! One `Client` owns the whole vertically-integrated stack: object
//! store, catalog, PJRT runtime, control plane, worker, run engine.
//! [`remote::RemoteClient`] is its wire twin: the same surface spoken
//! over the API server's JSON protocol (`doc/SERVER.md`).

pub mod remote;

use std::path::Path;
use std::sync::Arc;

use crate::catalog::{Catalog, Commit, CommitRequest, TableDiff, MAIN};
use crate::contracts::schema::SchemaRegistry;
use crate::control_plane::ControlPlane;
use crate::dag::{Plan, PipelineSpec};
use crate::error::Result;
use crate::runs::{FailurePlan, RunMode, RunState, RunStatus, Runner, Verifier};
use crate::runtime::ExecHandle;
use crate::storage::ObjectStore;
use crate::worker::Worker;

/// The vertically-integrated lakehouse handle.
#[derive(Clone)]
pub struct Client {
    pub catalog: Catalog,
    pub runtime: Arc<ExecHandle>,
    pub control_plane: ControlPlane,
    pub runner: Runner,
    pub worker: Worker,
}

impl Client {
    /// Open a lakehouse backed by the AOT artifacts in `artifacts_dir`.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Client> {
        Self::open_with_store(artifacts_dir, Arc::new(ObjectStore::new()))
    }

    /// Open with a custom object store (benches inject latency here).
    pub fn open_with_store(
        artifacts_dir: impl AsRef<Path>,
        store: Arc<ObjectStore>,
    ) -> Result<Client> {
        Self::open_with_catalog(artifacts_dir, Catalog::new(store))
    }

    /// Open against an existing catalog (e.g. one reopened from disk via
    /// [`Catalog::load`]).
    pub fn open_with_catalog(
        artifacts_dir: impl AsRef<Path>,
        catalog: Catalog,
    ) -> Result<Client> {
        // Pool size 1: measured best for both single-stream and 4-way
        // concurrent runs (EXPERIMENTS.md §Perf iteration 4) — the TFRT
        // CPU PJRT client parallelizes internally, so extra executor
        // threads only add queue contention.
        let runtime = Arc::new(ExecHandle::start_pool(artifacts_dir.as_ref(), 1)?);
        Self::from_parts(runtime, catalog)
    }

    /// Open a lakehouse on the simulated compute backend
    /// ([`ExecHandle::sim`]): pure-rust reference kernels, no PJRT and no
    /// artifacts directory. The offline path for end-to-end runs, the
    /// run cache, and CI smoke benches.
    pub fn open_sim() -> Result<Client> {
        Self::open_sim_with_catalog(Catalog::new(Arc::new(ObjectStore::new())))
    }

    /// [`Client::open_sim`] against an existing catalog (e.g. a durable
    /// lake reopened via [`Catalog::recover`](crate::catalog::Catalog::recover)).
    pub fn open_sim_with_catalog(catalog: Catalog) -> Result<Client> {
        Self::from_parts(Arc::new(ExecHandle::sim()), catalog)
    }

    fn from_parts(runtime: Arc<ExecHandle>, catalog: Catalog) -> Result<Client> {
        let registry = SchemaRegistry::with_paper_schemas();
        let worker = Worker::new(runtime.clone(), catalog.clone(), registry)
            .with_lineage_skipping()?;
        let control_plane = ControlPlane::new(runtime.clone());
        let runner = Runner::new(catalog.clone(), worker.clone());
        Ok(Client { catalog, runtime, control_plane, runner, worker })
    }

    /// Set the run engine's wavefront width: up to `jobs` ready DAG
    /// nodes execute concurrently per run (`--jobs` on the CLI; see
    /// `doc/SCHEDULER.md`). The published branch state is identical for
    /// every width — only wall-clock changes.
    pub fn with_jobs(mut self, jobs: usize) -> Client {
        self.runner = self.runner.clone().with_jobs(jobs);
        self
    }

    /// Attach a run cache: memoized nodes publish their verified
    /// snapshot instead of executing (see `doc/RUN_CACHE.md`).
    ///
    /// Re-pins every loaded entry against this catalog and drops the
    /// stale ones (a durable index can outlive the snapshots it names —
    /// e.g. when GC ran between sessions), so an attached cache only
    /// ever serves snapshots the catalog can actually publish.
    pub fn attach_run_cache(&mut self, cache: Arc<crate::cache::RunCache>) {
        for e in cache.entries() {
            if self.catalog.pin_snapshot(&e.snapshot_id).is_err() {
                let _ = cache.remove(&e.key); // stale: nothing to unpin
            }
        }
        self.runner = self.runner.clone().with_cache(cache);
    }

    // ------------------------------------------------------------ branches

    /// `client.create_branch('feature', from_ref='main')`.
    pub fn create_branch(&self, name: &str, from: &str) -> Result<String> {
        self.catalog.create_branch(name, from, false).map(|b| b.name)
    }

    /// Merge `src` into `dst` (a data PR landing).
    pub fn merge(&self, src: &str, dst: &str) -> Result<String> {
        self.catalog.merge(src, dst, false)
    }

    pub fn log(&self, r: &str, limit: usize) -> Result<Vec<Commit>> {
        self.catalog.log(r, limit)
    }

    pub fn diff(&self, from: &str, to: &str) -> Result<Vec<TableDiff>> {
        self.catalog.diff(from, to)
    }

    pub fn tag(&self, name: &str, target: &str) -> Result<String> {
        self.catalog.tag(name, target)
    }

    // ------------------------------------------------------------ runs

    /// Plan + execute a pipeline project text on `branch` with the full
    /// transactional protocol.
    pub fn run_text(&self, text: &str, branch: &str) -> Result<RunState> {
        let plan = self.control_plane.plan_from_text(text)?;
        self.run_plan(&plan, branch, RunMode::Transactional, &FailurePlan::none(), &[])
    }

    /// Plan + execute an in-memory spec.
    pub fn run_spec(&self, spec: &PipelineSpec, branch: &str) -> Result<RunState> {
        let plan = self.control_plane.plan_from_spec(spec)?;
        self.run_plan(&plan, branch, RunMode::Transactional, &FailurePlan::none(), &[])
    }

    /// Full-control run entry point (mode, failure injection, verifiers).
    pub fn run_plan(
        &self,
        plan: &Plan,
        branch: &str,
        mode: RunMode,
        failure: &FailurePlan,
        verifiers: &[Verifier],
    ) -> Result<RunState> {
        self.runner.run(plan, branch, mode, failure, verifiers)
    }

    /// `client.get_run(run_id)` — the reproducibility handle.
    pub fn get_run(&self, run_id: &str) -> Option<RunState> {
        self.runner.get_run(run_id)
    }

    // ------------------------------------------------------------ data

    /// Seed `raw_table` on a branch with synthetic data (the demo's
    /// "ingestion" step).
    pub fn seed_raw_table(
        &self,
        branch: &str,
        batches: usize,
        rows_per_batch: usize,
    ) -> Result<()> {
        self.seed_table(
            branch,
            "raw_table",
            "RawSchema",
            crate::data::raw_table(42, batches, rows_per_batch),
        )
    }

    /// Seed an arbitrary table from in-memory batches.
    pub fn seed_table(
        &self,
        branch: &str,
        name: &str,
        schema: &str,
        batches: Vec<crate::storage::columnar::Batch>,
    ) -> Result<()> {
        let table = crate::storage::columnar::Table::new(schema, batches);
        let snap = self.worker.persist_table(&table, "seed")?;
        let req = CommitRequest::new(branch, name, snap)
            .author("seed")
            .message(&format!("seed {name}"));
        self.catalog.commit(req)?;
        Ok(())
    }
}

/// Convenience for examples/tests: is this run state a success?
impl RunState {
    pub fn is_success(&self) -> bool {
        self.status == RunStatus::Success
    }
}

/// Re-export the default branch name for examples.
pub const PRODUCTION: &str = MAIN;
