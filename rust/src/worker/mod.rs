//! The worker: executes one DAG node's compute via the PJRT runtime and
//! validates outputs against their contract (moment M3) *before* anything
//! is persisted.
//!
//! Data path per node (paper Fig. 1, step 3): read input snapshots from
//! the object store → decode batches → pad to the artifact's static shape
//! → execute AOT executables → assemble the output table → run the fused
//! stats kernel per column and check the contract → only then encode,
//! PUT, and hand a Snapshot back to the run engine for the atomic commit.
//!
//! The lineage optimization of Appendix A is implemented: columns that
//! are pure propagations of already-validated upstream columns skip the
//! stats pass (`Worker::with_lineage_skipping`).

use std::sync::Arc;

use crate::catalog::{Catalog, Commit, Snapshot};
use crate::contracts::checker::{check_runtime, ColumnStats};
use crate::contracts::lineage::LineageGraph;
use crate::contracts::schema::SchemaRegistry;
use crate::contracts::types::LogicalType;
use crate::dag::NodeSpec;
use crate::error::{BauplanError, Result};
use crate::metrics::Metrics;
use crate::runtime::manifest::ScanManifest;
use crate::runtime::{ExecHandle, TensorArg, TensorOut};
use crate::storage::codec::{decode_batch, encode_batch};
use crate::storage::columnar::{Batch, Column, Table};
use crate::trace::{Span, Trace};

/// Executes node compute + M3 validation. Cheap to clone via Arc fields.
#[derive(Clone)]
pub struct Worker {
    runtime: Arc<ExecHandle>,
    catalog: Catalog,
    registry: SchemaRegistry,
    lineage: Option<Arc<LineageGraph>>,
    /// Zone-map predicate pushdown for range-filter scans (on by
    /// default; the pruned-vs-unpruned property test turns it off for
    /// its oracle side).
    pruning: bool,
    pub metrics: Arc<Metrics>,
}

impl Worker {
    pub fn new(runtime: Arc<ExecHandle>, catalog: Catalog, registry: SchemaRegistry) -> Worker {
        Worker {
            runtime,
            catalog,
            registry,
            lineage: None,
            pruning: true,
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Enable/disable zone-map scan pruning (`doc/DATA_PLANE.md`). Both
    /// settings publish byte-identical results — only wall-clock and the
    /// `scan.*` counters differ.
    pub fn with_pruning(mut self, pruning: bool) -> Worker {
        self.pruning = pruning;
        self
    }

    /// Enable the Appendix-A "skip provably-preserved validations"
    /// optimization.
    pub fn with_lineage_skipping(mut self) -> Result<Worker> {
        self.lineage = Some(Arc::new(LineageGraph::from_registry(&self.registry)?));
        Ok(self)
    }

    pub fn runtime(&self) -> &Arc<ExecHandle> {
        &self.runtime
    }

    // ---------------------------------------------------------------- read

    /// Materialize a table from the lake state `commit`.
    pub fn read_table(&self, commit: &Commit, name: &str) -> Result<Table> {
        let snap_id = commit
            .snapshot_of(name)
            .ok_or_else(|| BauplanError::TableNotFound(name.to_string()))?;
        let snap = self.catalog.get_snapshot(snap_id)?;
        let mut batches = Vec::with_capacity(snap.objects.len());
        for key in &snap.objects {
            let bytes = self.catalog.store().get(key)?;
            batches.push(decode_batch(&bytes)?);
        }
        Ok(Table::new(&snap.schema_name, batches))
    }

    // ---------------------------------------------------------------- write

    /// Validate (M3), encode, PUT, and register a snapshot for `table`.
    pub fn persist_table(&self, table: &Table, run_id: &str) -> Result<Snapshot> {
        self.metrics.time("worker.validate", || self.validate_table(table))?;
        let mut objects = Vec::with_capacity(table.batches.len());
        for b in &table.batches {
            let bytes = encode_batch(b);
            objects.push(self.catalog.store().put(bytes));
        }
        let schema = self.registry.get(&table.schema_name)?;
        let snap = Snapshot::new(
            objects,
            &table.schema_name,
            &schema.fingerprint(),
            table.row_count() as u64,
            run_id,
        );
        self.catalog.register_snapshot(snap.clone())?;
        Ok(snap)
    }

    // ---------------------------------------------------------------- M3

    /// Run the fused stats kernel per column and enforce the contract.
    pub fn validate_table(&self, table: &Table) -> Result<()> {
        let schema = self.registry.get(&table.schema_name)?;
        for batch in &table.batches {
            for field in &schema.fields {
                if let Some(l) = &self.lineage {
                    if l.can_skip_validation(&schema.name, &field.name) {
                        self.metrics.incr("worker.validation_skipped", 1);
                        continue;
                    }
                }
                let col = batch.column(&field.name).map_err(|_| {
                    BauplanError::ContractRuntime(format!(
                        "{}: column '{}' missing from physical data",
                        schema.name,
                        field.name
                    ))
                })?;
                // physical type must match the declared logical type
                let expected_physical = physical_type(field.ty.logical);
                if col.data.logical_type() != expected_physical {
                    return Err(BauplanError::ContractRuntime(format!(
                        "{}.{}: physical {:?} does not implement declared {}",
                        schema.name,
                        field.name,
                        col.data.logical_type(),
                        field.ty
                    )));
                }
                let stats = self.column_stats(col, &batch.valid)?;
                check_runtime(&schema.name, &field.name, &field.ty, &stats)?;
                if field.unique {
                    check_unique(&schema.name, &field.name, col, &batch.valid)?;
                }
                self.metrics.incr("worker.columns_validated", 1);
            }
        }
        Ok(())
    }

    /// Fused single-pass stats via the AOT kernel (validate_n/validate_g
    /// by physical width; any other width falls back to a rust loop —
    /// same semantics, used for odd-sized test batches).
    fn column_stats(&self, col: &Column, valid: &[f32]) -> Result<ColumnStats> {
        let null_count = col
            .nulls
            .as_ref()
            .map(|m| {
                m.iter()
                    .zip(valid)
                    .filter(|(&n, &v)| n >= 1.0 && v > 0.0)
                    .count() as f64
            })
            .unwrap_or(0.0);
        // include = valid && not-null (nulls checked separately above)
        let include: Vec<f32> = match &col.nulls {
            Some(m) => valid
                .iter()
                .zip(m)
                .map(|(&v, &n)| if v > 0.0 && n < 1.0 { 1.0 } else { 0.0 })
                .collect(),
            None => valid.to_vec(),
        };
        let x = col.data.to_f32_vec();
        let artifact = match x.len() {
            n if n == self.runtime.manifest().n => Some("validate_n"),
            g if g == self.runtime.manifest().g => Some("validate_g"),
            _ => None,
        };
        let out = match artifact {
            Some(name) => {
                let res = self.metrics.time("worker.stats_kernel", || {
                    self.runtime
                        .execute(name, &[TensorArg::F32(x), TensorArg::F32(include)])
                })?;
                match &res[0] {
                    TensorOut::F32(v) => v.clone(),
                    _ => return Err(BauplanError::Pjrt("stats output not f32".into())),
                }
            }
            None => rust_stats(&x, &include),
        };
        ColumnStats::from_kernel(&out, null_count)
    }

    // ---------------------------------------------------------------- ops

    /// Execute one node: read inputs from `state`, run the op, return the
    /// (not yet persisted) output table.
    pub fn execute_node(&self, node: &NodeSpec, state: &Commit) -> Result<Table> {
        self.execute_node_traced(node, state, &Trace::disabled().span("execute"))
    }

    /// [`Worker::execute_node`] under a live span: range-filter scans get
    /// a `scan:<table>` child span carrying batch/pruning attrs.
    pub fn execute_node_traced(
        &self,
        node: &NodeSpec,
        state: &Commit,
        span: &Span,
    ) -> Result<Table> {
        if matches!(node.op.as_str(), "transform_n" | "transform_g") {
            // Lazy scan path: fetch objects + zone maps, decode only the
            // batches the predicate can possibly match.
            let (t_name, _) = node
                .inputs
                .first()
                .ok_or_else(|| BauplanError::Dag("transform node has no input".into()))?;
            let scan = self.scan_manifest(state, t_name)?;
            let batches = self.metrics.time("worker.compute", || {
                self.op_transform_scan(&scan, &node.params, &node.op, span)
            })?;
            return Ok(Table::new(&node.out_schema, batches));
        }
        let inputs: Vec<Table> = node
            .inputs
            .iter()
            .map(|(t, _)| self.read_table(state, t))
            .collect::<Result<_>>()?;
        let batches = self.metrics.time("worker.compute", || match node.op.as_str() {
            "parent" => self.op_parent(&inputs[0]),
            "child" => self.op_child(&inputs[0], &node.params),
            "grand_child" => self.op_grand_child(&inputs[0], &node.params),
            "family_friend" => self.op_family_friend(&inputs[0], &inputs[1], &node.params),
            other => Err(BauplanError::Dag(format!("unknown op '{other}'"))),
        })?;
        Ok(Table::new(&node.out_schema, batches))
    }

    /// Resolve `name` in `commit` and build the scan-side manifest
    /// (object handles + zone maps, no row decoding).
    fn scan_manifest(&self, commit: &Commit, name: &str) -> Result<ScanManifest> {
        let snap_id = commit
            .snapshot_of(name)
            .ok_or_else(|| BauplanError::TableNotFound(name.to_string()))?;
        let snap = self.catalog.get_snapshot(snap_id)?;
        ScanManifest::build(name, self.catalog.store(), &snap.objects)
    }

    /// parent: grouped SUM(col3) + MAX(col2) BY col1, combined across
    /// batches in rust (partials add / max — exactly the merge the MXU
    /// partials use inside the kernel, lifted one level).
    ///
    /// Per-batch kernels are independent, so they pipeline through the
    /// non-blocking [`ExecHandle::submit`] API with a bounded in-flight
    /// window (pool width + 1 — enough to keep every executor busy
    /// without buffering the whole input's tensor copies at once).
    /// Completions drain in batch order, so the float merge below is
    /// bit-deterministic regardless of which kernel finishes first.
    fn op_parent(&self, input: &Table) -> Result<Vec<Batch>> {
        let n = self.runtime.manifest().n;
        let g = self.runtime.manifest().g;
        let window = self.runtime.workers().max(1) + 1;
        let mut sums = vec![0f32; g];
        let mut counts = vec![0f32; g];
        let mut rep2 = vec![f32::NEG_INFINITY; g];
        let mut merge = |out: Vec<TensorOut>| -> Result<()> {
            let (_k, c2, s, v) = (
                out[0].as_i32()?,
                out[1].as_f32()?.to_vec(),
                out[2].as_f32()?.to_vec(),
                out[3].as_f32()?.to_vec(),
            );
            for i in 0..g {
                sums[i] += s[i];
                if v[i] > 0.0 {
                    rep2[i] = rep2[i].max(c2[i]);
                    counts[i] += 1.0;
                }
            }
            Ok(())
        };
        let mut pending = std::collections::VecDeque::with_capacity(window);
        for b in &input.batches {
            if pending.len() >= window {
                let completion: crate::runtime::ExecCompletion =
                    pending.pop_front().expect("non-empty window");
                merge(completion.wait()?)?;
            }
            let b = b.padded_to(n)?;
            let col1 = TensorArg::I32(b.column("col1")?.data.as_i32()?.to_vec());
            let col2 = TensorArg::F32(b.column("col2")?.data.as_f32()?.to_vec());
            let col3 = TensorArg::F32(b.column("col3")?.data.as_f32()?.to_vec());
            let valid = TensorArg::F32(b.valid.clone());
            pending.push_back(self.runtime.submit("parent", &[col1, col2, col3, valid])?);
        }
        for completion in pending {
            merge(completion.wait()?)?;
        }
        let valid: Vec<f32> = counts.iter().map(|&c| if c > 0.0 { 1.0 } else { 0.0 }).collect();
        let rep2: Vec<f32> = rep2
            .iter()
            .zip(&valid)
            .map(|(&r, &v)| if v > 0.0 { r } else { 0.0 })
            .collect();
        Ok(vec![Batch::new(
            vec![
                Column::i32("col1", (0..g as i32).collect()),
                Column::f32("col2", rep2),
                Column::f32("_S", sums),
            ],
            valid,
        )?])
    }

    /// child: fresh col4 (affine of _S) + nullable col5.
    fn op_child(&self, input: &Table, params: &[f32]) -> Result<Vec<Batch>> {
        let g = self.runtime.manifest().g;
        let params = normalize_params(params);
        let mut out_batches = Vec::new();
        for b in &input.batches {
            let b = b.padded_to(g)?;
            let out = self.runtime.execute(
                "child",
                &[
                    TensorArg::F32(b.column("col2")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(b.column("_S")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(b.valid.clone()),
                    TensorArg::F32(params.clone()),
                ],
            )?;
            out_batches.push(Batch::new(
                vec![
                    Column::f32("col2", out[0].as_f32()?.to_vec()),
                    Column::f32("col4", out[1].as_f32()?.to_vec()),
                    Column::f32("col5", out[2].as_f32()?.to_vec())
                        .with_nulls(out[3].as_f32()?.to_vec()),
                ],
                out[4].as_f32()?.to_vec(),
            )?);
        }
        Ok(out_batches)
    }

    /// grand_child: explicit narrowing cast float -> int.
    fn op_grand_child(&self, input: &Table, params: &[f32]) -> Result<Vec<Batch>> {
        let g = self.runtime.manifest().g;
        let params = normalize_params(params);
        let mut out_batches = Vec::new();
        for b in &input.batches {
            let b = b.padded_to(g)?;
            let out = self.runtime.execute(
                "grand_child",
                &[
                    TensorArg::F32(b.column("col2")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(b.column("col4")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(b.valid.clone()),
                    TensorArg::F32(params.clone()),
                ],
            )?;
            out_batches.push(Batch::new(
                vec![
                    Column::f32("col2", out[0].as_f32()?.to_vec()),
                    Column::i32("col4", out[1].as_i32()?.to_vec()),
                ],
                out[2].as_f32()?.to_vec(),
            )?);
        }
        Ok(out_batches)
    }

    /// family_friend: join child (tall view, synthesized row keys) against
    /// grand (grouped), filter NOT NULL + |Δcol4| < eps.
    fn op_family_friend(
        &self,
        child: &Table,
        grand: &Table,
        params: &[f32],
    ) -> Result<Vec<Batch>> {
        let n = self.runtime.manifest().n;
        let g = self.runtime.manifest().g;
        let params = normalize_params(params);
        let gb = grand.batches.first().ok_or_else(|| {
            BauplanError::Dag("family_friend: grand table empty".into())
        })?;
        let gb = gb.padded_to(g)?;
        let g_key: Vec<i32> = (0..g as i32).collect();
        let g_col4i = gb.column("col4")?.data.as_i32()?.to_vec();
        let g_valid = gb.valid.clone();

        let mut out_batches = Vec::new();
        for b in &child.batches {
            let rows = b.width();
            let b = b.padded_to(n)?;
            // synthesized join key: row index within the (grouped) child
            let c_key: Vec<i32> =
                (0..n as i32).map(|i| if (i as usize) < rows { i } else { -1 }).collect();
            let col5 = b.column("col5")?;
            let nulls = col5
                .nulls
                .clone()
                .unwrap_or_else(|| vec![0.0; n]);
            let out = self.runtime.execute(
                "family_friend",
                &[
                    TensorArg::I32(c_key),
                    TensorArg::F32(b.column("col2")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(b.column("col4")?.data.as_f32()?.to_vec()),
                    TensorArg::F32(col5.data.as_f32()?.to_vec()),
                    TensorArg::F32(nulls),
                    TensorArg::F32(b.valid.clone()),
                    TensorArg::I32(g_key.clone()),
                    TensorArg::I32(g_col4i.clone()),
                    TensorArg::F32(g_valid.clone()),
                    TensorArg::F32(params.clone()),
                ],
            )?;
            let keep = out[3].as_f32()?.to_vec();
            out_batches.push(Batch::new(
                vec![
                    Column::f32("col2", out[0].as_f32()?.to_vec()),
                    Column::i32(
                        "col4",
                        out[1].as_f32()?.iter().map(|&x| x as i32).collect(),
                    ),
                    Column::f32("col5", out[2].as_f32()?.to_vec()),
                ],
                keep,
            )?);
        }
        Ok(out_batches)
    }

    /// Generic fused filter/project/cast over every batch of a scan,
    /// with zone-map predicate pushdown.
    ///
    /// The kernel's `[lo, hi]` range filter *zeroes* filtered rows
    /// instead of removing them, so a batch whose zone map proves no row
    /// can match produces exactly the all-zero output — synthesized here
    /// without decoding the object or dispatching the kernel. Pruning is
    /// byte-invisible (the property test in `tests/properties.rs` and
    /// the simulator oracles both pin this).
    fn op_transform_scan(
        &self,
        scan: &ScanManifest,
        params: &[f32],
        op: &str,
        parent: &Span,
    ) -> Result<Vec<Batch>> {
        let width = if op == "transform_n" {
            self.runtime.manifest().n
        } else {
            self.runtime.manifest().g
        };
        let params = normalize_params(params);
        let (lo, hi) = (params[0], params[1]);
        let span = parent.child(&format!("scan:{}", scan.table));
        let mut pruned = 0u64;
        let mut rows_scanned = 0u64;
        let mut out_batches = Vec::with_capacity(scan.entries.len());
        for e in &scan.entries {
            // Only prune batches that would have padded cleanly — a
            // too-wide batch must keep erroring exactly like the
            // unpruned path does.
            let skip = self.pruning
                && e.stats
                    .as_ref()
                    .map(|s| s.n_rows as usize <= width && !s.can_match_range(0, lo, hi))
                    .unwrap_or(false);
            if skip {
                pruned += 1;
                out_batches.push(Batch::new(
                    vec![
                        Column::f32("y", vec![0.0; width]),
                        Column::i32("y_int", vec![0; width]),
                    ],
                    vec![0.0; width],
                )?);
                continue;
            }
            let b = decode_batch(&e.data)?.padded_to(width)?;
            rows_scanned += width as u64;
            let first = &b.columns[0];
            let out = self.runtime.execute(
                op,
                &[
                    TensorArg::F32(first.data.to_f32_vec()),
                    TensorArg::F32(b.valid.clone()),
                    TensorArg::F32(params.clone()),
                ],
            )?;
            out_batches.push(Batch::new(
                vec![
                    Column::f32("y", out[0].as_f32()?.to_vec()),
                    Column::i32("y_int", out[1].as_i32()?.to_vec()),
                ],
                out[2].as_f32()?.to_vec(),
            )?);
        }
        self.metrics.incr("scan.batches_pruned", pruned);
        self.metrics.incr("scan.rows_scanned", rows_scanned);
        if span.is_live() {
            span.attr_str("table", &scan.table);
            span.attr_u64("batches", scan.entries.len() as u64);
            span.attr_u64("pruned", pruned);
            span.attr_u64("rows_scanned", rows_scanned);
        }
        Ok(out_batches)
    }
}

/// Pad params to the fixed [4] the artifacts expect.
fn normalize_params(p: &[f32]) -> Vec<f32> {
    let mut v = p.to_vec();
    v.resize(4, 0.0);
    v
}

/// Declared logical type -> physical column representation.
fn physical_type(t: LogicalType) -> LogicalType {
    match t {
        LogicalType::Int => LogicalType::Int,
        LogicalType::Str => LogicalType::Int, // dictionary codes
        _ => LogicalType::Float,              // float/timestamp/bool as f32
    }
}

/// Uniqueness check over valid, non-null rows (bit-exact comparison).
fn check_unique(
    schema: &str,
    field: &str,
    col: &Column,
    valid: &[f32],
) -> Result<()> {
    let mut seen = std::collections::HashSet::new();
    for i in 0..valid.len() {
        if valid[i] <= 0.0 {
            continue;
        }
        if let Some(nulls) = &col.nulls {
            if nulls[i] >= 1.0 {
                continue;
            }
        }
        let key = match &col.data {
            crate::storage::columnar::ColumnData::F32(v) => v[i].to_bits() as u64,
            crate::storage::columnar::ColumnData::I32(v) => v[i] as u64 | (1 << 63),
        };
        if !seen.insert(key) {
            return Err(BauplanError::ContractRuntime(format!(
                "{schema}.{field}: duplicate value at row {i} violates [unique]")));
        }
    }
    Ok(())
}

/// Pure-rust fallback stats (same layout as the kernel's f32[8]).
fn rust_stats(x: &[f32], include: &[f32]) -> Vec<f32> {
    let mut cnt = 0.0;
    let mut exc = 0.0;
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    let mut nans = 0.0;
    let mut sum = 0.0;
    for (&v, &inc) in x.iter().zip(include) {
        if inc > 0.0 {
            cnt += 1.0;
            if v.is_nan() {
                nans += 1.0;
            } else {
                mn = mn.min(v);
                mx = mx.max(v);
                sum += v;
            }
        } else {
            exc += 1.0;
        }
    }
    vec![cnt, exc, mn, mx, nans, sum, 0.0, 0.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_stats_matches_semantics() {
        let x = vec![1.0, f32::NAN, 3.0, 100.0];
        let inc = vec![1.0, 1.0, 1.0, 0.0];
        let s = rust_stats(&x, &inc);
        assert_eq!(s[0], 3.0); // included
        assert_eq!(s[1], 1.0); // excluded
        assert_eq!(s[2], 1.0); // min skips NaN and excluded
        assert_eq!(s[3], 3.0);
        assert_eq!(s[4], 1.0); // NaN counted
        assert_eq!(s[5], 4.0);
    }

    #[test]
    fn params_normalize_to_four() {
        assert_eq!(normalize_params(&[1.0]), vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(normalize_params(&[1., 2., 3., 4.]), vec![1., 2., 3., 4.]);
    }

    #[test]
    fn physical_mapping() {
        assert_eq!(physical_type(LogicalType::Str), LogicalType::Int);
        assert_eq!(physical_type(LogicalType::Timestamp), LogicalType::Float);
    }
}

#[cfg(test)]
mod unique_tests {
    use super::*;

    #[test]
    fn unique_detects_duplicates_ignores_invalid_and_null() {
        let col = Column::f32("k", vec![1.0, 2.0, 1.0, 1.0])
            .with_nulls(vec![0.0, 0.0, 1.0, 0.0]);
        // row2 duplicate is NULL -> ignored; row3 duplicate is invalid
        assert!(check_unique("S", "k", &col, &[1.0, 1.0, 1.0, 0.0]).is_ok());
        // making row3 valid exposes the duplicate
        let err = check_unique("S", "k", &col, &[1.0, 1.0, 1.0, 1.0]).unwrap_err();
        assert_eq!(err.contract_moment(), Some(3));
        assert!(err.to_string().contains("[unique]"));
    }

    #[test]
    fn unique_i32_columns() {
        let col = Column::i32("k", vec![5, 6, 5]);
        assert!(check_unique("S", "k", &col, &[1.0, 1.0, 1.0]).is_err());
        assert!(check_unique("S", "k", &col, &[1.0, 1.0, 0.0]).is_ok());
    }
}
