//! Delta-debugging shrinker: reduce a failing trace to a (locally)
//! minimal one that still triggers the *same kind* of violation.
//!
//! Classic ddmin over the op list: try removing ever-smaller chunks
//! (halves, quarters, …, single ops) and keep any removal after which
//! replay still reports a violation of the target kind. Replay skips
//! inapplicable ops deterministically (see `sim::driver`), so removing a
//! `BeginRun` simply turns the orphaned `StepRun`s into no-ops instead
//! of invalidating the candidate — which is what makes plain list-level
//! delta debugging converge on op traces.
//!
//! The pinned Fig. 3 / Fig. 4 counterexamples shrink to ≤ 8 ops this
//! way (CI asserts it): `BeginRun(direct) → StepRun` for Fig. 3,
//! `BeginRun(txn) → StepRun → FailRun → AgentFork(aborted) → AgentMerge`
//! for Fig. 4.

use crate::sim::driver::{replay, SimConfig};
use crate::sim::generator::SimOp;
use crate::sim::oracles::ViolationKind;

/// Hard cap on replays per shrink — each replay builds a throwaway lake,
/// so a runaway candidate set must not stall CI. Minimality is
/// best-effort past the cap (never hit by the generator's trace sizes).
const MAX_REPLAYS: usize = 2_000;

/// Shrink `trace` (which must produce a violation of `kind` under
/// `config`) to a locally minimal trace with the same verdict kind.
/// Returns the reduced trace; on any replay error the best trace so far
/// is returned.
pub fn shrink(trace: &[SimOp], config: &SimConfig, kind: ViolationKind) -> Vec<SimOp> {
    let mut current: Vec<SimOp> = trace.to_vec();
    let mut budget = MAX_REPLAYS;
    let still_fails = |candidate: &[SimOp], budget: &mut usize| -> bool {
        if *budget == 0 {
            return false;
        }
        *budget -= 1;
        match replay(candidate, config) {
            Ok(report) => report.violation.map(|v| v.kind) == Some(kind),
            Err(_) => false,
        }
    };

    let mut chunk = ((current.len() + 1) / 2).max(1);
    loop {
        let mut removed_any = false;
        let mut start = 0;
        while start < current.len() {
            let end = (start + chunk).min(current.len());
            let mut candidate = current.clone();
            candidate.drain(start..end);
            if still_fails(&candidate, &mut budget) {
                current = candidate;
                removed_any = true;
                // re-test the same window position against the shorter list
            } else {
                start = end;
            }
        }
        if chunk == 1 {
            if !removed_any || budget == 0 {
                break;
            }
            // a pass at granularity 1 removed something: run one more
            // pass to reach a local fixpoint
        } else {
            chunk = (chunk / 2).max(1);
        }
    }
    current
}
