//! The conformance driver: execute one op trace through the model and
//! the real stack in lockstep, checking the oracles after every op.
//!
//! The driver owns a throwaway durable lake directory (journal + disk
//! object store + run-cache index), a full [`Client`] stack on the sim
//! compute backend, and the tracked [`ModelState`]. Each [`SimOp`] maps
//! to:
//!
//! - one or more *real* catalog/runner operations, and
//! - the [`Op`](crate::model::Op)s that mirror them in the model (via
//!   [`ModelState::apply`]).
//!
//! Fine-grained ops are *predictive*: the driver constructs the
//! snapshots itself, so the model fully predicts the real state and the
//! refinement oracle compares the two exactly. [`SimOp::FullRun`] ops
//! are *observed*: the real `Runner` executes end to end (jobs>1, cache,
//! fault injection) and the driver derives the model mirror from the
//! run's first-parent commit history — the oracles (main consistency,
//! branch lifecycle, recovery idempotence) still bind the observed
//! outcome.
//!
//! Inapplicable ops (stale run indices after shrinking, mutations while
//! the journal is dead) are *skipped* deterministically on both sides,
//! which is what makes delta-debugged sub-traces replayable.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::cache::{RunCache, CACHE_INDEX_FILE};
use crate::catalog::{
    BranchState, Catalog, Commit, CommitRequest, JournalConfig, Snapshot, SyncPolicy, MAIN,
    TXN_PREFIX,
};
use crate::client::remote::{RemoteClient, RemoteCommit, RemoteRetryPolicy, RemoteRunOpts};
use crate::client::Client;
use crate::server::{Server, ServerConfig, ServerHandle};
use crate::dag::{PipelineSpec, Plan};
use crate::error::{BauplanError, Result};
use crate::model::state::{BranchPhase, ModelState, Op as MOp, RunPhase, Snap};
use crate::runs::failure::FailurePoint;
use crate::runs::{FailurePlan, RunMode, RunStatus, Verifier};
use crate::sim::generator::{self, AgentSource, GenParams, RunFault, SimOp};
use crate::sim::oracles::{
    check_main_consistent, check_refinement, check_trace_complete, Projection, Violation,
    ViolationKind,
};
use crate::sim::{PLAN_LEN, PLAN_TABLES};
use crate::testing::Rng;
use crate::util::json::Json;

/// Journal fsync policy for simulation lakes: batched, because a single
/// CI sweep replays tens of thousands of mutations and the simulated
/// crashes never lose the OS page cache.
const SIM_SYNC: SyncPolicy = SyncPolicy::Batch(256);

/// Journal tuning for simulation lakes: batched sync (above) plus tiny
/// segments, so rotation and compaction — both the scheduled
/// [`SimOp::RotateSegment`]/[`SimOp::Compact`] ops and the automatic
/// size-triggered rotations — actually happen inside a 40-op trace.
fn sim_journal_config() -> JournalConfig {
    JournalConfig {
        sync: SIM_SYNC,
        segment_bytes: 2048,
        compact_after_deltas: 8,
        sync_latency_micros: 0,
    }
}

/// Deliberately tiny run-cache budget so LRU evictions actually happen
/// inside a trace.
const CACHE_BUDGET: u64 = 16 * 1024;

/// Model scope guards: `ModelState` indexes commits and runs with `u8`.
const MAX_MODEL_COMMITS: usize = 200;
const MAX_MODEL_RUNS: usize = 16;

static SIM_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// RNG seed (drives trace generation only; replay ignores it).
    pub seed: u64,
    /// Approximate trace length to generate.
    pub ops: usize,
    /// `true` = the paper's stack (transactional protocol + visibility
    /// guardrail); `false` = today's lakehouse (direct writes possible,
    /// aborted branches forkable) — the counterexample mode.
    pub guardrail: bool,
    /// Drive the real stack through [`RemoteClient`] against an
    /// in-process API server over a real TCP loopback connection,
    /// instead of direct in-process calls (`--remote-loopback`). The
    /// oracles are unchanged — the same refinement/consistency/recovery
    /// checks must hold for traffic that crossed the wire.
    pub remote_loopback: bool,
    /// Interleave real concurrent-committer bursts with the trace
    /// (`--concurrent-committers`): every few ops, two OS threads
    /// chain strict-CAS commits on disjoint scratch branches while the
    /// schedule is paused. Per-branch OCC promises disjoint branches
    /// never contend; any `CasConflict` (or a head that missed a
    /// commit) fires the [`ViolationKind::OccDisjointConflict`] oracle.
    pub concurrent_committers: bool,
}

impl SimConfig {
    /// Guardrails-on config with the default trace length.
    pub fn new(seed: u64) -> SimConfig {
        SimConfig {
            seed,
            ops: 40,
            guardrail: true,
            remote_loopback: false,
            concurrent_committers: false,
        }
    }

    /// The counterexample mode ([`SimConfig::guardrail`] = false).
    pub fn no_guardrail(seed: u64) -> SimConfig {
        SimConfig { guardrail: false, ..SimConfig::new(seed) }
    }

    /// Loopback mode ([`SimConfig::remote_loopback`] = true): every
    /// driver op rides HTTP over a real socket.
    pub fn loopback(seed: u64) -> SimConfig {
        SimConfig { remote_loopback: true, ..SimConfig::new(seed) }
    }

    /// Concurrent-committers mode
    /// ([`SimConfig::concurrent_committers`] = true).
    pub fn concurrent(seed: u64) -> SimConfig {
        SimConfig { concurrent_committers: true, ..SimConfig::new(seed) }
    }
}

/// Outcome of one simulation.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Seed the trace came from (0 for file-replayed traces).
    pub seed: u64,
    /// Guardrail setting the trace ran under.
    pub guardrail: bool,
    /// The executed trace.
    pub trace: Vec<SimOp>,
    /// Ops that took effect.
    pub applied: usize,
    /// Ops skipped as inapplicable (shrunken traces, dead journal).
    pub skipped: usize,
    /// Forks of aborted branches the guardrail refused — proof the
    /// visibility oracle was actually exercised.
    pub guardrail_refusals: u64,
    /// First violation found, if any (the trace stops there).
    pub violation: Option<Violation>,
    /// Canonical JSON of the final model projection — equal across
    /// schedules that publish the same states (the jobs=1 vs jobs=4
    /// property keys on this).
    pub model_digest: String,
}

impl SimReport {
    /// Verdict as canonical JSON (determinism checks compare this
    /// byte-for-byte).
    pub fn verdict_json(&self) -> Json {
        match &self.violation {
            Some(v) => v.to_json(),
            None => Json::obj(vec![
                ("verdict", Json::str("ok")),
                ("applied", Json::num(self.applied as f64)),
                ("skipped", Json::num(self.skipped as f64)),
                ("guardrail_refusals", Json::num(self.guardrail_refusals as f64)),
            ]),
        }
    }

    /// Full machine-readable report: config, trace, and verdict.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("guardrail", Json::Bool(self.guardrail)),
            ("ops", generator::trace_to_json(&self.trace)),
            ("verdict", self.verdict_json()),
        ])
    }
}

/// Generate the seed's trace and run it. Deterministic: the same seed
/// and config produce the same trace and the same verdict on every
/// invocation.
pub fn simulate(config: &SimConfig) -> Result<SimReport> {
    let mut rng = Rng::new(config.seed);
    let trace =
        generator::generate(&mut rng, &GenParams { ops: config.ops, guardrail: config.guardrail });
    replay(&trace, config)
}

/// Run one explicit trace (the `--ops-file` / shrinker entry point).
pub fn replay(trace: &[SimOp], config: &SimConfig) -> Result<SimReport> {
    let mut driver = Driver::new(config.guardrail, config.remote_loopback)?;
    let mut applied = 0usize;
    let mut skipped = 0usize;
    let mut violation: Option<Violation> = None;

    for (i, op) in trace.iter().enumerate() {
        if driver.model.commits.len() > MAX_MODEL_COMMITS {
            skipped += trace.len() - i;
            break;
        }
        match driver.apply(op)? {
            Outcome::Applied => applied += 1,
            Outcome::Skipped => skipped += 1,
            Outcome::Violated { kind, detail } => {
                violation = Some(Violation { kind, at_op: i, detail });
                break;
            }
        }
        if config.concurrent_committers && !driver.journal_dead && i % 8 == 7 {
            if let Some((kind, detail)) = driver.concurrent_burst(i as u64)? {
                violation = Some(Violation { kind, at_op: i, detail });
                break;
            }
        }
        if let Some(v) = driver.check_oracles(i, Some(op)) {
            violation = Some(v);
            break;
        }
    }

    if violation.is_none() {
        // end-of-trace crash: every trace finishes with the recovery
        // idempotence + refinement check, whatever the generator emitted
        let at = trace.len();
        match driver.crash_recover()? {
            Some((kind, detail)) => violation = Some(Violation { kind, at_op: at, detail }),
            None => violation = driver.check_oracles(at, None),
        }
    }

    Ok(SimReport {
        seed: config.seed,
        guardrail: config.guardrail,
        trace: trace.to_vec(),
        applied,
        skipped,
        guardrail_refusals: driver.guardrail_refusals,
        violation,
        model_digest: driver.model_digest(),
    })
}

/// How one op landed.
enum Outcome {
    Applied,
    Skipped,
    Violated { kind: ViolationKind, detail: String },
}

/// Real-side context of one model run.
struct RunCtx {
    run_id: String,
    transactional: bool,
    /// `txn/<run_id>` or `main`.
    exec_branch: String,
    /// Model branch index of the txn branch (0 for direct runs).
    model_branch: u8,
    /// Fine-grained runs are driven op by op; `FullRun` contexts are
    /// terminal the moment they are created.
    fine_grained: bool,
}

struct AgentCtx {
    model_branch: u8,
    from_aborted: bool,
}

/// How driver ops reach the stack: direct in-process calls, or HTTP
/// over a real TCP loopback connection to an in-process [`Server`]
/// hosting the same catalog — the exact bytes a remote tenant would
/// send. Fault injection (journal crashes, process kills) and the
/// oracles' *reads* stay in-process in both modes: they are the test
/// harness poking at / observing the server's internals, not API
/// traffic.
enum Wire {
    Local,
    Loopback {
        /// Kept alive for its Drop (shutdown + thread join).
        _server: ServerHandle,
        remote: RemoteClient,
    },
}

struct Driver {
    dir: PathBuf,
    client: Client,
    wire: Wire,
    /// Rebuild the wire as loopback after every crash/restart?
    loopback: bool,
    plan: Plan,
    model: ModelState,
    runs: Vec<RunCtx>,
    /// Model snap `(run, step)` → real snapshot id (the refinement
    /// bijection; learned from observation for `FullRun` steps).
    snaps: BTreeMap<Snap, String>,
    agent: Option<AgentCtx>,
    guardrail: bool,
    /// Set while the journal is failing every append (between a
    /// `JournalCrash` and the next `CrashRecover`).
    journal_dead: bool,
    /// Did the last applied `AgentMerge` carry aborted-branch content?
    last_agent_merge_from_aborted: bool,
    guardrail_refusals: u64,
    env_seq: u64,
    /// Canonical trace JSON per successful run (`run_id` → bytes), as
    /// first observed; recovery must reproduce each byte-identically.
    traced_runs: Vec<(String, String)>,
}

impl Drop for Driver {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

impl Driver {
    fn new(guardrail: bool, loopback: bool) -> Result<Driver> {
        let dir = std::env::temp_dir().join(format!(
            "bpl_sim_{}_{}",
            std::process::id(),
            SIM_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let catalog = Catalog::open_durable_cfg(&dir, sim_journal_config())?;
        let mut client = Client::open_sim_with_catalog(catalog)?;
        let cache = RunCache::open(&dir.join(CACHE_INDEX_FILE), CACHE_BUDGET)?;
        client.attach_run_cache(Arc::new(cache));
        client.seed_raw_table(MAIN, 2, 200)?;
        let plan = PipelineSpec::paper_pipeline().plan()?;
        debug_assert_eq!(plan.outputs(), PLAN_TABLES.to_vec());
        let mut driver = Driver {
            dir,
            client,
            wire: Wire::Local,
            loopback,
            plan,
            model: ModelState::init(),
            runs: Vec::new(),
            snaps: BTreeMap::new(),
            agent: None,
            guardrail,
            journal_dead: false,
            last_agent_merge_from_aborted: false,
            guardrail_refusals: 0,
            env_seq: 0,
            traced_runs: Vec::new(),
        };
        if loopback {
            driver.start_loopback()?;
        }
        Ok(driver)
    }

    /// Start an API server on the current client stack (ephemeral
    /// loopback port) and point a fresh [`RemoteClient`] at it. The
    /// server's catalog IS the driver's catalog (`Catalog` is an `Arc`
    /// handle), so oracle reads keep observing the served state.
    fn start_loopback(&mut self) -> Result<()> {
        let server = Server::start(self.client.clone(), "127.0.0.1:0", ServerConfig::default())?;
        let remote = RemoteClient::new(&server.base_url());
        self.wire = Wire::Loopback { _server: server, remote };
        Ok(())
    }

    fn remote(&self) -> Option<&RemoteClient> {
        match &self.wire {
            Wire::Loopback { remote, .. } => Some(remote),
            Wire::Local => None,
        }
    }

    fn catalog(&self) -> &Catalog {
        &self.client.catalog
    }

    // -------------------------------------------------------- wire dispatch
    //
    // Every *operation* a tenant could issue goes through these: direct
    // catalog calls in local mode, `RemoteClient` HTTP in loopback mode.
    // The remote error decoding reconstructs the same `BauplanError`
    // variants, so the op handlers' match arms are mode-agnostic.

    fn w_create_branch(&self, name: &str, from: &str, allow_aborted: bool) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.create_branch(name, from, allow_aborted).map(|_| ()),
            None => self.catalog().create_branch(name, from, allow_aborted).map(|_| ()),
        }
    }

    fn w_create_txn_branch(&self, target: &str, run_id: &str) -> Result<String> {
        match self.remote() {
            Some(rc) => rc.create_txn_branch(target, run_id).map(|b| b.name),
            None => self.catalog().create_txn_branch(target, run_id).map(|b| b.name),
        }
    }

    fn w_delete_branch(&self, name: &str) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.delete_branch(name),
            None => self.catalog().delete_branch(name),
        }
    }

    fn w_set_branch_state(&self, name: &str, state: BranchState) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.set_branch_state(name, state),
            None => self.catalog().set_branch_state(name, state),
        }
    }

    fn w_merge(&self, src: &str, dst: &str, allow_aborted: bool) -> Result<String> {
        match self.remote() {
            Some(rc) => rc.merge(src, dst, allow_aborted),
            None => self.catalog().merge(src, dst, allow_aborted),
        }
    }

    fn w_rebase(&self, branch: &str, onto: &str) -> Result<String> {
        match self.remote() {
            Some(rc) => rc.rebase(branch, onto),
            None => self.catalog().rebase(branch, onto),
        }
    }

    fn w_cherry_pick(&self, commit_ref: &str, onto: &str) -> Result<String> {
        match self.remote() {
            Some(rc) => rc.cherry_pick(commit_ref, onto),
            None => self.catalog().cherry_pick(commit_ref, onto),
        }
    }

    fn w_gc(&self) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.gc().map(|_| ()),
            None => self.catalog().gc().map(|_| ()),
        }
    }

    fn w_checkpoint(&self) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.checkpoint().map(|_| ()),
            None => self.catalog().checkpoint().map(|_| ()),
        }
    }

    fn w_compact(&self) -> Result<()> {
        match self.remote() {
            Some(rc) => rc.compact().map(|_| ()),
            None => self.catalog().compact().map(|_| ()),
        }
    }

    /// Commit one simulated table write; returns the snapshot id. Both
    /// modes compute the identical content-derived snapshot id (the
    /// server runs the same `Snapshot::new` over the same fields), so
    /// the refinement bijection is mode-independent.
    fn w_commit_sim_table(
        &self,
        branch: &str,
        table: &str,
        content: &str,
        rows: u64,
        snap_run: &str,
        commit_run: Option<String>,
        author: &str,
        message: &str,
    ) -> Result<String> {
        match self.remote() {
            Some(rc) => {
                let commit = RemoteCommit {
                    branch,
                    table,
                    content,
                    schema: "SimTable",
                    fingerprint: "sim_fp",
                    rows,
                    snap_run_id: snap_run,
                    author,
                    message,
                    run_id: commit_run.as_deref(),
                    expected_head: None,
                    retry: RemoteRetryPolicy::OneShot,
                };
                rc.commit(&commit).map(|o| o.snapshot)
            }
            None => {
                let key = self.catalog().store().put(content.as_bytes().to_vec());
                let snap = Snapshot::new(vec![key], "SimTable", "sim_fp", rows, snap_run);
                let req = CommitRequest::new(branch, table, snap)
                    .author(author)
                    .message(message)
                    .run_id(commit_run);
                self.catalog().commit(req).map(|o| o.snapshot)
            }
        }
    }

    /// Mirror one op into the model; refusal here means the driver's
    /// preconditions and the model disagree — a harness bug, never a
    /// stack bug, so it surfaces as an error rather than a violation.
    fn model_apply(&mut self, op: &MOp) -> Result<()> {
        if self.model.apply(op) {
            Ok(())
        } else {
            Err(BauplanError::Other(format!("sim driver bug: model refused {op:?}")))
        }
    }

    // ------------------------------------------------------------ oracles

    fn projection(&self) -> Projection<'_> {
        let mut branch_names: Vec<Option<String>> = vec![None; self.model.branches.len()];
        branch_names[0] = Some(MAIN.to_string());
        for ctx in &self.runs {
            if ctx.transactional {
                branch_names[ctx.model_branch as usize] = Some(ctx.exec_branch.clone());
            }
        }
        if let Some(agent) = &self.agent {
            branch_names[agent.model_branch as usize] = Some("agent".to_string());
        }
        Projection { branch_names, snaps: &self.snaps }
    }

    /// Refinement + Fig. 3 main consistency, after op `at` (`last_op` =
    /// `None` for the end-of-trace recovery check).
    fn check_oracles(&self, at: usize, last_op: Option<&SimOp>) -> Option<Violation> {
        if let Err(detail) = check_refinement(&self.model, self.catalog(), &self.projection()) {
            return Some(Violation { kind: ViolationKind::RefinementDivergence, at_op: at, detail });
        }
        if let Err(detail) = check_main_consistent(&self.model) {
            let kind = match last_op {
                Some(SimOp::AgentMerge) if self.last_agent_merge_from_aborted => {
                    ViolationKind::Fig4AbortedBranchMerge
                }
                // cherry-picking from an aborted branch is the same leak
                // through the commit-addressed door
                Some(SimOp::CherryPickToMain { .. }) => ViolationKind::Fig4AbortedBranchMerge,
                _ => ViolationKind::Fig3MixedMain,
            };
            return Some(Violation { kind, at_op: at, detail });
        }
        None
    }

    // ------------------------------------------------------------ op apply

    fn apply(&mut self, op: &SimOp) -> Result<Outcome> {
        match op {
            SimOp::BeginRun { transactional } => self.begin_run(*transactional),
            SimOp::StepRun { run } => self.step_run(*run),
            SimOp::FailRun { run } => self.fail_run(*run),
            SimOp::KillRun { run } => self.kill_run(*run),
            SimOp::PublishRun { run } => self.publish_run(*run),
            SimOp::AgentFork { from } => self.agent_fork(*from),
            SimOp::AgentMerge => self.agent_merge(),
            SimOp::RebaseRun { run } => self.rebase_run(*run),
            SimOp::CherryPickToMain { run } => self.cherry_pick(*run),
            SimOp::FullRun { transactional, jobs, fault, mid_run_write } => {
                self.full_run(*transactional, *jobs, *fault, *mid_run_write)
            }
            SimOp::EnvWrite => self.env_write(),
            SimOp::Gc => {
                let result = self.w_gc();
                self.map_journalable(result)
            }
            SimOp::Checkpoint => {
                let result = self.w_checkpoint();
                self.map_journalable(result)
            }
            SimOp::RotateSegment => {
                // maintenance on the deployment's own journal, not a
                // tenant request — always a direct catalog call
                let result = self.catalog().journal_rotate();
                self.map_journalable(result)
            }
            SimOp::Compact => {
                let result = self.w_compact();
                self.map_journalable(result)
            }
            SimOp::JournalCrash => {
                self.catalog().journal_inject_fail_after(0);
                self.journal_dead = true;
                Ok(Outcome::Applied)
            }
            SimOp::CrashRecover => match self.crash_recover()? {
                Some((kind, detail)) => Ok(Outcome::Violated { kind, detail }),
                None => Ok(Outcome::Applied),
            },
        }
    }

    /// Fold a journal-sensitive mutation result: while the journal is
    /// dead every append fails and the write-ahead discipline promises
    /// the mutation left no trace — the op is a deterministic skip (and
    /// the refinement check right after verifies "no trace" for real).
    fn map_journalable(&self, result: Result<()>) -> Result<Outcome> {
        match result {
            Ok(()) => Ok(Outcome::Applied),
            Err(_) if self.journal_dead => Ok(Outcome::Skipped),
            Err(e) => Err(e),
        }
    }

    fn begin_run(&mut self, transactional: bool) -> Result<Outcome> {
        if self.model.runs.len() >= MAX_MODEL_RUNS {
            return Ok(Outcome::Skipped);
        }
        if !transactional && self.guardrail {
            // the paper's stack never direct-writes; replayed/shrunken
            // traces may still carry the op — skip, don't error
            return Ok(Outcome::Skipped);
        }
        let r = self.model.runs.len() as u8;
        let run_id = format!("sim{r}");
        let exec_branch = if transactional {
            match self.w_create_txn_branch(MAIN, &run_id) {
                Ok(name) => name,
                Err(_) if self.journal_dead => return Ok(Outcome::Skipped),
                Err(BauplanError::RefExists(_)) => return Ok(Outcome::Skipped),
                Err(e) => return Err(e),
            }
        } else {
            MAIN.to_string()
        };
        self.model_apply(&MOp::BeginRun { run: r, transactional })?;
        let model_branch = if transactional {
            (self.model.branches.len() - 1) as u8
        } else {
            0
        };
        self.runs.push(RunCtx {
            run_id,
            transactional,
            exec_branch,
            model_branch,
            fine_grained: true,
        });
        Ok(Outcome::Applied)
    }

    /// `(run_id, exec_branch, transactional, model_branch)` of
    /// fine-grained run `run`, if it is applicable in phase `Running`.
    fn fine_running(&self, run: u8) -> Option<(String, String, bool, u8)> {
        let ctx = self.runs.get(run as usize)?;
        if !ctx.fine_grained {
            return None;
        }
        if self.model.runs.get(run as usize)?.phase != RunPhase::Running {
            return None;
        }
        Some((ctx.run_id.clone(), ctx.exec_branch.clone(), ctx.transactional, ctx.model_branch))
    }

    fn step_run(&mut self, run: u8) -> Result<Outcome> {
        let Some((run_id, exec_branch, _, _)) = self.fine_running(run) else {
            return Ok(Outcome::Skipped);
        };
        let step = self.model.runs[run as usize].idx;
        if step >= PLAN_LEN {
            return Ok(Outcome::Skipped);
        }
        let content = format!("sim:{run_id}:{step}");
        let message = format!("sim run {run_id}: write {}", PLAN_TABLES[step as usize]);
        let committed = self.w_commit_sim_table(
            &exec_branch,
            PLAN_TABLES[step as usize],
            &content,
            (step + 1) as u64,
            &run_id,
            Some(run_id.clone()),
            "sim",
            &message,
        );
        let snap_id = match committed {
            Ok(id) => id,
            Err(_) if self.journal_dead => return Ok(Outcome::Skipped),
            Err(e) => return Err(e),
        };
        self.model_apply(&MOp::StepRun { run, table: step })?;
        self.snaps.insert((run, step), snap_id);
        Ok(Outcome::Applied)
    }

    fn fail_run(&mut self, run: u8) -> Result<Outcome> {
        let Some((_, exec_branch, transactional, _)) = self.fine_running(run) else {
            return Ok(Outcome::Skipped);
        };
        if transactional {
            match self.w_set_branch_state(&exec_branch, BranchState::Aborted) {
                Ok(()) => {}
                Err(_) if self.journal_dead => return Ok(Outcome::Skipped),
                Err(e) => return Err(e),
            }
        }
        self.model_apply(&MOp::FailRun { run })?;
        Ok(Outcome::Applied)
    }

    fn kill_run(&mut self, run: u8) -> Result<Outcome> {
        if self.fine_running(run).is_none() {
            return Ok(Outcome::Skipped);
        }
        // the process dies: no catalog mutation at all — the orphaned
        // branch stays Open until recovery aborts it
        self.model_apply(&MOp::CrashRun { run })?;
        Ok(Outcome::Applied)
    }

    fn publish_run(&mut self, run: u8) -> Result<Outcome> {
        if self.journal_dead {
            return Ok(Outcome::Skipped); // multi-record op: not a victim
        }
        let Some((_, exec_branch, transactional, _)) = self.fine_running(run) else {
            return Ok(Outcome::Skipped);
        };
        if self.model.runs[run as usize].idx != PLAN_LEN {
            // the run engine never publishes an incomplete run; shrunken
            // traces may try — skip
            return Ok(Outcome::Skipped);
        }
        if !transactional {
            self.model_apply(&MOp::PublishRun { run })?;
            return Ok(Outcome::Applied);
        }
        match self.w_merge(&exec_branch, MAIN, false) {
            Ok(_) => {
                self.w_set_branch_state(&exec_branch, BranchState::Merged)?;
                self.w_delete_branch(&exec_branch)?;
                self.model_apply(&MOp::PublishRun { run })?;
                Ok(Outcome::Applied)
            }
            Err(BauplanError::MergeConflict(_)) => {
                // refused publish is still a *total* failure: abort
                self.w_set_branch_state(&exec_branch, BranchState::Aborted)?;
                self.model_apply(&MOp::FailRun { run })?;
                Ok(Outcome::Applied)
            }
            Err(e) => Err(e),
        }
    }

    fn agent_fork(&mut self, from: AgentSource) -> Result<Outcome> {
        if self.agent.is_some() || self.journal_dead {
            return Ok(Outcome::Skipped);
        }
        let (src_name, src_model, from_aborted) = match from {
            AgentSource::Main => (MAIN.to_string(), 0u8, false),
            AgentSource::AbortedTxn(r) => {
                let Some(ctx) = self.runs.get(r as usize) else { return Ok(Outcome::Skipped) };
                if !ctx.transactional {
                    return Ok(Outcome::Skipped);
                }
                let (name, model_branch) = (ctx.exec_branch.clone(), ctx.model_branch);
                if self.model.branches[model_branch as usize].phase != BranchPhase::Aborted {
                    return Ok(Outcome::Skipped);
                }
                (name, model_branch, true)
            }
        };
        match self.w_create_branch("agent", &src_name, !self.guardrail) {
            Ok(_) => {
                if from_aborted && self.guardrail {
                    // the oracle with teeth: the catalog let an aborted
                    // txn branch be forked without the capability
                    return Ok(Outcome::Violated {
                        kind: ViolationKind::GuardrailBreach,
                        detail: format!(
                            "fork of aborted transactional branch '{src_name}' succeeded \
                             without allow_aborted"
                        ),
                    });
                }
                self.model_apply(&MOp::AgentFork { from: src_model })?;
                self.agent = Some(AgentCtx {
                    model_branch: (self.model.branches.len() - 1) as u8,
                    from_aborted,
                });
                Ok(Outcome::Applied)
            }
            Err(BauplanError::Visibility(_)) if self.guardrail && from_aborted => {
                self.guardrail_refusals += 1;
                Ok(Outcome::Skipped)
            }
            Err(BauplanError::RefExists(_)) => Ok(Outcome::Skipped),
            Err(e) => Err(e),
        }
    }

    fn agent_merge(&mut self) -> Result<Outcome> {
        if self.journal_dead {
            return Ok(Outcome::Skipped);
        }
        let Some(agent) = &self.agent else { return Ok(Outcome::Skipped) };
        let (model_branch, from_aborted) = (agent.model_branch, agent.from_aborted);
        match self.w_merge("agent", MAIN, !self.guardrail) {
            Ok(_) => {
                self.w_delete_branch("agent")?;
                self.model_apply(&MOp::MergeToMain { src: model_branch })?;
                self.last_agent_merge_from_aborted = from_aborted;
                self.agent = None;
                Ok(Outcome::Applied)
            }
            Err(BauplanError::MergeConflict(_)) => Ok(Outcome::Skipped),
            Err(e) => Err(e),
        }
    }

    fn rebase_run(&mut self, run: u8) -> Result<Outcome> {
        if self.journal_dead {
            return Ok(Outcome::Skipped); // multi-record op: not a victim
        }
        let Some((_, exec_branch, transactional, model_branch)) = self.fine_running(run) else {
            return Ok(Outcome::Skipped);
        };
        if !transactional {
            return Ok(Outcome::Skipped);
        }
        match self.w_rebase(&exec_branch, MAIN) {
            Ok(_) => {
                self.model_apply(&MOp::RebaseOntoMain { branch: model_branch })?;
                Ok(Outcome::Applied)
            }
            Err(BauplanError::MergeConflict(_)) => Ok(Outcome::Skipped),
            Err(e) => Err(e),
        }
    }

    fn cherry_pick(&mut self, run: u8) -> Result<Outcome> {
        // the commit-addressed Fig. 4 leak: only meaningful as an attack,
        // so the paper's stack (guardrail on) never performs it
        if self.guardrail || self.journal_dead {
            return Ok(Outcome::Skipped);
        }
        let Some(ctx) = self.runs.get(run as usize) else { return Ok(Outcome::Skipped) };
        if !ctx.transactional {
            return Ok(Outcome::Skipped);
        }
        let (exec_branch, model_branch) = (ctx.exec_branch.clone(), ctx.model_branch);
        if self.model.branches[model_branch as usize].phase != BranchPhase::Aborted {
            return Ok(Outcome::Skipped);
        }
        if self.model.runs[run as usize].idx == 0 {
            // head commit predates the run: picking it replays an old
            // main commit, which the model does not, er, model
            return Ok(Outcome::Skipped);
        }
        match self.w_cherry_pick(&exec_branch, MAIN) {
            Ok(_) => {
                self.model_apply(&MOp::CherryPickToMain { src: model_branch })?;
                Ok(Outcome::Applied)
            }
            Err(BauplanError::MergeConflict(_)) => Ok(Outcome::Skipped),
            Err(e) => Err(e),
        }
    }

    fn env_write(&mut self) -> Result<Outcome> {
        self.env_seq += 1;
        let content = format!("env:{}", self.env_seq);
        let result = self
            .w_commit_sim_table(
                MAIN,
                "env_table",
                &content,
                1,
                "env",
                None,
                "env",
                "concurrent tenant write",
            )
            .map(|_| ());
        self.map_journalable(result)
    }

    // ------------------------------------------------- concurrent committers

    /// Two committer threads on disjoint scratch branches, each chaining
    /// strict-CAS commits off its own head: every request pins
    /// `expected_head` to the thread's previous commit, so any
    /// interference surfaces as `CasConflict` instead of a silent
    /// rebase. Branch contents are deterministic per branch, so the
    /// final catalog state is schedule-independent even though the two
    /// threads race for real. The scratch branches are deleted before
    /// the refinement sweep runs, so the model never has to track them.
    fn concurrent_burst(&mut self, round: u64) -> Result<Option<(ViolationKind, String)>> {
        let names = [format!("occ/a{round}"), format!("occ/b{round}")];
        for name in &names {
            self.catalog().create_branch(name, MAIN, false)?;
        }
        let mut joins = Vec::new();
        for name in names.clone() {
            let catalog = self.catalog().clone();
            joins.push(std::thread::spawn(move || -> Result<String> {
                let mut head = catalog.resolve(&name)?;
                for i in 0..3u64 {
                    let key = catalog.store().put(format!("occ:{name}:{i}").into_bytes());
                    let snap = Snapshot::new(vec![key], "SimTable", "sim_fp", 1, "occ");
                    let req = CommitRequest::new(&name, "occ_table", snap)
                        .author("occ")
                        .message("concurrent committer")
                        .expected_head(&head);
                    head = catalog.commit(req)?.commit;
                }
                Ok(head)
            }));
        }
        let mut verdict = None;
        for (name, join) in names.iter().zip(joins) {
            match join.join().expect("committer thread panicked") {
                Ok(head) if self.catalog().resolve(name)? == head => {}
                Ok(head) => {
                    verdict = Some((
                        ViolationKind::OccDisjointConflict,
                        format!("branch '{name}': head is not the last commit {head}"),
                    ));
                }
                Err(e) => {
                    verdict = Some((
                        ViolationKind::OccDisjointConflict,
                        format!("committer on disjoint branch '{name}' failed: {e}"),
                    ));
                }
            }
        }
        for name in &names {
            self.catalog().delete_branch(name)?;
        }
        Ok(verdict)
    }

    // ------------------------------------------------------------ full runs

    fn full_run(
        &mut self,
        transactional: bool,
        jobs: u8,
        fault: RunFault,
        mid_run_write: bool,
    ) -> Result<Outcome> {
        if self.model.runs.len() >= MAX_MODEL_RUNS || self.journal_dead {
            return Ok(Outcome::Skipped);
        }
        if !transactional && self.guardrail {
            return Ok(Outcome::Skipped);
        }
        let r = self.model.runs.len() as u8;
        let run_id = format!("sim{r}");
        let txn_branch = format!("{TXN_PREFIX}{run_id}");
        let main_before = self.catalog().read_ref(MAIN)?;

        let mut failure = match fault {
            RunFault::None | RunFault::FailingVerifier => FailurePlan::none(),
            RunFault::CrashBefore(k) => {
                FailurePlan::crash_before(PLAN_TABLES[k as usize % PLAN_TABLES.len()])
            }
            RunFault::CrashAfter(k) => {
                FailurePlan::crash_after(PLAN_TABLES[k as usize % PLAN_TABLES.len()])
            }
            RunFault::KillAfter(k) => {
                FailurePlan::kill_after(PLAN_TABLES[k as usize % PLAN_TABLES.len()])
            }
            RunFault::JournalCrash(n) => FailurePlan::journal_crash_after(n as u64),
        };
        if mid_run_write {
            // mid-run interleaving: another tenant commits to main while
            // this run sits between two node commits — forces the publish
            // merge onto the three-way path
            let catalog = self.client.catalog.clone();
            let content = format!("env:midrun:{run_id}");
            failure = failure.with_pause(Arc::new(move |point, node| {
                if point == FailurePoint::BeforeNode && node == PLAN_TABLES[1] {
                    let key = catalog.store().put(content.clone().into_bytes());
                    let snap = Snapshot::new(vec![key], "SimTable", "sim_fp", 1, "env");
                    let req = CommitRequest::new(MAIN, "env_table", snap)
                        .author("env")
                        .message("mid-run tenant write");
                    let _ = catalog.commit(req);
                }
            }));
        }
        let verifiers: Vec<Verifier> = if fault == RunFault::FailingVerifier {
            vec![Verifier::min_rows("grand_child", usize::MAX)]
        } else {
            Vec::new()
        };
        let mode = if transactional {
            RunMode::Transactional
        } else {
            RunMode::DirectWrite
        };
        // Serializable faults ride the wire; process-level faults (kill,
        // journal crash) and pause-hook interleavings are injected into
        // the server process directly — they model the *deployment*
        // failing, not a client request.
        let wire_ok = !mid_run_write
            && matches!(
                fault,
                RunFault::None
                    | RunFault::FailingVerifier
                    | RunFault::CrashBefore(_)
                    | RunFault::CrashAfter(_)
            );
        let result = match self.remote() {
            Some(rc) if wire_ok => {
                let mut opts = RemoteRunOpts {
                    mode_direct: !transactional,
                    jobs: jobs.max(1) as usize,
                    run_id: Some(run_id.clone()),
                    ..RemoteRunOpts::default()
                };
                match fault {
                    RunFault::FailingVerifier => {
                        opts.min_rows = Some(("grand_child".to_string(), u64::MAX));
                    }
                    RunFault::CrashBefore(k) => {
                        let node = PLAN_TABLES[k as usize % PLAN_TABLES.len()];
                        opts.fault = Some(("crash_before".to_string(), node.to_string()));
                    }
                    RunFault::CrashAfter(k) => {
                        let node = PLAN_TABLES[k as usize % PLAN_TABLES.len()];
                        opts.fault = Some(("crash_after".to_string(), node.to_string()));
                    }
                    _ => {}
                }
                rc.submit_run(crate::dag::parser::PAPER_PIPELINE_TEXT, MAIN, &opts)
            }
            _ => {
                let runner = self.client.runner.clone().with_jobs(jobs.max(1) as usize);
                runner.run_with_id(&self.plan, MAIN, mode, &failure, &verifiers, &run_id)
            }
        };

        match result {
            Ok(state) => match state.status {
                RunStatus::Success => {
                    self.begin_full_model(r, transactional, &run_id, &txn_branch)?;
                    let main_now = self.catalog().read_ref(MAIN)?;
                    for k in 0..PLAN_LEN {
                        self.model_apply(&MOp::StepRun { run: r, table: k })?;
                        let id = main_now
                            .tables
                            .get(PLAN_TABLES[k as usize])
                            .cloned()
                            .ok_or_else(|| {
                                BauplanError::Other(format!(
                                    "sim: successful run {run_id} left no '{}' on main",
                                    PLAN_TABLES[k as usize]
                                ))
                            })?;
                        self.snaps.insert((r, k), id);
                    }
                    self.model_apply(&MOp::PublishRun { run: r })?;
                    // trace-completeness oracle: a successful run must
                    // have journaled a full span trace beside its
                    // terminal record. Trace journaling is best-effort
                    // under a dying journal, so the JournalCrash fault
                    // is exempt.
                    if !matches!(fault, RunFault::JournalCrash(_)) && !self.journal_dead {
                        match self.catalog().get_run_trace(&run_id) {
                            Some(trace) => {
                                if let Err(detail) = check_trace_complete(&trace) {
                                    return Ok(Outcome::Violated {
                                        kind: ViolationKind::TraceIncomplete,
                                        detail: format!("run {run_id}: {detail}"),
                                    });
                                }
                                self.traced_runs.push((run_id.clone(), trace.to_string()));
                            }
                            None => {
                                return Ok(Outcome::Violated {
                                    kind: ViolationKind::TraceIncomplete,
                                    detail: format!(
                                        "run {run_id}: no journaled trace after success"
                                    ),
                                })
                            }
                        }
                    }
                }
                RunStatus::Aborted { .. } => {
                    self.begin_full_model(r, transactional, &run_id, &txn_branch)?;
                    self.sync_observed_steps(r, &txn_branch, &main_before)?;
                    self.model_apply(&MOp::FailRun { run: r })?;
                }
                RunStatus::FailedPartial { .. } => {
                    self.begin_full_model(r, transactional, &run_id, &txn_branch)?;
                    self.sync_observed_steps(r, MAIN, &main_before)?;
                    self.model_apply(&MOp::FailRun { run: r })?;
                }
            },
            Err(e) => {
                let process_died =
                    matches!(fault, RunFault::KillAfter(_) | RunFault::JournalCrash(_));
                if !process_died {
                    return Err(e);
                }
                let exec = if transactional {
                    txn_branch.clone()
                } else {
                    MAIN.to_string()
                };
                if let Ok(info) = self.catalog().branch_info(&exec) {
                    self.begin_full_model(r, transactional, &run_id, &txn_branch)?;
                    self.sync_observed_steps(r, &exec, &main_before)?;
                    // a journal crash can land *between* the publish
                    // merge and the branch bookkeeping: main already
                    // advanced with the run's outputs. Detect it from the
                    // plan tables (env writes never touch them) and
                    // mirror the published half.
                    let main_now = self.catalog().read_ref(MAIN)?;
                    let published = transactional
                        && PLAN_TABLES
                            .iter()
                            .any(|t| main_now.tables.get(*t) != main_before.tables.get(*t));
                    if published && info.state == BranchState::Merged {
                        // merge + Merged landed; only the delete (and
                        // later appends) died — logically fully published
                        self.model_apply(&MOp::PublishRun { run: r })?;
                    } else if published {
                        self.model_apply(&MOp::CrashPublish { run: r })?;
                    } else {
                        self.model_apply(&MOp::CrashRun { run: r })?;
                    }
                }
                // else: the run died before its first mutation landed —
                // nothing to mirror
            }
        }

        if matches!(fault, RunFault::JournalCrash(_)) {
            // the journal may or may not have died exactly inside the
            // run; pin it dead so the mandated CrashRecover heals from a
            // known state
            self.catalog().journal_inject_fail_after(0);
            self.journal_dead = true;
        }
        Ok(Outcome::Applied)
    }

    /// Mirror a `FullRun`'s begin into the model and register its
    /// real-side context (keeps `runs` aligned with `model.runs`).
    fn begin_full_model(
        &mut self,
        r: u8,
        transactional: bool,
        run_id: &str,
        txn_branch: &str,
    ) -> Result<()> {
        self.model_apply(&MOp::BeginRun { run: r, transactional })?;
        let model_branch = if transactional {
            (self.model.branches.len() - 1) as u8
        } else {
            0
        };
        self.runs.push(RunCtx {
            run_id: run_id.to_string(),
            transactional,
            exec_branch: if transactional {
                txn_branch.to_string()
            } else {
                MAIN.to_string()
            },
            model_branch,
            fine_grained: false,
        });
        Ok(())
    }

    /// Mirror the steps a (failed or killed) full run actually landed on
    /// `exec_branch`: walk the first-parent chain back to `base`, keep
    /// the commits this run authored, and apply them oldest-first as
    /// model steps (learning the snap → snapshot-id mapping from the
    /// observed values). The paper pipeline is a chain, so the written
    /// tables must form a plan-order prefix — anything else is a real
    /// scheduler bug and surfaces as an error.
    fn sync_observed_steps(&mut self, r: u8, exec_branch: &str, base: &Commit) -> Result<()> {
        let run_id = format!("sim{r}");
        let mut cursor = self.catalog().read_ref(exec_branch)?;
        let mut writes: Vec<(u8, String)> = Vec::new();
        while cursor.id != base.id {
            let Some(parent_id) = cursor.parents.first().cloned() else { break };
            let parent = self.catalog().get_commit(&parent_id)?;
            if cursor.run_id.as_deref() == Some(run_id.as_str()) {
                for (k, table) in PLAN_TABLES.iter().enumerate() {
                    if cursor.tables.get(*table) != parent.tables.get(*table) {
                        if let Some(id) = cursor.tables.get(*table) {
                            writes.push((k as u8, id.clone()));
                        }
                    }
                }
            }
            cursor = parent;
        }
        writes.reverse();
        for (i, (table, id)) in writes.iter().enumerate() {
            if *table != i as u8 {
                return Err(BauplanError::Other(format!(
                    "sim: run {run_id} wrote plan tables out of order: {writes:?}"
                )));
            }
            self.model_apply(&MOp::StepRun { run: r, table: *table })?;
            self.snaps.insert((r, *table), id.clone());
        }
        Ok(())
    }

    // ------------------------------------------------------------ recovery

    /// The crash + restart procedure: recover the lake twice and demand
    /// byte-identical exports (the idempotence oracle) plus
    /// byte-identical journaled run traces, then rebuild the client
    /// stack on the recovered catalog and mirror the orphan-abort
    /// policy into the model. Returns `Some((kind, detail))` on
    /// divergence.
    fn crash_recover(&mut self) -> Result<Option<(ViolationKind, String)>> {
        // the "process" dies: in loopback mode that takes the API server
        // down with it (prompt shutdown + thread join); a fresh server
        // is started on the recovered stack below
        self.wire = Wire::Local;
        // fsck-clean oracle, pre-recovery: the crashed on-disk state
        // must already audit clean (torn active tails are expected and
        // info-severity; anything error/warn is real damage).
        if let Some(v) = self.fsck_oracle("pre-recovery")? {
            return Ok(Some(v));
        }
        let a = Catalog::open_durable_cfg(&self.dir, sim_journal_config())?;
        let export_a = a.export().to_string();
        drop(a);
        let b = Catalog::open_durable_cfg(&self.dir, sim_journal_config())?;
        let export_b = b.export().to_string();
        if export_a != export_b {
            return Ok(Some((
                ViolationKind::RecoveryDivergence,
                format!(
                    "two consecutive recoveries diverge ({} vs {} bytes)",
                    export_a.len(),
                    export_b.len()
                ),
            )));
        }
        // every trace observed at run success must survive recovery
        // byte-identically (replay reconstructs the journaled op)
        for (run_id, expected) in &self.traced_runs {
            match b.get_run_trace(run_id) {
                Some(t) if &t.to_string() == expected => {}
                Some(t) => {
                    return Ok(Some((
                        ViolationKind::TraceIncomplete,
                        format!(
                            "run {run_id}: trace changed across recovery \
                             ({} vs {} bytes)",
                            expected.len(),
                            t.to_string().len()
                        ),
                    )))
                }
                None => {
                    return Ok(Some((
                        ViolationKind::TraceIncomplete,
                        format!("run {run_id}: journaled trace lost across recovery"),
                    )))
                }
            }
        }
        let mut client = Client::open_sim_with_catalog(b)?;
        let cache = RunCache::open(&self.dir.join(CACHE_INDEX_FILE), CACHE_BUDGET)?;
        client.attach_run_cache(Arc::new(cache));
        self.client = client;
        self.journal_dead = false;
        // fsck-clean oracle, post-recovery: recovery must not have left
        // the lake in a state the auditor objects to.
        if let Some(v) = self.fsck_oracle("post-recovery")? {
            return Ok(Some(v));
        }
        if self.loopback {
            self.start_loopback()?;
        }
        self.model_apply(&MOp::Recover)?;
        Ok(None)
    }

    /// Run the offline integrity audit over the lake directory; any
    /// error- or warn-severity finding is a [`ViolationKind::FsckUnclean`]
    /// violation (info findings — torn active tails, orphan objects —
    /// are expected crash residue).
    fn fsck_oracle(&self, when: &str) -> Result<Option<(ViolationKind, String)>> {
        let report = crate::audit::fsck_path(&self.dir, false)?;
        if report.clean() {
            return Ok(None);
        }
        let detail = crate::audit::worst_finding(&report)
            .map(|(code, line)| format!("{code}: {line}"))
            .unwrap_or_else(|| "unclean fsck report with no findings".into());
        Ok(Some((ViolationKind::FsckUnclean, format!("{when}: {detail}"))))
    }

    // ------------------------------------------------------------ digest

    /// Canonical JSON of the model projection: branch lifecycles and
    /// plan-table maps plus run phases. Schedule-independent — the
    /// jobs=1 vs jobs=4 property compares exactly this.
    fn model_digest(&self) -> String {
        use crate::model::state::BranchKind;
        let branches: Vec<Json> = self
            .model
            .branches
            .iter()
            .enumerate()
            .map(|(bi, b)| {
                let kind = match b.kind {
                    BranchKind::Main => "main".to_string(),
                    BranchKind::Txn(r) => format!("txn:{r}"),
                    BranchKind::Agent => "agent".to_string(),
                };
                let phase = match b.phase {
                    BranchPhase::Open => "open",
                    BranchPhase::Aborted => "aborted",
                    BranchPhase::Deleted => "deleted",
                };
                let tables: BTreeMap<String, Json> = self
                    .model
                    .branch_tables(bi as u8)
                    .iter()
                    .map(|(t, (run, step))| {
                        (
                            t.to_string(),
                            Json::Arr(vec![Json::num(*run as f64), Json::num(*step as f64)]),
                        )
                    })
                    .collect();
                Json::obj(vec![
                    ("kind", Json::str(kind)),
                    ("phase", Json::str(phase)),
                    ("tables", Json::Obj(tables)),
                ])
            })
            .collect();
        let runs: Vec<Json> = self
            .model
            .runs
            .iter()
            .map(|r| {
                let phase = match r.phase {
                    RunPhase::Running => "running",
                    RunPhase::Published => "published",
                    RunPhase::Failed => "failed",
                };
                Json::obj(vec![
                    ("phase", Json::str(phase)),
                    ("transactional", Json::Bool(r.transactional)),
                ])
            })
            .collect();
        Json::obj(vec![("branches", Json::Arr(branches)), ("runs", Json::Arr(runs))]).to_string()
    }
}
