//! The simulator's oracles: what "the real stack refines the model"
//! means, and the paper's safety properties as machine-checkable
//! predicates. Spec: `doc/SIMULATION.md` §Oracles.

use std::collections::BTreeMap;

use crate::catalog::{BranchState, Catalog};
use crate::model::state::{BranchPhase, ModelState, Snap};
use crate::sim::PLAN_TABLES;
use crate::util::json::Json;

/// Classification of a detected safety violation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// Fig. 3: `main` holds plan tables written by more than one run, or
    /// a strict partial prefix — a reader can observe a mixed state.
    Fig3MixedMain,
    /// Fig. 4: the inconsistency was introduced by merging an agent
    /// branch forked from an *aborted* transactional branch.
    Fig4AbortedBranchMerge,
    /// With guardrails on, the catalog allowed a fork/merge of an
    /// aborted transactional branch without the explicit capability.
    GuardrailBreach,
    /// The real branch states no longer project onto the tracked model
    /// state (lifecycle phase or plan-table map diverged).
    RefinementDivergence,
    /// Two consecutive `Catalog::recover` calls produced different
    /// exports — recovery is not idempotent.
    RecoveryDivergence,
    /// A successful run's journaled trace is missing, malformed, or
    /// incomplete: not exactly one `commit:<table>` span per plan
    /// table, spans escaping their parents' intervals, or a trace that
    /// changed (or vanished) across recovery.
    TraceIncomplete,
    /// Concurrent committers on *disjoint* branches interfered: a
    /// strict-CAS commit hit `CasConflict`, or a branch head did not
    /// land on the committer's last commit. Per-branch OCC promises
    /// disjoint branches never contend.
    OccDisjointConflict,
    /// The offline integrity audit ([`crate::audit::fsck`]) found
    /// error- or warn-severity damage in the durable lake directory —
    /// either in the crashed pre-recovery state or after recovery.
    FsckUnclean,
}

impl ViolationKind {
    /// Stable string id (CLI `--expect`, JSON reports).
    pub fn as_str(self) -> &'static str {
        match self {
            ViolationKind::Fig3MixedMain => "fig3_mixed_main",
            ViolationKind::Fig4AbortedBranchMerge => "fig4_aborted_branch_merge",
            ViolationKind::GuardrailBreach => "guardrail_breach",
            ViolationKind::RefinementDivergence => "refinement_divergence",
            ViolationKind::RecoveryDivergence => "recovery_divergence",
            ViolationKind::TraceIncomplete => "trace_incomplete",
            ViolationKind::OccDisjointConflict => "occ_disjoint_conflict",
            ViolationKind::FsckUnclean => "fsck_unclean",
        }
    }

    /// Inverse of [`ViolationKind::as_str`].
    pub fn parse(s: &str) -> Option<ViolationKind> {
        Some(match s {
            "fig3_mixed_main" => ViolationKind::Fig3MixedMain,
            "fig4_aborted_branch_merge" => ViolationKind::Fig4AbortedBranchMerge,
            "guardrail_breach" => ViolationKind::GuardrailBreach,
            "refinement_divergence" => ViolationKind::RefinementDivergence,
            "recovery_divergence" => ViolationKind::RecoveryDivergence,
            "trace_incomplete" => ViolationKind::TraceIncomplete,
            "occ_disjoint_conflict" => ViolationKind::OccDisjointConflict,
            "fsck_unclean" => ViolationKind::FsckUnclean,
            _ => return None,
        })
    }
}

/// A detected violation: which oracle fired, after which trace op, and
/// a human-readable account of the evidence.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    /// Which oracle fired.
    pub kind: ViolationKind,
    /// Index (into the trace) of the op after which the oracle fired;
    /// `trace.len()` means the end-of-trace recovery check.
    pub at_op: usize,
    /// Evidence (diverging branch, mixed table map, …).
    pub detail: String,
}

impl Violation {
    /// Canonical-JSON encoding (CLI output, CI artifacts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("verdict", Json::str("violation")),
            ("kind", Json::str(self.kind.as_str())),
            ("at_op", Json::num(self.at_op as f64)),
            ("detail", Json::str(&self.detail)),
        ])
    }
}

/// The driver-side half of the refinement relation: how model branches
/// map to real refs and model snaps to real snapshot ids.
pub(crate) struct Projection<'a> {
    /// Real branch name per model branch index (`None` = untracked).
    pub branch_names: Vec<Option<String>>,
    /// Model snap `(run, step)` → the real snapshot id it stands for.
    pub snaps: &'a BTreeMap<Snap, String>,
}

/// The refinement oracle: every live model branch must have a real
/// counterpart in the same lifecycle phase whose plan-table map equals
/// the model head's table map under the snap bijection; every `Deleted`
/// model branch must be gone for real. Returns the first divergence.
pub(crate) fn check_refinement(
    model: &ModelState,
    catalog: &Catalog,
    proj: &Projection<'_>,
) -> Result<(), String> {
    for (bi, mb) in model.branches.iter().enumerate() {
        let Some(Some(name)) = proj.branch_names.get(bi) else { continue };
        let real = catalog.branch_info(name);
        if mb.phase == BranchPhase::Deleted {
            // a published branch is normally deleted; a crash between the
            // `Merged` transition and the delete leaves it behind in
            // state `Merged` — logically gone, physically present
            if let Ok(b) = &real {
                if b.state != BranchState::Merged {
                    return Err(format!(
                        "model branch {bi} ('{name}') is Deleted but the real branch \
                         exists in state {:?}",
                        b.state
                    ));
                }
            }
            continue;
        }
        let real = match real {
            Ok(b) => b,
            Err(_) => {
                return Err(format!(
                    "model branch {bi} ('{name}', {:?}) has no real counterpart",
                    mb.phase
                ))
            }
        };
        let phase_ok = match (mb.phase, real.state) {
            (BranchPhase::Open, BranchState::Open) => true,
            (BranchPhase::Aborted, BranchState::Aborted) => true,
            _ => false,
        };
        if !phase_ok {
            return Err(format!(
                "branch '{name}': model phase {:?} vs real state {:?}",
                mb.phase, real.state
            ));
        }
        // plan-table maps must agree under the snap mapping
        let model_tables = model.branch_tables(bi as u8);
        let real_commit = match catalog.read_ref(name) {
            Ok(c) => c,
            Err(e) => return Err(format!("branch '{name}': head unreadable: {e}")),
        };
        for (k, table) in PLAN_TABLES.iter().enumerate() {
            let model_snap = model_tables.get(&(k as u8));
            let expected = model_snap.map(|s| {
                proj.snaps
                    .get(s)
                    .cloned()
                    .unwrap_or_else(|| format!("<unmapped snap {s:?}>"))
            });
            let real_id = real_commit.tables.get(*table).cloned();
            if expected != real_id {
                return Err(format!(
                    "branch '{name}', table '{table}': model {:?} -> {:?}, real {:?}",
                    model_snap, expected, real_id
                ));
            }
        }
    }
    // conversely: the real catalog must not contain branches the model
    // does not know — a replay bug resurrecting a deleted txn branch
    // (for example) must not slip past the sweep. Every real branch the
    // driver's stack can create (main, txn/<run>, agent) has a mapped
    // name; anything else is a divergence.
    for real in catalog.list_branches() {
        let known = proj
            .branch_names
            .iter()
            .flatten()
            .any(|name| name == &real.name);
        if !known {
            return Err(format!(
                "real branch '{}' ({:?}) has no model counterpart",
                real.name, real.state
            ));
        }
    }
    Ok(())
}

/// The trace-completeness oracle: a successful run's journaled trace
/// must carry exactly one `commit:<table>` span per plan table, and
/// every span whose parent is present in the trace must nest inside the
/// parent's interval. Fires as [`ViolationKind::TraceIncomplete`].
pub(crate) fn check_trace_complete(trace: &Json) -> Result<(), String> {
    let Some(spans) = trace.get("spans").as_arr() else {
        return Err("trace has no 'spans' array".to_string());
    };
    // id -> (start_us, end_us); span ids are unique and ascending, but
    // the nesting check only needs the lookup, not the order.
    let mut intervals: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
    let mut commits: BTreeMap<&str, usize> = BTreeMap::new();
    for s in spans {
        let Some(id) = s.get("id").as_usize() else {
            return Err("span missing numeric 'id'".to_string());
        };
        let (Some(start), Some(end)) =
            (s.get("start_us").as_f64(), s.get("end_us").as_f64())
        else {
            return Err(format!("span {id} missing start_us/end_us"));
        };
        if end < start {
            return Err(format!("span {id} ends before it starts ({end} < {start})"));
        }
        intervals.insert(id, (start, end));
        if let Some(name) = s.get("name").as_str() {
            if let Some(table) = name.strip_prefix("commit:") {
                if let Some(t) = PLAN_TABLES.iter().find(|&&t| t == table) {
                    *commits.entry(*t).or_insert(0) += 1;
                }
            }
        }
    }
    for table in PLAN_TABLES {
        match commits.get(table).copied() {
            Some(1) => {}
            Some(n) => {
                return Err(format!("{n} 'commit:{table}' spans (expected exactly 1)"))
            }
            None => return Err(format!("no 'commit:{table}' span")),
        }
    }
    for s in spans {
        let Some(parent) = s.get("parent").as_usize() else { continue };
        let Some(&(ps, pe)) = intervals.get(&parent) else { continue };
        let id = s.get("id").as_usize().unwrap_or(0);
        let (cs, ce) = intervals[&id];
        if cs < ps || ce > pe {
            return Err(format!(
                "span {id} [{cs}, {ce}] escapes parent {parent} [{ps}, {pe}]"
            ));
        }
    }
    Ok(())
}

/// The Fig. 3 oracle, evaluated on the tracked model state (which the
/// refinement oracle has just tied to the real one): all plan tables on
/// main written by one run, or none. Returns the offending table map
/// rendered for the report.
pub(crate) fn check_main_consistent(model: &ModelState) -> Result<(), String> {
    if model.main_consistent(crate::sim::PLAN_LEN) {
        return Ok(());
    }
    let tables = model.branch_tables(0);
    let rendered: Vec<String> = tables
        .iter()
        .map(|(t, (run, step))| {
            format!("{}=(run {run}, step {step})", PLAN_TABLES[*t as usize])
        })
        .collect();
    Err(format!("main holds a mixed/partial state: [{}]", rendered.join(", ")))
}
