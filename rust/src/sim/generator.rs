//! The seeded workload generator: turns an RNG into a multi-agent op
//! trace.
//!
//! The op grammar (spec: `doc/SIMULATION.md` §Op grammar) has two
//! layers:
//!
//! - **fine-grained run ops** (`BeginRun`/`StepRun`/…) drive the run
//!   protocol one catalog mutation at a time, so the generator can
//!   interleave several runs and an agent actor arbitrarily — the same
//!   interleaving freedom the model checker's BFS explores;
//! - **environment ops** (`FullRun`/`Gc`/`Checkpoint`/…) exercise the
//!   real machinery end to end: whole `Runner` executions (with jobs>1,
//!   cache, fault injection), garbage collection, checkpoints, process
//!   crashes and journal crash points.
//!
//! Generation is guided by a lightweight mirror of the abstract state so
//! most emitted ops are applicable; the driver skips the rest
//! deterministically (which is also what makes delta-debugged trace
//! prefixes replayable).

use crate::testing::Rng;
use crate::util::json::Json;

/// Fault injected into a [`SimOp::FullRun`]. Node indices are model
/// table indices (0..[`PLAN_LEN`](crate::sim::PLAN_LEN)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunFault {
    /// Healthy run.
    None,
    /// `FailurePlan::crash_before(node)`.
    CrashBefore(u8),
    /// `FailurePlan::crash_after(node)`.
    CrashAfter(u8),
    /// `FailurePlan::kill_after(node)`: the process dies — no abort
    /// bookkeeping; the txn branch stays `Open` until recovery.
    KillAfter(u8),
    /// A step-3 verifier that always vetoes the publish.
    FailingVerifier,
    /// The catalog journal dies after `n` more appends mid-run — the
    /// paper's durability crash points, swept one position at a time.
    /// The generator always schedules a [`SimOp::CrashRecover`] next.
    JournalCrash(u8),
}

/// Where an agent forks its branch from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentSource {
    /// Fork from `main` (always legal).
    Main,
    /// Fork from run `.0`'s transactional branch after it aborted — the
    /// Fig. 4 move. With guardrails on the driver *expects refusal*.
    AbortedTxn(u8),
}

/// One step of a simulated workload.
#[derive(Debug, Clone, PartialEq)]
pub enum SimOp {
    /// Start fine-grained run `runs.len()` (transactional or direct).
    BeginRun {
        /// Use the paper's transactional protocol (vs direct writes).
        transactional: bool,
    },
    /// Run `run` commits its next plan table to its execution branch.
    StepRun {
        /// Fine-grained run index.
        run: u8,
    },
    /// Run `run` fails cleanly: abort bookkeeping runs (txn branch →
    /// `Aborted`).
    FailRun {
        /// Fine-grained run index.
        run: u8,
    },
    /// The process executing run `run` dies: no abort bookkeeping; its
    /// txn branch stays `Open` until a [`SimOp::CrashRecover`].
    KillRun {
        /// Fine-grained run index.
        run: u8,
    },
    /// Run `run` publishes: merge its txn branch into main (or, for a
    /// direct run, simply finish).
    PublishRun {
        /// Fine-grained run index.
        run: u8,
    },
    /// The agent forks a branch.
    AgentFork {
        /// Fork source.
        from: AgentSource,
    },
    /// The agent merges its branch into main (the Fig. 4 payload when
    /// the branch came from an aborted txn branch).
    AgentMerge,
    /// Rebase run `run`'s open transactional branch onto main's current
    /// head (`Catalog::rebase` — the delta-replay path; refused
    /// atomically on conflicts).
    RebaseRun {
        /// Fine-grained run index.
        run: u8,
    },
    /// Cherry-pick the head commit of run `run`'s *aborted* branch onto
    /// main (`Catalog::cherry_pick`) — the commit-addressed variant of
    /// the Fig. 4 leak; generated only with guardrails off.
    CherryPickToMain {
        /// Fine-grained run index owning the aborted branch.
        run: u8,
    },
    /// A complete `Runner` execution of the paper pipeline against main.
    FullRun {
        /// Transactional protocol vs direct writes.
        transactional: bool,
        /// Wavefront width handed to the scheduler.
        jobs: u8,
        /// Injected fault, if any.
        fault: RunFault,
        /// Fire the pause hook mid-run to commit a (non-plan) table to
        /// main between two node commits — concurrent-actor
        /// interleaving inside the run.
        mid_run_write: bool,
    },
    /// Another tenant commits a non-plan table to main (forces non-fast-
    /// forward publish merges; invisible to the model projection).
    EnvWrite,
    /// `Catalog::gc()`.
    Gc,
    /// `Catalog::checkpoint()` (bounds the next recovery's replay).
    Checkpoint,
    /// `Catalog::journal_rotate()`: seal the active journal segment and
    /// start a fresh one mid-trace, so recovery crosses segment
    /// boundaries the maintenance schedule didn't pick.
    RotateSegment,
    /// `Catalog::compact()`: fold the delta chain into a base snapshot
    /// and retire covered journal segments mid-trace.
    Compact,
    /// The journal starts failing *now* (every later append dies). The
    /// generator always emits one victim op and then a
    /// [`SimOp::CrashRecover`] — the write-ahead-discipline probe.
    JournalCrash,
    /// The process dies and restarts: `Catalog::recover` twice (the
    /// idempotence oracle), then the driver rebuilds its stack on the
    /// recovered catalog.
    CrashRecover,
}

/// Trace-generation knobs shared with [`SimConfig`](crate::sim::SimConfig).
pub(crate) struct GenParams {
    pub ops: usize,
    pub guardrail: bool,
}

/// Mirror of the abstract state, just rich enough to keep emitted ops
/// mostly applicable.
#[derive(Default)]
struct GenState {
    /// (transactional, idx, running) per fine-grained run.
    runs: Vec<(bool, u8, bool)>,
    /// Fine-grained run indices with an aborted (visible) txn branch.
    aborted: Vec<u8>,
    /// Killed txn runs whose branch is still `Open` (aborts on recover).
    orphans: Vec<u8>,
    agent_open: bool,
    /// Total model runs begun (fine-grained + full), bounds trace size.
    total_runs: usize,
    /// Maintenance ops emitted so far; cycles checkpoint → rotate →
    /// compact without spending RNG draws (pinned seeds stay valid).
    maintenance: usize,
}

impl GenState {
    fn running(&self) -> Vec<u8> {
        self.runs
            .iter()
            .enumerate()
            .filter(|(_, (_, _, running))| *running)
            .map(|(i, _)| i as u8)
            .collect()
    }

    fn recover(&mut self) {
        for (i, (transactional, _, running)) in self.runs.iter_mut().enumerate() {
            if *running {
                *running = false;
                if *transactional {
                    self.aborted.push(i as u8);
                }
            }
        }
        self.aborted.append(&mut self.orphans);
        self.aborted.sort_unstable();
        self.aborted.dedup();
    }
}

/// Generate a trace of roughly `params.ops` ops from `rng`.
pub(crate) fn generate(rng: &mut Rng, params: &GenParams) -> Vec<SimOp> {
    let mut trace = Vec::with_capacity(params.ops + 4);
    let mut st = GenState::default();
    while trace.len() < params.ops {
        emit(rng, params, &mut st, &mut trace);
    }
    trace
}

/// Public convenience wrapper ([`generate`] with a fresh seeded RNG).
pub fn generate_trace(seed: u64, ops: usize, guardrail: bool) -> Vec<SimOp> {
    let mut rng = Rng::new(seed);
    generate(&mut rng, &GenParams { ops, guardrail })
}

fn emit(rng: &mut Rng, params: &GenParams, st: &mut GenState, trace: &mut Vec<SimOp>) {
    let running = st.running();
    // (weight, candidate) pairs; weights tuned so guardrail-off traces
    // reach both Fig. 3 (direct partial writes) and Fig. 4 (aborted
    // fork + merge) shapes within a few dozen ops
    let mut moves: Vec<(u32, u8)> = Vec::new();
    if running.len() < 3 && st.total_runs < 10 {
        moves.push((12, 0)); // BeginRun
    }
    if !running.is_empty() {
        moves.push((30, 1)); // StepRun
        moves.push((5, 2)); // FailRun
        moves.push((4, 3)); // KillRun
    }
    if st
        .runs
        .iter()
        .any(|(_, idx, running)| *running && *idx == crate::sim::PLAN_LEN)
    {
        moves.push((18, 4)); // PublishRun
    }
    if !st.agent_open {
        let w = if !params.guardrail && !st.aborted.is_empty() {
            14
        } else {
            5
        };
        moves.push((w, 5)); // AgentFork
    } else {
        moves.push((12, 6)); // AgentMerge
    }
    if st.total_runs < 10 {
        moves.push((8, 7)); // FullRun
    }
    moves.push((4, 8)); // EnvWrite
    moves.push((2, 9)); // Gc
    moves.push((2, 10)); // maintenance: Checkpoint / RotateSegment / Compact
    moves.push((3, 11)); // JournalCrash triple
    moves.push((2, 12)); // CrashRecover
    if st.runs.iter().any(|(t, _, running)| *t && *running) {
        moves.push((4, 13)); // RebaseRun
    }
    if !params.guardrail && !st.aborted.is_empty() {
        moves.push((8, 14)); // CherryPickToMain (the attack variant)
    }

    let total: u32 = moves.iter().map(|(w, _)| w).sum();
    let mut pick = (rng.next_u64() % total as u64) as u32;
    let mut chosen = moves[0].1;
    for (w, m) in &moves {
        if pick < *w {
            chosen = *m;
            break;
        }
        pick -= w;
    }

    match chosen {
        0 => {
            // guardrail on = the paper's stack: every run transactional;
            // off = today's lakehouse: direct writes show up
            let transactional = params.guardrail || rng.bool(0.55);
            st.runs.push((transactional, 0, true));
            st.total_runs += 1;
            trace.push(SimOp::BeginRun { transactional });
        }
        1 => {
            let r = *rng.pick(&running);
            let (_, idx, _) = &mut st.runs[r as usize];
            if *idx < crate::sim::PLAN_LEN {
                *idx += 1;
                trace.push(SimOp::StepRun { run: r });
            }
        }
        2 => {
            let r = *rng.pick(&running);
            let (transactional, _, running) = &mut st.runs[r as usize];
            *running = false;
            if *transactional {
                st.aborted.push(r);
            }
            trace.push(SimOp::FailRun { run: r });
        }
        3 => {
            let r = *rng.pick(&running);
            let (transactional, _, running) = &mut st.runs[r as usize];
            *running = false;
            if *transactional {
                st.orphans.push(r);
            }
            trace.push(SimOp::KillRun { run: r });
        }
        4 => {
            let complete: Vec<u8> = st
                .runs
                .iter()
                .enumerate()
                .filter(|(_, (_, idx, running))| *running && *idx == crate::sim::PLAN_LEN)
                .map(|(i, _)| i as u8)
                .collect();
            let r = *rng.pick(&complete);
            st.runs[r as usize].2 = false;
            trace.push(SimOp::PublishRun { run: r });
        }
        5 => {
            // prefer the aborted-branch fork when one is available: with
            // guardrails on the driver asserts refusal, off it is the
            // Fig. 4 setup
            let p_aborted = if params.guardrail { 0.5 } else { 0.85 };
            let from = if !st.aborted.is_empty() && rng.bool(p_aborted) {
                AgentSource::AbortedTxn(*rng.pick(&st.aborted))
            } else {
                AgentSource::Main
            };
            // refused forks leave no agent; mirror optimistically only
            // when the fork can succeed
            let succeeds = match from {
                AgentSource::Main => true,
                AgentSource::AbortedTxn(_) => !params.guardrail,
            };
            if succeeds {
                st.agent_open = true;
            }
            trace.push(SimOp::AgentFork { from });
        }
        6 => {
            st.agent_open = false;
            trace.push(SimOp::AgentMerge);
        }
        7 => {
            let transactional = params.guardrail || rng.bool(0.7);
            let jobs = if rng.bool(0.5) { 4 } else { 1 };
            let fault = match rng.below(100) {
                0..=54 => RunFault::None,
                55..=62 => RunFault::CrashBefore(rng.below(3) as u8),
                63..=70 => RunFault::CrashAfter(rng.below(3) as u8),
                71..=78 => RunFault::KillAfter(rng.below(3) as u8),
                79..=86 => RunFault::FailingVerifier,
                _ => RunFault::JournalCrash(rng.below(10) as u8),
            };
            let mid_run_write = rng.bool(0.25);
            st.total_runs += 1;
            match fault {
                RunFault::None => {}
                RunFault::KillAfter(_) if transactional => {
                    st.orphans.push(st.runs.len() as u8); // approximate
                }
                RunFault::JournalCrash(_) => {}
                _ if transactional => st.aborted.push(st.runs.len() as u8),
                _ => {}
            }
            // the mirror's fine-grained indices no longer line up after a
            // FullRun (it occupies a model run slot); pad so later
            // fine-grained ops still reference live runs — the driver
            // skips any that miss
            st.runs.push((transactional, crate::sim::PLAN_LEN, false));
            trace.push(SimOp::FullRun { transactional, jobs, fault, mid_run_write });
            if matches!(fault, RunFault::JournalCrash(_)) {
                st.recover();
                trace.push(SimOp::CrashRecover);
            }
        }
        8 => trace.push(SimOp::EnvWrite),
        9 => trace.push(SimOp::Gc),
        10 => {
            // cycle the three maintenance ops deterministically — no RNG
            // draw, so traces before this op are unchanged across seeds
            trace.push(match st.maintenance % 3 {
                0 => SimOp::Checkpoint,
                1 => SimOp::RotateSegment,
                _ => SimOp::Compact,
            });
            st.maintenance += 1;
        }
        11 => {
            // the write-ahead-discipline probe: journal dies, one victim
            // op must leave no trace, then the process restarts
            trace.push(SimOp::JournalCrash);
            let victim = match rng.below(4) {
                0 if !running.is_empty() => SimOp::StepRun { run: *rng.pick(&running) },
                1 => SimOp::EnvWrite,
                2 => SimOp::BeginRun { transactional: true },
                _ => SimOp::Gc,
            };
            trace.push(victim);
            st.recover();
            trace.push(SimOp::CrashRecover);
        }
        13 => {
            let txn_running: Vec<u8> = st
                .runs
                .iter()
                .enumerate()
                .filter(|(_, (t, _, running))| *t && *running)
                .map(|(i, _)| i as u8)
                .collect();
            trace.push(SimOp::RebaseRun { run: *rng.pick(&txn_running) });
        }
        14 => {
            trace.push(SimOp::CherryPickToMain { run: *rng.pick(&st.aborted) });
        }
        _ => {
            st.recover();
            trace.push(SimOp::CrashRecover);
        }
    }
}

// ---------------------------------------------------------------- JSON

impl RunFault {
    fn to_json(self) -> Json {
        let (kind, node) = match self {
            RunFault::None => ("none", None),
            RunFault::CrashBefore(n) => ("crash_before", Some(n)),
            RunFault::CrashAfter(n) => ("crash_after", Some(n)),
            RunFault::KillAfter(n) => ("kill_after", Some(n)),
            RunFault::FailingVerifier => ("failing_verifier", None),
            RunFault::JournalCrash(n) => ("journal_crash", Some(n)),
        };
        let mut pairs = vec![("kind", Json::str(kind))];
        if let Some(n) = node {
            pairs.push(("node", Json::num(n as f64)));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Option<RunFault> {
        let node = || j.get("node").as_usize().map(|n| n as u8);
        Some(match j.get("kind").as_str()? {
            "none" => RunFault::None,
            "crash_before" => RunFault::CrashBefore(node()?),
            "crash_after" => RunFault::CrashAfter(node()?),
            "kill_after" => RunFault::KillAfter(node()?),
            "failing_verifier" => RunFault::FailingVerifier,
            "journal_crash" => RunFault::JournalCrash(node()?),
            _ => return None,
        })
    }
}

impl SimOp {
    /// Canonical-JSON encoding of one op.
    pub fn to_json(&self) -> Json {
        match self {
            SimOp::BeginRun { transactional } => Json::obj(vec![
                ("op", Json::str("begin_run")),
                ("transactional", Json::Bool(*transactional)),
            ]),
            SimOp::StepRun { run } => Json::obj(vec![
                ("op", Json::str("step_run")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::FailRun { run } => Json::obj(vec![
                ("op", Json::str("fail_run")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::KillRun { run } => Json::obj(vec![
                ("op", Json::str("kill_run")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::PublishRun { run } => Json::obj(vec![
                ("op", Json::str("publish_run")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::AgentFork { from } => {
                let mut pairs = vec![("op", Json::str("agent_fork"))];
                match from {
                    AgentSource::Main => pairs.push(("from", Json::str("main"))),
                    AgentSource::AbortedTxn(r) => {
                        pairs.push(("from", Json::str("aborted_txn")));
                        pairs.push(("run", Json::num(*r as f64)));
                    }
                }
                Json::obj(pairs)
            }
            SimOp::AgentMerge => Json::obj(vec![("op", Json::str("agent_merge"))]),
            SimOp::RebaseRun { run } => Json::obj(vec![
                ("op", Json::str("rebase_run")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::CherryPickToMain { run } => Json::obj(vec![
                ("op", Json::str("cherry_pick")),
                ("run", Json::num(*run as f64)),
            ]),
            SimOp::FullRun { transactional, jobs, fault, mid_run_write } => Json::obj(vec![
                ("op", Json::str("full_run")),
                ("transactional", Json::Bool(*transactional)),
                ("jobs", Json::num(*jobs as f64)),
                ("fault", fault.to_json()),
                ("mid_run_write", Json::Bool(*mid_run_write)),
            ]),
            SimOp::EnvWrite => Json::obj(vec![("op", Json::str("env_write"))]),
            SimOp::Gc => Json::obj(vec![("op", Json::str("gc"))]),
            SimOp::Checkpoint => Json::obj(vec![("op", Json::str("checkpoint"))]),
            SimOp::RotateSegment => Json::obj(vec![("op", Json::str("rotate_segment"))]),
            SimOp::Compact => Json::obj(vec![("op", Json::str("compact"))]),
            SimOp::JournalCrash => Json::obj(vec![("op", Json::str("journal_crash"))]),
            SimOp::CrashRecover => Json::obj(vec![("op", Json::str("crash_recover"))]),
        }
    }

    /// Inverse of [`SimOp::to_json`]; `None` on malformed input.
    pub fn from_json(j: &Json) -> Option<SimOp> {
        let run = || j.get("run").as_usize().map(|n| n as u8);
        Some(match j.get("op").as_str()? {
            "begin_run" => SimOp::BeginRun { transactional: j.get("transactional").as_bool()? },
            "step_run" => SimOp::StepRun { run: run()? },
            "fail_run" => SimOp::FailRun { run: run()? },
            "kill_run" => SimOp::KillRun { run: run()? },
            "publish_run" => SimOp::PublishRun { run: run()? },
            "agent_fork" => SimOp::AgentFork {
                from: match j.get("from").as_str()? {
                    "main" => AgentSource::Main,
                    "aborted_txn" => AgentSource::AbortedTxn(run()?),
                    _ => return None,
                },
            },
            "agent_merge" => SimOp::AgentMerge,
            "rebase_run" => SimOp::RebaseRun { run: run()? },
            "cherry_pick" => SimOp::CherryPickToMain { run: run()? },
            "full_run" => SimOp::FullRun {
                transactional: j.get("transactional").as_bool()?,
                jobs: j.get("jobs").as_usize()? as u8,
                fault: RunFault::from_json(j.get("fault"))?,
                mid_run_write: j.get("mid_run_write").as_bool()?,
            },
            "env_write" => SimOp::EnvWrite,
            "gc" => SimOp::Gc,
            "checkpoint" => SimOp::Checkpoint,
            "rotate_segment" => SimOp::RotateSegment,
            "compact" => SimOp::Compact,
            "journal_crash" => SimOp::JournalCrash,
            "crash_recover" => SimOp::CrashRecover,
            _ => return None,
        })
    }
}

/// Encode a whole trace as a canonical JSON array.
pub fn trace_to_json(trace: &[SimOp]) -> Json {
    Json::Arr(trace.iter().map(|o| o.to_json()).collect())
}

/// Inverse of [`trace_to_json`]; `None` if any element is malformed.
pub fn trace_from_json(j: &Json) -> Option<Vec<SimOp>> {
    j.as_arr()?.iter().map(SimOp::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate_trace(7, 40, true);
        let b = generate_trace(7, 40, true);
        assert_eq!(a, b);
        assert!(a.len() >= 40);
    }

    #[test]
    fn trace_json_roundtrips() {
        for guardrail in [true, false] {
            for seed in 1..=5u64 {
                let t = generate_trace(seed, 30, guardrail);
                let j = trace_to_json(&t);
                // through text, like the CLI's --ops-file path
                let parsed = Json::parse(&j.to_string()).unwrap();
                assert_eq!(trace_from_json(&parsed).unwrap(), t);
            }
        }
    }

    #[test]
    fn journal_crash_is_always_followed_by_recover() {
        for seed in 1..=20u64 {
            let t = generate_trace(seed, 60, true);
            for (i, op) in t.iter().enumerate() {
                if matches!(op, SimOp::JournalCrash) {
                    assert!(
                        matches!(t.get(i + 2), Some(SimOp::CrashRecover)),
                        "seed {seed}: JournalCrash at {i} not followed by victim+recover"
                    );
                }
                if let SimOp::FullRun { fault: RunFault::JournalCrash(_), .. } = op {
                    assert!(
                        matches!(t.get(i + 1), Some(SimOp::CrashRecover)),
                        "seed {seed}: journal-faulted FullRun at {i} not followed by recover"
                    );
                }
            }
        }
    }
}
