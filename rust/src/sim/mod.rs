//! Deterministic lakehouse simulator (FoundationDB-style).
//!
//! The paper validates "illegal states are unrepresentable" only in a
//! small-scope Alloy model (§4); `model/` ports that model, but nothing
//! checked that the **real** catalog / journal / scheduler / cache stack
//! actually refines it under concurrency and crashes. This module closes
//! the gap: a seeded generator ([`generator`]) produces randomized
//! multi-agent op traces — interleaved transactional and direct-write
//! runs, agent branch forks and merges, full `Runner` executions at
//! `jobs > 1` with cache hits and evictions, GC, checkpoints, process
//! kills and journal crash points — and a conformance driver
//! ([`driver`]) executes every trace *twice in lockstep*: once through
//! [`ModelState`](crate::model::ModelState) (via the projection API
//! `ModelState::apply`) and once through the real
//! [`Catalog`](crate::catalog::Catalog) + [`Runner`](crate::runs::Runner)
//! + sim compute backend.
//!
//! After every op the oracles ([`oracles`]) assert:
//!
//! 1. **refinement** — every live real branch projects onto the tracked
//!    model branch (same lifecycle phase, same plan-table map under the
//!    driver's snapshot bijection);
//! 2. **main consistency** (Fig. 3) — the plan tables on `main` were all
//!    written by one run, or none;
//! 3. **aborted-branch visibility** (Fig. 4) — with guardrails on, every
//!    fork/merge of an aborted transactional branch is refused;
//! 4. **recovery idempotence** — after every injected crash (and at the
//!    end of every trace) two consecutive `Catalog::recover` calls
//!    produce byte-identical exports.
//!
//! Failing seeds shrink to a minimal trace by delta debugging
//! ([`shrinker`]) and replay via `bauplan simulate --seed N` /
//! `--ops-file trace.json`. With `--no-guardrail` the same oracles
//! rediscover the paper's Fig. 3 and Fig. 4 counterexamples — proof the
//! oracles have teeth. Spec: `doc/SIMULATION.md`.
#![warn(missing_docs)]

pub mod driver;
pub mod generator;
pub mod oracles;
pub mod shrinker;

pub use driver::{replay, simulate, SimConfig, SimReport};
pub use generator::{generate_trace, trace_from_json, trace_to_json, AgentSource, RunFault, SimOp};
pub use oracles::{Violation, ViolationKind};
pub use shrinker::shrink;

/// The model's plan tables, in plan order: model table index `k` is the
/// real pipeline's `PLAN_TABLES[k]`. These are exactly the outputs of
/// the paper pipeline, so fine-grained simulated runs and full `Runner`
/// executions write the same model-visible tables.
pub const PLAN_TABLES: [&str; 3] = ["parent_table", "child_table", "grand_child"];

/// Number of plan tables (the model scope's `plan_len`).
pub const PLAN_LEN: u8 = PLAN_TABLES.len() as u8;
