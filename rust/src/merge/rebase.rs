//! Rebase and cherry-pick — the "richer set of logic and conditions"
//! the paper says can be defined on top of table snapshots (§3.2).
//!
//! Both are replay operations over table-map deltas:
//!
//! - `cherry_pick(commit, onto)` applies one commit's delta (vs its first
//!   parent) as a fresh commit on `onto`;
//! - `rebase(branch, onto)` replays every first-parent commit of `branch`
//!   since its fork point on top of `onto`'s head, then moves `branch`.
//!
//! Conflicts follow the merge rule: a delta that touches a table the
//! destination changed since the fork point aborts the operation (the
//! catalog is left untouched — rebases are atomic too).

use std::collections::BTreeMap;

use crate::catalog::commit::Commit;
use crate::catalog::Catalog;
use crate::catalog::snapshot::SnapshotId;
use crate::error::{BauplanError, Result};

/// The table-level delta a commit introduced relative to a base map.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    /// table -> Some(new snapshot) | None (removed)
    pub changes: BTreeMap<String, Option<SnapshotId>>,
}

impl Delta {
    /// Delta of `commit` vs `parent_tables`.
    pub fn between(
        parent_tables: &BTreeMap<String, SnapshotId>,
        commit: &Commit,
    ) -> Delta {
        let mut changes = BTreeMap::new();
        for (t, s) in &commit.tables {
            if parent_tables.get(t) != Some(s) {
                changes.insert(t.clone(), Some(s.clone()));
            }
        }
        for t in parent_tables.keys() {
            if !commit.tables.contains_key(t) {
                changes.insert(t.clone(), None);
            }
        }
        Delta { changes }
    }

    /// Apply onto a table map.
    pub fn apply(&self, tables: &mut BTreeMap<String, SnapshotId>) {
        for (t, change) in &self.changes {
            match change {
                Some(s) => {
                    tables.insert(t.clone(), s.clone());
                }
                None => {
                    tables.remove(t);
                }
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }
}

impl Catalog {
    /// Apply one commit's delta on top of branch `onto`.
    ///
    /// Same conflict rule as [`Catalog::rebase`]: a delta that touches a
    /// table whose `onto` value differs from the picked commit's parent
    /// view aborts — silently overwriting a concurrent change would be
    /// the Fig. 3 lost-update, one table at a time.
    pub fn cherry_pick(&self, commit_ref: &str, onto: &str) -> Result<String> {
        let commit = self.get_commit(&self.resolve(commit_ref)?)?;
        let parent_tables = match commit.parents.first() {
            Some(p) => self.get_commit(p)?.tables,
            None => BTreeMap::new(),
        };
        let delta = Delta::between(&parent_tables, &commit);
        if delta.is_empty() {
            return self.resolve(onto);
        }
        let onto_tables = self.get_commit(&self.resolve(onto)?)?.tables;
        for t in delta.changes.keys() {
            if onto_tables.get(t) != parent_tables.get(t) {
                return Err(BauplanError::MergeConflict(format!(
                    "cherry-pick: '{t}' changed on '{onto}' since the picked \
                     commit's parent")));
            }
        }
        self.apply_deltas(onto, &[(delta, commit.message.clone(), commit.run_id.clone())])
    }

    /// Replay `branch`'s commits since its fork point from `onto` on top
    /// of `onto`'s current head, then fast-forward `branch` there.
    pub fn rebase(&self, branch: &str, onto: &str) -> Result<String> {
        let branch_head = self.resolve(branch)?;
        let onto_head = self.resolve(onto)?;
        if self.is_ancestor(&branch_head, &onto_head)? {
            // nothing unique on branch: just move it
            self.force_branch(branch, &onto_head)?;
            return Ok(onto_head);
        }
        if self.is_ancestor(&onto_head, &branch_head)? {
            return Ok(branch_head); // already based on onto
        }
        // collect first-parent chain from branch head down to the LCA
        let mut chain: Vec<Commit> = Vec::new();
        let mut cur = branch_head.clone();
        loop {
            if self.is_ancestor(&cur, &onto_head)? {
                break; // cur is the common base
            }
            let c = self.get_commit(&cur)?;
            let parent = c.parents.first().cloned();
            chain.push(c);
            match parent {
                Some(p) => cur = p,
                None => break,
            }
        }
        chain.reverse();

        // deltas, oldest first
        let mut deltas = Vec::new();
        for c in &chain {
            let parent_tables = match c.parents.first() {
                Some(p) => self.get_commit(p)?.tables,
                None => BTreeMap::new(),
            };
            let d = Delta::between(&parent_tables, c);
            if !d.is_empty() {
                deltas.push((d, c.message.clone(), c.run_id.clone()));
            }
        }

        // conflict rule: a replayed delta must not touch tables that
        // changed on `onto` since the base
        let base_tables = self.get_commit(&cur)?.tables;
        let onto_tables = self.get_commit(&onto_head)?.tables;
        for (d, msg, _) in &deltas {
            for t in d.changes.keys() {
                if onto_tables.get(t) != base_tables.get(t) {
                    return Err(BauplanError::MergeConflict(format!(
                        "rebase: '{t}' changed on both sides (while replaying '{msg}')")));
                }
            }
        }

        let new_head = self.apply_deltas(onto, &deltas)?;
        self.force_branch(branch, &new_head)?;
        Ok(new_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Snapshot, MAIN};
    use crate::storage::ObjectStore;
    use crate::testing::commit_table;
    use std::sync::Arc;

    fn snap(tag: &str) -> Snapshot {
        Snapshot::new(vec![tag.into()], "S", "fp", 1, "r")
    }

    fn setup() -> Catalog {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        commit_table(&c, MAIN, "base", snap("b0"), "u", "m", None).unwrap();
        c
    }

    #[test]
    fn cherry_pick_applies_single_delta() {
        let c = setup();
        c.create_branch("dev", MAIN, false).unwrap();
        let picked =
            commit_table(&c, "dev", "feature", snap("f"), "u", "add feature", None).unwrap();
        commit_table(&c, "dev", "other", snap("o"), "u", "noise", None).unwrap();

        c.cherry_pick(&picked, MAIN).unwrap();
        let main = c.read_ref(MAIN).unwrap();
        assert!(main.tables.contains_key("feature"));
        assert!(!main.tables.contains_key("other")); // only the one delta
        assert_eq!(main.message, "add feature");
    }

    #[test]
    fn rebase_replays_chain_in_order() {
        let c = setup();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "a", snap("a"), "u", "wa", None).unwrap();
        commit_table(&c, "dev", "b", snap("b"), "u", "wb", None).unwrap();
        // main moves forward independently (disjoint table)
        commit_table(&c, MAIN, "m", snap("m"), "u", "wm", None).unwrap();

        c.rebase("dev", MAIN).unwrap();
        let dev = c.read_ref("dev").unwrap();
        // dev now contains main's table AND its own, linear on top
        assert!(dev.tables.contains_key("m"));
        assert!(dev.tables.contains_key("a"));
        assert!(dev.tables.contains_key("b"));
        // linear history: replayed commits, newest is "wb"
        assert_eq!(dev.message, "wb");
        assert!(c.is_ancestor(MAIN, "dev").unwrap());
        // merge after rebase is a fast-forward
        let ff = c.merge("dev", MAIN, false).unwrap();
        assert_eq!(ff, c.resolve("dev").unwrap());
    }

    #[test]
    fn rebase_conflict_leaves_everything_untouched() {
        let c = setup();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "t", snap("dev"), "u", "dev write", None).unwrap();
        commit_table(&c, MAIN, "t", snap("main"), "u", "main write", None).unwrap();
        let dev_before = c.resolve("dev").unwrap();
        let main_before = c.resolve(MAIN).unwrap();
        let err = c.rebase("dev", MAIN).unwrap_err();
        assert!(matches!(err, BauplanError::MergeConflict(_)));
        assert_eq!(c.resolve("dev").unwrap(), dev_before);
        assert_eq!(c.resolve(MAIN).unwrap(), main_before);
    }

    #[test]
    fn rebase_txn_branch_conflicts_when_target_advanced_same_table() {
        // the delta-replay conflict path for the branches the run engine
        // actually creates: a txn branch writes `base` while the target
        // advances `base` concurrently — replay must refuse atomically
        let c = setup();
        c.create_txn_branch(MAIN, "r7").unwrap();
        commit_table(
            &c,
            "txn/r7",
            "base",
            snap("txn"),
            "runner",
            "run r7: write base",
            Some("r7".into()),
        )
        .unwrap();
        commit_table(&c, MAIN, "base", snap("main2"), "u", "concurrent write", None).unwrap();

        let txn_before = c.resolve("txn/r7").unwrap();
        let main_before = c.resolve(MAIN).unwrap();
        let err = c.rebase("txn/r7", MAIN).unwrap_err();
        assert!(matches!(err, BauplanError::MergeConflict(_)));
        assert!(err.to_string().contains("base"), "{err}");
        // atomic: neither side moved, no replay commits leaked
        assert_eq!(c.resolve("txn/r7").unwrap(), txn_before);
        assert_eq!(c.resolve(MAIN).unwrap(), main_before);

        // cherry-picking the conflicting commit is refused the same way
        let err = c.cherry_pick(&txn_before, MAIN).unwrap_err();
        assert!(matches!(err, BauplanError::MergeConflict(_)));
        assert_eq!(c.resolve(MAIN).unwrap(), main_before);
    }

    #[test]
    fn rebase_txn_branch_replays_disjoint_deltas_onto_advanced_target() {
        // the success-path contrast: the txn branch's table is untouched
        // on the target, so its delta replays cleanly on the new head
        let c = setup();
        c.create_txn_branch(MAIN, "r8").unwrap();
        commit_table(
            &c,
            "txn/r8",
            "out",
            snap("o1"),
            "runner",
            "run r8: write out",
            Some("r8".into()),
        )
        .unwrap();
        commit_table(&c, MAIN, "base", snap("main2"), "u", "m", None).unwrap();

        let out_snap = c.read_ref("txn/r8").unwrap().tables["out"].clone();
        c.rebase("txn/r8", MAIN).unwrap();
        let head = c.read_ref("txn/r8").unwrap();
        // delta replay preserved the txn write and picked up the advance
        assert_eq!(head.tables["out"], out_snap);
        assert_eq!(head.tables["base"], snap("main2").id);
        assert!(c.is_ancestor(MAIN, "txn/r8").unwrap());
        // run provenance survives the replayed commit
        assert_eq!(head.run_id, Some("r8".into()));
        // and the publish is now a fast-forward
        let ff = c.merge("txn/r8", MAIN, false).unwrap();
        assert_eq!(ff, c.resolve("txn/r8").unwrap());
    }

    #[test]
    fn rebase_of_contained_branch_fast_forwards() {
        let c = setup();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, MAIN, "x", snap("x"), "u", "m", None).unwrap();
        let main_head = c.resolve(MAIN).unwrap();
        c.rebase("dev", MAIN).unwrap();
        assert_eq!(c.resolve("dev").unwrap(), main_head);
    }

    #[test]
    fn delta_between_and_apply_roundtrip() {
        let mut base = BTreeMap::new();
        base.insert("keep".to_string(), "s0".to_string());
        base.insert("change".to_string(), "s0".to_string());
        base.insert("drop".to_string(), "s0".to_string());
        let mut commit_tables = base.clone();
        commit_tables.insert("change".to_string(), "s1".to_string());
        commit_tables.insert("new".to_string(), "s2".to_string());
        commit_tables.remove("drop");
        let commit = Commit::new(vec![], commit_tables.clone(), "u", "m", None);
        let d = Delta::between(&base, &commit);
        assert_eq!(d.changes.len(), 3);
        let mut applied = base.clone();
        d.apply(&mut applied);
        assert_eq!(applied, commit_tables);
    }
}
