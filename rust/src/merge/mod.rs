//! Merge resolution: the pure three-way table-map merge.
//!
//! Merges here are *logical* (paper §3.2): no data moves, only
//! `table -> snapshot` pointers combine. Given the lowest common ancestor
//! `base` and the two heads, per table:
//!
//! | base | src | dst | result |
//! |------|-----|-----|--------|
//! | unchanged in both | — | — | keep |
//! | changed in src only | — | — | take src |
//! | changed in dst only | — | — | take dst |
//! | changed in both, equal | — | — | take either (convergent) |
//! | changed in both, different | — | — | **conflict** |
//!
//! "Changed" covers add/modify/remove. The catalog applies the resolved
//! map atomically (one merge commit, two parents), so readers of the
//! destination observe the entire merge or none of it — the primitive the
//! transactional-run protocol (§3.3) builds on.

pub mod rebase;

use std::collections::BTreeMap;

use crate::catalog::commit::Commit;
use crate::catalog::snapshot::SnapshotId;
use crate::error::{BauplanError, Result};

/// Result of a three-way merge computation.
#[derive(Debug, Clone, PartialEq)]
pub enum MergeOutcome {
    /// src introduced no changes relative to base.
    AlreadyMerged,
    /// The combined table map to commit on the destination.
    Merged(BTreeMap<String, SnapshotId>),
}

/// Pure three-way merge over table maps; conflicts abort with the list of
/// conflicting tables.
pub fn compute_merge(base: &Commit, src: &Commit, dst: &Commit) -> Result<MergeOutcome> {
    let mut all_tables: Vec<&String> = base
        .tables
        .keys()
        .chain(src.tables.keys())
        .chain(dst.tables.keys())
        .collect();
    all_tables.sort();
    all_tables.dedup();

    let mut out = BTreeMap::new();
    let mut conflicts = Vec::new();
    let mut src_changed_any = false;

    for t in all_tables {
        let b = base.tables.get(t);
        let s = src.tables.get(t);
        let d = dst.tables.get(t);
        let src_changed = s != b;
        let dst_changed = d != b;
        src_changed_any |= src_changed;
        let winner = match (src_changed, dst_changed) {
            (false, false) => b,
            (true, false) => s,
            (false, true) => d,
            (true, true) => {
                if s == d {
                    s // convergent change
                } else {
                    conflicts.push(t.clone());
                    continue;
                }
            }
        };
        if let Some(snap) = winner {
            out.insert(t.clone(), snap.clone());
        }
        // winner == None means the table was removed on the winning side.
    }

    if !conflicts.is_empty() {
        return Err(BauplanError::MergeConflict(format!(
            "tables changed on both sides: {}",
            conflicts.join(", ")
        )));
    }
    if !src_changed_any {
        return Ok(MergeOutcome::AlreadyMerged);
    }
    Ok(MergeOutcome::Merged(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn commit(tables: &[(&str, &str)]) -> Commit {
        let map: BTreeMap<String, String> = tables
            .iter()
            .map(|(t, s)| (t.to_string(), s.to_string()))
            .collect();
        Commit::new(vec![], map, "t", "m", None)
    }

    #[test]
    fn disjoint_changes_combine() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[("x", "s0"), ("a", "sa")]);
        let dst = commit(&[("x", "s0"), ("b", "sb")]);
        let MergeOutcome::Merged(m) = compute_merge(&base, &src, &dst).unwrap() else {
            panic!()
        };
        assert_eq!(m.len(), 3);
        assert_eq!(m["a"], "sa");
        assert_eq!(m["b"], "sb");
        assert_eq!(m["x"], "s0");
    }

    #[test]
    fn src_modification_wins_when_dst_untouched() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[("x", "s1")]);
        let dst = commit(&[("x", "s0")]);
        let MergeOutcome::Merged(m) = compute_merge(&base, &src, &dst).unwrap() else {
            panic!()
        };
        assert_eq!(m["x"], "s1");
    }

    #[test]
    fn both_changed_differently_is_conflict() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[("x", "s1")]);
        let dst = commit(&[("x", "s2")]);
        let err = compute_merge(&base, &src, &dst).unwrap_err();
        assert!(err.to_string().contains("x"));
    }

    #[test]
    fn convergent_changes_are_not_conflicts() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[("x", "s1")]);
        let dst = commit(&[("x", "s1")]);
        let MergeOutcome::Merged(m) = compute_merge(&base, &src, &dst).unwrap() else {
            panic!()
        };
        assert_eq!(m["x"], "s1");
    }

    #[test]
    fn removal_propagates() {
        let base = commit(&[("x", "s0"), ("y", "s0")]);
        let src = commit(&[("y", "s0")]); // src removed x
        let dst = commit(&[("x", "s0"), ("y", "s1")]); // dst changed y
        let MergeOutcome::Merged(m) = compute_merge(&base, &src, &dst).unwrap() else {
            panic!()
        };
        assert!(!m.contains_key("x"));
        assert_eq!(m["y"], "s1");
    }

    #[test]
    fn removal_vs_modification_is_conflict() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[]); // removed
        let dst = commit(&[("x", "s1")]); // modified
        assert!(compute_merge(&base, &src, &dst).is_err());
    }

    #[test]
    fn no_src_change_reports_already_merged() {
        let base = commit(&[("x", "s0")]);
        let src = commit(&[("x", "s0")]);
        let dst = commit(&[("x", "s1"), ("y", "s2")]);
        assert_eq!(
            compute_merge(&base, &src, &dst).unwrap(),
            MergeOutcome::AlreadyMerged
        );
    }
}
