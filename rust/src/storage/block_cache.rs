//! A byte-budgeted, sharded LRU block cache for object-store reads.
//!
//! The object store is content-addressed, so a cached entry can never go
//! stale — the key *is* the hash of the bytes — and the cache needs no
//! invalidation protocol: entries only ever leave under byte pressure
//! (LRU eviction) or when GC retires the object itself.
//!
//! Entries are `Arc<[u8]>`, so a hit is a refcount bump, not a copy.
//! The budget is split evenly across a fixed number of shards, each
//! behind its own mutex, so concurrent scans don't serialize on one
//! lock. Recency is tracked with a lazy queue: every touch appends a
//! `(key, seq)` slot and bumps the entry's seq; eviction pops from the
//! front and skips slots whose seq no longer matches (stale touches).
//! The queue is compacted when it grows well past the live entry count,
//! so its size stays O(entries) amortized.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const N_SHARDS: usize = 8;
/// Compact a shard's recency queue when it exceeds this multiple of the
/// live entry count (plus slack for small shards).
const QUEUE_SLACK: usize = 4;

struct Entry {
    data: Arc<[u8]>,
    /// Seq of this entry's newest recency-queue slot; older slots are stale.
    seq: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<String, Entry>,
    /// Lazy LRU order: front = least recently touched (modulo stale slots).
    queue: VecDeque<(String, u64)>,
    bytes: usize,
}

/// Point-in-time counters for the cache (see `store.cache_*` metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Reads served from the cache.
    pub hits: u64,
    /// Reads that had to go to the backing store.
    pub misses: u64,
    /// Total bytes evicted under budget pressure (cumulative).
    pub evicted_bytes: u64,
    /// Bytes currently resident.
    pub cached_bytes: u64,
    /// Entries currently resident.
    pub entries: u64,
}

impl CacheStats {
    /// Fraction of reads served from the cache (0.0 when no reads yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded LRU over immutable content-addressed blocks.
pub struct BlockCache {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard byte budget; an entry larger than this is never cached.
    shard_budget: usize,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evicted_bytes: AtomicU64,
}

impl BlockCache {
    /// A cache holding at most `budget_bytes` in total (0 disables
    /// caching entirely: every `get` returns `None` without counting).
    pub fn new(budget_bytes: usize) -> BlockCache {
        BlockCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: budget_bytes.div_euclid(N_SHARDS)
                + usize::from(budget_bytes % N_SHARDS != 0),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evicted_bytes: AtomicU64::new(0),
        }
    }

    /// Whether this cache can hold anything at all.
    pub fn enabled(&self) -> bool {
        self.shard_budget > 0
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        &self.shards[fnv1a64(key.as_bytes()) as usize % N_SHARDS]
    }

    /// Zero-copy lookup; bumps the entry's recency on hit.
    pub fn get(&self, key: &str) -> Option<Arc<[u8]>> {
        if !self.enabled() {
            return None;
        }
        let mut s = self.shard(key).lock().unwrap();
        let seq = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match s.map.get_mut(key) {
            Some(e) => {
                e.seq = seq;
                Some(e.data.clone())
            }
            None => None,
        };
        match hit {
            Some(data) => {
                s.queue.push_back((key.to_string(), seq));
                maybe_compact(&mut s);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(data)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a block, evicting least-recently-touched entries until the
    /// shard fits its budget. Oversized blocks (bigger than a whole
    /// shard's budget) are not cached. Re-inserting a resident key is a
    /// no-op — content addressing guarantees the bytes are identical.
    pub fn insert(&self, key: &str, data: Arc<[u8]>) {
        if !self.enabled() || data.len() > self.shard_budget {
            return;
        }
        let mut s = self.shard(key).lock().unwrap();
        if s.map.contains_key(key) {
            return;
        }
        let seq = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        s.bytes += data.len();
        s.map.insert(key.to_string(), Entry { data, seq });
        s.queue.push_back((key.to_string(), seq));
        while s.bytes > self.shard_budget {
            let (k, slot_seq) = match s.queue.pop_front() {
                Some(front) => front,
                None => break,
            };
            let live = s.map.get(&k).map(|e| e.seq) == Some(slot_seq);
            if live {
                let e = s.map.remove(&k).unwrap();
                s.bytes -= e.data.len();
                self.evicted_bytes.fetch_add(e.data.len() as u64, Ordering::Relaxed);
            }
        }
        maybe_compact(&mut s);
    }

    /// Drop every entry whose key fails `keep`, returning the removed
    /// keys (GC sweep — the store may need to retire backing files too).
    /// Not counted in `evicted_bytes`: this is correctness, not budget
    /// pressure.
    pub fn retain<F: Fn(&str) -> bool>(&self, keep: F) -> Vec<String> {
        let mut removed = Vec::new();
        for sh in &self.shards {
            let mut s = sh.lock().unwrap();
            let dead: Vec<String> = s.map.keys().filter(|k| !keep(k)).cloned().collect();
            for k in dead {
                if let Some(e) = s.map.remove(&k) {
                    s.bytes -= e.data.len();
                }
                removed.push(k);
            }
        }
        removed
    }

    /// Drop a block (object-store GC retired it). No-op if absent.
    pub fn remove(&self, key: &str) {
        if !self.enabled() {
            return;
        }
        let mut s = self.shard(key).lock().unwrap();
        if let Some(e) = s.map.remove(key) {
            s.bytes -= e.data.len();
        }
    }

    /// Current counters (cheap: sums shard occupancy under the locks).
    pub fn stats(&self) -> CacheStats {
        let mut cached_bytes = 0u64;
        let mut entries = 0u64;
        for sh in &self.shards {
            let s = sh.lock().unwrap();
            cached_bytes += s.bytes as u64;
            entries += s.map.len() as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evicted_bytes: self.evicted_bytes.load(Ordering::Relaxed),
            cached_bytes,
            entries,
        }
    }
}

fn maybe_compact(s: &mut Shard) {
    if s.queue.len() > QUEUE_SLACK * s.map.len() + 16 {
        let live: Vec<(String, u64)> = s
            .queue
            .drain(..)
            .filter(|(k, seq)| s.map.get(k).map(|e| e.seq) == Some(*seq))
            .collect();
        s.queue.extend(live);
    }
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, fill: u8) -> Arc<[u8]> {
        Arc::from(vec![fill; n])
    }

    /// Keys that land in the same shard, so per-shard LRU is observable.
    fn colliding_keys(n: usize) -> Vec<String> {
        let mut out = Vec::new();
        let mut i = 0u64;
        while out.len() < n {
            let k = format!("key-{i}");
            if fnv1a64(k.as_bytes()) as usize % N_SHARDS == 0 {
                out.push(k);
            }
            i += 1;
        }
        out
    }

    #[test]
    fn hit_and_miss_counters() {
        let c = BlockCache::new(1 << 20);
        assert!(c.get("absent").is_none());
        c.insert("k", blob(100, 7));
        assert_eq!(&*c.get("k").unwrap(), &[7u8; 100][..]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.cached_bytes, 100);
        assert_eq!(s.entries, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn evicts_least_recently_used_within_budget() {
        // Per-shard budget fits two 100-byte blobs; third insert evicts.
        let c = BlockCache::new(N_SHARDS * 250);
        let ks = colliding_keys(3);
        c.insert(&ks[0], blob(100, 0));
        c.insert(&ks[1], blob(100, 1));
        assert!(c.get(&ks[0]).is_some()); // ks[0] is now most recent
        c.insert(&ks[2], blob(100, 2));
        assert!(c.get(&ks[1]).is_none(), "LRU entry evicted");
        assert!(c.get(&ks[0]).is_some(), "recently-touched entry kept");
        assert!(c.get(&ks[2]).is_some());
        let s = c.stats();
        assert_eq!(s.evicted_bytes, 100);
        assert!(s.cached_bytes <= 250);
    }

    #[test]
    fn oversized_blocks_are_not_cached() {
        let c = BlockCache::new(N_SHARDS * 64);
        c.insert("big", blob(65, 0));
        assert!(c.get("big").is_none());
        assert_eq!(c.stats().cached_bytes, 0);
    }

    #[test]
    fn zero_budget_disables_everything() {
        let c = BlockCache::new(0);
        assert!(!c.enabled());
        c.insert("k", blob(10, 0));
        assert!(c.get("k").is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn remove_frees_bytes() {
        let c = BlockCache::new(1 << 20);
        c.insert("k", blob(100, 0));
        c.remove("k");
        c.remove("k"); // absent: no-op
        assert!(c.get("k").is_none());
        assert_eq!(c.stats().cached_bytes, 0);
    }

    #[test]
    fn queue_stays_bounded_under_repeated_touches() {
        let c = BlockCache::new(1 << 20);
        c.insert("k", blob(10, 0));
        for _ in 0..10_000 {
            assert!(c.get("k").is_some());
        }
        let s = c.shards[fnv1a64(b"k") as usize % N_SHARDS].lock().unwrap();
        assert!(
            s.queue.len() <= QUEUE_SLACK * s.map.len() + 17,
            "queue len {} not compacted",
            s.queue.len()
        );
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = Arc::new(BlockCache::new(N_SHARDS * 1000));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    let k = format!("t{t}-{i}");
                    c.insert(&k, blob(50, t as u8));
                    let _ = c.get(&k);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = c.stats();
        assert!(s.cached_bytes <= (N_SHARDS * 1000) as u64);
        assert!(s.hits > 0);
    }
}
