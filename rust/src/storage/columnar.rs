//! In-memory columnar batches — the tables pipelines exchange.
//!
//! A [`Batch`] is a set of equal-length columns plus a row-validity mask
//! (fixed-shape PJRT executables force padding; the mask marks real rows).
//! Nullable columns additionally carry a per-value null mask, mirroring
//! the paper's `UNION(str, None)` contract type. A [`Table`] is a list of
//! batches plus the logical schema name it claims to satisfy — the claim
//! is *checked*, not trusted, by the worker's M3 validation.

use crate::contracts::types::LogicalType;
use crate::error::{BauplanError, Result};

/// Physical column payload. The compute layer is f32/i32-only (PJRT CPU
/// artifacts); strings are dictionary-encoded to i32 codes upstream.
#[derive(Debug, Clone, PartialEq)]
pub enum ColumnData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl ColumnData {
    pub fn len(&self) -> usize {
        match self {
            ColumnData::F32(v) => v.len(),
            ColumnData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn logical_type(&self) -> LogicalType {
        match self {
            ColumnData::F32(_) => LogicalType::Float,
            ColumnData::I32(_) => LogicalType::Int,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            ColumnData::F32(v) => Ok(v),
            _ => Err(BauplanError::Codec("expected f32 column".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            ColumnData::I32(v) => Ok(v),
            _ => Err(BauplanError::Codec("expected i32 column".into())),
        }
    }

    /// Lossless view as f32 for validation kernels (i32 values are exact
    /// in f32 up to 2^24, enough for dictionary codes and small ints).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            ColumnData::F32(v) => v.clone(),
            ColumnData::I32(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }
}

/// A named column: payload + optional null mask (1.0 = NULL at that row).
#[derive(Debug, Clone, PartialEq)]
pub struct Column {
    pub name: String,
    pub data: ColumnData,
    /// Per-row null indicator; `None` means the column is non-nullable.
    pub nulls: Option<Vec<f32>>,
}

impl Column {
    pub fn f32(name: &str, data: Vec<f32>) -> Column {
        Column { name: name.into(), data: ColumnData::F32(data), nulls: None }
    }

    pub fn i32(name: &str, data: Vec<i32>) -> Column {
        Column { name: name.into(), data: ColumnData::I32(data), nulls: None }
    }

    pub fn with_nulls(mut self, nulls: Vec<f32>) -> Column {
        self.nulls = Some(nulls);
        self
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn null_count(&self) -> usize {
        self.nulls
            .as_ref()
            .map(|m| m.iter().filter(|&&x| x >= 1.0).count())
            .unwrap_or(0)
    }
}

/// One fixed-width batch: columns of equal length + row validity mask.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub columns: Vec<Column>,
    /// 1.0 = real row, 0.0 = padding. Length equals every column's length.
    pub valid: Vec<f32>,
}

impl Batch {
    pub fn new(columns: Vec<Column>, valid: Vec<f32>) -> Result<Batch> {
        let n = valid.len();
        for c in &columns {
            if c.len() != n {
                return Err(BauplanError::Codec(format!(
                    "column '{}' length {} != batch length {n}",
                    c.name,
                    c.len()
                )));
            }
            if let Some(m) = &c.nulls {
                if m.len() != n {
                    return Err(BauplanError::Codec(format!(
                        "null mask of '{}' length {} != batch length {n}",
                        c.name,
                        m.len()
                    )));
                }
            }
        }
        Ok(Batch { columns, valid })
    }

    /// Number of physical rows (incl. padding).
    pub fn width(&self) -> usize {
        self.valid.len()
    }

    /// Number of real (valid) rows.
    pub fn row_count(&self) -> usize {
        self.valid.iter().filter(|&&v| v > 0.0).count()
    }

    pub fn column(&self, name: &str) -> Result<&Column> {
        self.columns
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| BauplanError::Codec(format!("no column '{name}'")))
    }

    pub fn column_names(&self) -> Vec<&str> {
        self.columns.iter().map(|c| c.name.as_str()).collect()
    }

    /// Pad (or reject) to exactly `n` physical rows: the PJRT artifacts
    /// have static shapes, so the worker normalizes every batch.
    pub fn padded_to(&self, n: usize) -> Result<Batch> {
        if self.width() > n {
            return Err(BauplanError::Codec(format!(
                "batch width {} exceeds target {n}",
                self.width()
            )));
        }
        if self.width() == n {
            return Ok(self.clone());
        }
        let pad = n - self.width();
        let columns = self
            .columns
            .iter()
            .map(|c| {
                let data = match &c.data {
                    ColumnData::F32(v) => {
                        let mut v = v.clone();
                        v.extend(std::iter::repeat(0.0).take(pad));
                        ColumnData::F32(v)
                    }
                    ColumnData::I32(v) => {
                        let mut v = v.clone();
                        v.extend(std::iter::repeat(0).take(pad));
                        ColumnData::I32(v)
                    }
                };
                let nulls = c.nulls.as_ref().map(|m| {
                    let mut m = m.clone();
                    m.extend(std::iter::repeat(1.0).take(pad));
                    m
                });
                Column { name: c.name.clone(), data, nulls }
            })
            .collect();
        let mut valid = self.valid.clone();
        valid.extend(std::iter::repeat(0.0).take(pad));
        Ok(Batch { columns, valid })
    }
}

/// A logical table: ordered batches + the schema it claims to satisfy.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub schema_name: String,
    pub batches: Vec<Batch>,
}

impl Table {
    pub fn new(schema_name: &str, batches: Vec<Batch>) -> Table {
        Table { schema_name: schema_name.into(), batches }
    }

    pub fn row_count(&self) -> usize {
        self.batches.iter().map(|b| b.row_count()).sum()
    }

    pub fn batch_count(&self) -> usize {
        self.batches.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_batch() -> Batch {
        Batch::new(
            vec![
                Column::f32("a", vec![1.0, 2.0, 3.0]),
                Column::i32("b", vec![10, 20, 30]),
            ],
            vec![1.0, 1.0, 0.0],
        )
        .unwrap()
    }

    #[test]
    fn batch_checks_lengths() {
        let err = Batch::new(
            vec![Column::f32("a", vec![1.0])],
            vec![1.0, 1.0],
        );
        assert!(err.is_err());
    }

    #[test]
    fn null_mask_length_checked() {
        let err = Batch::new(
            vec![Column::f32("a", vec![1.0, 2.0]).with_nulls(vec![0.0])],
            vec![1.0, 1.0],
        );
        assert!(err.is_err());
    }

    #[test]
    fn row_count_respects_validity() {
        assert_eq!(small_batch().row_count(), 2);
        assert_eq!(small_batch().width(), 3);
    }

    #[test]
    fn padding_extends_with_invalid_rows() {
        let b = small_batch().padded_to(8).unwrap();
        assert_eq!(b.width(), 8);
        assert_eq!(b.row_count(), 2);
        assert_eq!(b.column("a").unwrap().len(), 8);
        // over-padding rejected
        assert!(small_batch().padded_to(2).is_err());
    }

    #[test]
    fn nullable_column_counts_nulls() {
        let c = Column::f32("x", vec![1.0, 2.0, 3.0]).with_nulls(vec![0.0, 1.0, 1.0]);
        assert_eq!(c.null_count(), 2);
    }

    #[test]
    fn i32_column_converts_to_f32_for_validation() {
        let c = ColumnData::I32(vec![1, -2, 3]);
        assert_eq!(c.to_f32_vec(), vec![1.0, -2.0, 3.0]);
    }
}
