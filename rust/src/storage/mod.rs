//! Storage substrate: a content-addressed object store (the paper's S3
//! stand-in) and the columnar batch format pipelines exchange (the
//! parquet stand-in).
//!
//! Substitution note (DESIGN.md): the transactional-branch protocol only
//! requires (a) immutable, content-addressed data objects and (b) atomic
//! compare-and-swap on refs — which is exactly what S3 + an Iceberg
//! catalog give real Bauplan. `ObjectStore` provides (a) with an optional
//! injected latency so cost *ratios* (metadata ops vs data I/O) match the
//! paper's setting; the catalog provides (b).

pub mod block_cache;
pub mod object_store;
pub mod columnar;
pub mod codec;

pub use block_cache::{BlockCache, CacheStats};
pub use codec::BatchStats;
pub use columnar::{Batch, Column, ColumnData, Table};
pub use object_store::{valid_object_key, ObjectStore, StoreStats};
