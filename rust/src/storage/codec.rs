//! Binary (de)serialization of columnar batches — the parquet stand-in.
//!
//! Layout (little-endian, length-prefixed everywhere):
//!
//! ```text
//! magic "BPB1" | n_rows u32 | n_cols u32
//! valid mask: n_rows f32
//! per column:
//!   name_len u32 | name bytes | dtype u8 (0=f32, 1=i32) |
//!   has_nulls u8 | payload n_rows x 4 bytes | [null mask n_rows f32]
//! ```
//!
//! Objects produced here are immutable once PUT into the object store, so
//! a snapshot is fully described by its content address — the property
//! both copy-on-write branching and dedup rely on.

use crate::error::{BauplanError, Result};
use crate::storage::columnar::{Batch, Column, ColumnData};

const MAGIC: &[u8; 4] = b"BPB1";

/// Serialize a batch to bytes.
pub fn encode_batch(b: &Batch) -> Vec<u8> {
    let n = b.width();
    let mut out = Vec::with_capacity(16 + n * 4 * (b.columns.len() + 1));
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(b.columns.len() as u32).to_le_bytes());
    for v in &b.valid {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for c in &b.columns {
        out.extend_from_slice(&(c.name.len() as u32).to_le_bytes());
        out.extend_from_slice(c.name.as_bytes());
        match &c.data {
            ColumnData::F32(v) => {
                out.push(0);
                out.push(c.nulls.is_some() as u8);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            ColumnData::I32(v) => {
                out.push(1);
                out.push(c.nulls.is_some() as u8);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        if let Some(m) = &c.nulls {
            for x in m {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(BauplanError::Codec("truncated batch".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Deserialize a batch from bytes produced by [`encode_batch`].
pub fn decode_batch(bytes: &[u8]) -> Result<Batch> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        return Err(BauplanError::Codec("bad magic".into()));
    }
    let n = r.u32()? as usize;
    let n_cols = r.u32()? as usize;
    if n > 1 << 28 || n_cols > 1 << 16 {
        return Err(BauplanError::Codec("implausible batch header".into()));
    }
    let valid = r.f32s(n)?;
    let mut columns = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        let name_len = r.u32()? as usize;
        if name_len > 4096 {
            return Err(BauplanError::Codec("implausible column name".into()));
        }
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| BauplanError::Codec("bad utf8 column name".into()))?;
        let dtype = r.u8()?;
        let has_nulls = r.u8()? != 0;
        let data = match dtype {
            0 => ColumnData::F32(r.f32s(n)?),
            1 => ColumnData::I32(r.i32s(n)?),
            d => return Err(BauplanError::Codec(format!("bad dtype {d}"))),
        };
        let nulls = if has_nulls { Some(r.f32s(n)?) } else { None };
        columns.push(Column { name, data, nulls });
    }
    if r.i != bytes.len() {
        return Err(BauplanError::Codec("trailing bytes in batch".into()));
    }
    Batch::new(columns, valid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{for_cases, Rng};

    fn roundtrip(b: &Batch) {
        let bytes = encode_batch(b);
        let back = decode_batch(&bytes).unwrap();
        assert_eq!(&back, b);
    }

    #[test]
    fn empty_batch_roundtrips() {
        roundtrip(&Batch::new(vec![], vec![]).unwrap());
    }

    #[test]
    fn mixed_batch_roundtrips() {
        let b = Batch::new(
            vec![
                Column::f32("f", vec![1.5, -2.5, f32::MIN_POSITIVE]),
                Column::i32("i", vec![i32::MIN, 0, i32::MAX]),
                Column::f32("n", vec![0.0, 1.0, 2.0]).with_nulls(vec![1.0, 0.0, 1.0]),
            ],
            vec![1.0, 0.0, 1.0],
        )
        .unwrap();
        roundtrip(&b);
    }

    #[test]
    fn rejects_corruption() {
        let b = Batch::new(
            vec![Column::f32("a", vec![1.0, 2.0])],
            vec![1.0, 1.0],
        )
        .unwrap();
        let mut bytes = encode_batch(&b);
        assert!(decode_batch(&bytes[..bytes.len() - 2]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(decode_batch(&bytes).is_err()); // bad magic
        assert!(decode_batch(&[]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let b = Batch::new(vec![], vec![]).unwrap();
        let mut bytes = encode_batch(&b);
        bytes.push(0);
        assert!(decode_batch(&bytes).is_err());
    }

    #[test]
    fn property_random_batches_roundtrip() {
        for_cases(50, |rng: &mut Rng| {
            let n = rng.below(64);
            let n_cols = rng.below(6);
            let mut cols = Vec::new();
            for ci in 0..n_cols {
                let name = format!("c{ci}");
                let mut col = if rng.bool(0.5) {
                    Column::f32(&name, (0..n).map(|_| rng.f32() * 100.0).collect())
                } else {
                    Column::i32(&name, (0..n).map(|_| rng.range(-1000, 1000) as i32).collect())
                };
                if rng.bool(0.3) {
                    let nulls: Vec<f32> =
                        (0..n).map(|_| if rng.bool(0.2) { 1.0 } else { 0.0 }).collect();
                    col = col.with_nulls(nulls);
                }
                cols.push(col);
            }
            let valid = (0..n).map(|_| if rng.bool(0.9) { 1.0 } else { 0.0 }).collect();
            let b = Batch::new(cols, valid).unwrap();
            let back = decode_batch(&encode_batch(&b)).unwrap();
            assert_eq!(back, b);
        });
    }
}
