//! Binary (de)serialization of columnar batches — the parquet stand-in.
//!
//! Layout (little-endian, length-prefixed everywhere):
//!
//! ```text
//! magic "BPB2" | n_rows u32 | n_cols u32
//! valid mask: n_rows f32
//! per column:
//!   name_len u32 | name bytes | dtype u8 (0=f32, 1=i32) |
//!   has_nulls u8 | payload n_rows x 4 bytes | [null mask n_rows f32]
//! zone-map footer:
//!   n_cols u32 | n_rows u32 | n_valid u32
//!   per column:
//!     name_len u32 | name bytes | min f32 | max f32 |
//!     null_count u32 | value_count u32
//! trailer: footer_len u32 | magic "ZMS1"
//! ```
//!
//! `BPB2` appends a per-column min/max/null-count footer (the zone map)
//! to the unchanged `BPB1` body; the trailer is fixed-size so
//! [`decode_stats`] can parse the footer from the tail of the object
//! without touching the row payload. `BPB1` objects (no footer) still
//! decode — they simply carry no stats, which reads as "unprunable".
//!
//! Zone-map semantics are dictated by the kernel the stats serve
//! (`filter_project_cast`'s `[lo, hi]` range filter, which consults only
//! the physical f32 value and the batch valid mask — never per-column
//! null masks): `min`/`max` cover the f32 value of **every** valid row,
//! including null-marked ones, and exclude NaN (NaN never passes
//! `x >= lo`). `value_count` is the number of valid non-NaN rows; when it
//! is zero no row can pass any range filter. `null_count` (valid rows
//! whose null mask is set) is informational. i32 columns are summarized
//! over `v as f32` — exactly the conversion the kernel sees.
//!
//! Objects produced here are immutable once PUT into the object store, so
//! a snapshot is fully described by its content address — the property
//! both copy-on-write branching and dedup rely on.

use crate::error::{BauplanError, Result};
use crate::storage::columnar::{Batch, Column, ColumnData};

const MAGIC_V1: &[u8; 4] = b"BPB1";
const MAGIC_V2: &[u8; 4] = b"BPB2";
const STATS_MAGIC: &[u8; 4] = b"ZMS1";
/// Trailer = footer_len u32 + stats magic.
const TRAILER_LEN: usize = 8;

/// Per-column zone-map entry: the range summary pruning consults.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnZone {
    /// Column name (matches the body column in the same position).
    pub name: String,
    /// Minimum f32 value over valid non-NaN rows (+inf when none).
    pub min: f32,
    /// Maximum f32 value over valid non-NaN rows (-inf when none).
    pub max: f32,
    /// Valid rows whose null mask is set (informational).
    pub null_count: u32,
    /// Valid non-NaN rows — zero means no row can pass a range filter.
    pub value_count: u32,
}

/// Batch-level zone map: what a scan can learn without decoding rows.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchStats {
    /// Physical row count (padded width) of the batch.
    pub n_rows: u32,
    /// Rows with `valid > 0.0`.
    pub n_valid: u32,
    /// One zone entry per column, in body column order.
    pub columns: Vec<ColumnZone>,
}

impl BatchStats {
    /// Can any row of column `col` pass the range filter `[lo, hi]`?
    ///
    /// `false` is a *proof* that the filter zeroes every row (safe to
    /// skip decoding); `true` means "maybe". Unknown columns return
    /// `true` (conservative). A NaN or inverted bound matches nothing —
    /// `x >= lo && x <= hi` is false for every x — so it prunes.
    pub fn can_match_range(&self, col: usize, lo: f32, hi: f32) -> bool {
        if !(lo <= hi) {
            return false;
        }
        match self.columns.get(col) {
            Some(c) => c.value_count > 0 && c.max >= lo && c.min <= hi,
            None => true,
        }
    }
}

/// Compute the zone map [`encode_batch`] embeds in the footer.
pub fn compute_stats(b: &Batch) -> BatchStats {
    let n = b.width();
    let n_valid = b.valid.iter().filter(|v| **v > 0.0).count() as u32;
    let columns = b
        .columns
        .iter()
        .map(|c| {
            let mut min = f32::INFINITY;
            let mut max = f32::NEG_INFINITY;
            let mut null_count = 0u32;
            let mut value_count = 0u32;
            for i in 0..n {
                if b.valid[i] <= 0.0 {
                    continue;
                }
                if let Some(m) = &c.nulls {
                    if m[i] > 0.0 {
                        null_count += 1;
                    }
                }
                let x = match &c.data {
                    ColumnData::F32(v) => v[i],
                    ColumnData::I32(v) => v[i] as f32,
                };
                if x.is_nan() {
                    continue;
                }
                value_count += 1;
                min = min.min(x);
                max = max.max(x);
            }
            ColumnZone { name: c.name.clone(), min, max, null_count, value_count }
        })
        .collect();
    BatchStats { n_rows: n as u32, n_valid, columns }
}

/// Serialize a batch to bytes (always the current `BPB2` layout).
pub fn encode_batch(b: &Batch) -> Vec<u8> {
    let n = b.width();
    let mut out = Vec::with_capacity(16 + n * 4 * (b.columns.len() + 1));
    out.extend_from_slice(MAGIC_V2);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(b.columns.len() as u32).to_le_bytes());
    for v in &b.valid {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for c in &b.columns {
        out.extend_from_slice(&(c.name.len() as u32).to_le_bytes());
        out.extend_from_slice(c.name.as_bytes());
        match &c.data {
            ColumnData::F32(v) => {
                out.push(0);
                out.push(c.nulls.is_some() as u8);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            ColumnData::I32(v) => {
                out.push(1);
                out.push(c.nulls.is_some() as u8);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        if let Some(m) = &c.nulls {
            for x in m {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let stats = compute_stats(b);
    let footer_start = out.len();
    out.extend_from_slice(&(stats.columns.len() as u32).to_le_bytes());
    out.extend_from_slice(&stats.n_rows.to_le_bytes());
    out.extend_from_slice(&stats.n_valid.to_le_bytes());
    for z in &stats.columns {
        out.extend_from_slice(&(z.name.len() as u32).to_le_bytes());
        out.extend_from_slice(z.name.as_bytes());
        out.extend_from_slice(&z.min.to_le_bytes());
        out.extend_from_slice(&z.max.to_le_bytes());
        out.extend_from_slice(&z.null_count.to_le_bytes());
        out.extend_from_slice(&z.value_count.to_le_bytes());
    }
    let footer_len = (out.len() - footer_start) as u32;
    out.extend_from_slice(&footer_len.to_le_bytes());
    out.extend_from_slice(STATS_MAGIC);
    out
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(BauplanError::Codec("truncated batch".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Parse the zone-map footer body (everything between the row payload
/// and the trailer).
fn read_footer(r: &mut Reader) -> Result<BatchStats> {
    let n_cols = r.u32()? as usize;
    if n_cols > 1 << 16 {
        return Err(BauplanError::Codec("implausible stats footer".into()));
    }
    let n_rows = r.u32()?;
    let n_valid = r.u32()?;
    let mut columns = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        let name_len = r.u32()? as usize;
        if name_len > 4096 {
            return Err(BauplanError::Codec("implausible column name".into()));
        }
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| BauplanError::Codec("bad utf8 column name".into()))?;
        let min = r.f32()?;
        let max = r.f32()?;
        let null_count = r.u32()?;
        let value_count = r.u32()?;
        columns.push(ColumnZone { name, min, max, null_count, value_count });
    }
    Ok(BatchStats { n_rows, n_valid, columns })
}

/// Read the zone map from an encoded object's tail without decoding the
/// row payload. `None` for `BPB1` objects (no footer — unprunable) and
/// for anything malformed: absence of stats is always a safe answer, so
/// this never errors.
pub fn decode_stats(bytes: &[u8]) -> Option<BatchStats> {
    if bytes.len() < 4 + TRAILER_LEN || &bytes[..4] != MAGIC_V2 {
        return None;
    }
    let tail = bytes.len() - TRAILER_LEN;
    if &bytes[tail + 4..] != STATS_MAGIC {
        return None;
    }
    let footer_len = u32::from_le_bytes(bytes[tail..tail + 4].try_into().unwrap()) as usize;
    let footer_start = tail.checked_sub(footer_len)?;
    if footer_start < 4 {
        return None;
    }
    let mut r = Reader { b: &bytes[footer_start..tail], i: 0 };
    let stats = read_footer(&mut r).ok()?;
    if r.i != footer_len {
        return None;
    }
    Some(stats)
}

/// Deserialize a batch from bytes produced by [`encode_batch`] — either
/// the current `BPB2` layout or legacy `BPB1` (no zone-map footer).
pub fn decode_batch(bytes: &[u8]) -> Result<Batch> {
    let mut r = Reader { b: bytes, i: 0 };
    let magic = r.take(4)?;
    let has_footer = match magic {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => return Err(BauplanError::Codec("bad magic".into())),
    };
    let n = r.u32()? as usize;
    let n_cols = r.u32()? as usize;
    if n > 1 << 28 || n_cols > 1 << 16 {
        return Err(BauplanError::Codec("implausible batch header".into()));
    }
    let valid = r.f32s(n)?;
    let mut columns = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        let name_len = r.u32()? as usize;
        if name_len > 4096 {
            return Err(BauplanError::Codec("implausible column name".into()));
        }
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| BauplanError::Codec("bad utf8 column name".into()))?;
        let dtype = r.u8()?;
        let has_nulls = r.u8()? != 0;
        let data = match dtype {
            0 => ColumnData::F32(r.f32s(n)?),
            1 => ColumnData::I32(r.i32s(n)?),
            d => return Err(BauplanError::Codec(format!("bad dtype {d}"))),
        };
        let nulls = if has_nulls { Some(r.f32s(n)?) } else { None };
        columns.push(Column { name, data, nulls });
    }
    if has_footer {
        let footer_start = r.i;
        let stats = read_footer(&mut r)?;
        if stats.n_rows as usize != n || stats.columns.len() != n_cols {
            return Err(BauplanError::Codec("stats footer disagrees with body".into()));
        }
        let footer_len = r.u32()? as usize;
        if footer_len != r.i - 4 - footer_start {
            return Err(BauplanError::Codec("bad stats footer length".into()));
        }
        if r.take(4)? != STATS_MAGIC {
            return Err(BauplanError::Codec("bad stats trailer magic".into()));
        }
    }
    if r.i != bytes.len() {
        return Err(BauplanError::Codec("trailing bytes in batch".into()));
    }
    Batch::new(columns, valid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{for_cases, Rng};

    fn roundtrip(b: &Batch) {
        let bytes = encode_batch(b);
        let back = decode_batch(&bytes).unwrap();
        assert_eq!(&back, b);
    }

    /// Strip the BPB2 footer+trailer and rewrite the magic: exactly the
    /// bytes the v1 encoder produced for the same batch.
    fn encode_v1(b: &Batch) -> Vec<u8> {
        let mut v = encode_batch(b);
        let tail = v.len() - TRAILER_LEN;
        let footer_len =
            u32::from_le_bytes(v[tail..tail + 4].try_into().unwrap()) as usize;
        v.truncate(tail - footer_len);
        v[..4].copy_from_slice(MAGIC_V1);
        v
    }

    #[test]
    fn empty_batch_roundtrips() {
        roundtrip(&Batch::new(vec![], vec![]).unwrap());
    }

    #[test]
    fn mixed_batch_roundtrips() {
        let b = Batch::new(
            vec![
                Column::f32("f", vec![1.5, -2.5, f32::MIN_POSITIVE]),
                Column::i32("i", vec![i32::MIN, 0, i32::MAX]),
                Column::f32("n", vec![0.0, 1.0, 2.0]).with_nulls(vec![1.0, 0.0, 1.0]),
            ],
            vec![1.0, 0.0, 1.0],
        )
        .unwrap();
        roundtrip(&b);
    }

    #[test]
    fn legacy_bpb1_still_decodes() {
        let b = Batch::new(
            vec![
                Column::f32("f", vec![1.0, 2.0, 3.0]),
                Column::i32("i", vec![-7, 0, 7]).with_nulls(vec![0.0, 1.0, 0.0]),
            ],
            vec![1.0, 1.0, 0.0],
        )
        .unwrap();
        let v1 = encode_v1(&b);
        assert_eq!(&v1[..4], b"BPB1");
        assert_eq!(decode_batch(&v1).unwrap(), b);
        assert!(decode_stats(&v1).is_none(), "v1 carries no zone map");
    }

    #[test]
    fn bpb1_wire_bytes_pinned() {
        // Hand-built v1 object: one f32 column "a" = [1.0], valid [1.0].
        // Pins the legacy layout byte for byte so a footer-era refactor
        // cannot silently break old objects.
        let mut v = Vec::new();
        v.extend_from_slice(b"BPB1");
        v.extend_from_slice(&1u32.to_le_bytes()); // n_rows
        v.extend_from_slice(&1u32.to_le_bytes()); // n_cols
        v.extend_from_slice(&1.0f32.to_le_bytes()); // valid
        v.extend_from_slice(&1u32.to_le_bytes()); // name_len
        v.extend_from_slice(b"a");
        v.push(0); // dtype f32
        v.push(0); // no nulls
        v.extend_from_slice(&1.0f32.to_le_bytes()); // payload
        let b = decode_batch(&v).unwrap();
        assert_eq!(b, Batch::new(vec![Column::f32("a", vec![1.0])], vec![1.0]).unwrap());
    }

    #[test]
    fn stats_decode_from_tail_matches_compute() {
        let b = Batch::new(
            vec![
                Column::f32("f", vec![3.0, -1.0, 9.0, 4.0]),
                Column::i32("i", vec![10, 20, 30, 40]).with_nulls(vec![0.0, 1.0, 0.0, 0.0]),
            ],
            vec![1.0, 1.0, 0.0, 1.0],
        )
        .unwrap();
        let bytes = encode_batch(&b);
        let s = decode_stats(&bytes).expect("BPB2 carries stats");
        assert_eq!(s, compute_stats(&b));
        assert_eq!(s.n_rows, 4);
        assert_eq!(s.n_valid, 3);
        // row 2 is invalid: f covers {3.0, -1.0, 4.0}, i covers {10, 20, 40}
        assert_eq!((s.columns[0].min, s.columns[0].max), (-1.0, 4.0));
        assert_eq!((s.columns[1].min, s.columns[1].max), (10.0, 40.0));
        assert_eq!(s.columns[1].null_count, 1);
        assert_eq!(s.columns[1].value_count, 3);
    }

    #[test]
    fn stats_exclude_nan_and_handle_all_invalid() {
        let b = Batch::new(
            vec![Column::f32("f", vec![f32::NAN, 2.0, 5.0])],
            vec![1.0, 1.0, 0.0],
        )
        .unwrap();
        let s = compute_stats(&b);
        assert_eq!((s.columns[0].min, s.columns[0].max), (2.0, 2.0));
        assert_eq!(s.columns[0].value_count, 1);

        let dead = Batch::new(vec![Column::f32("f", vec![1.0, 2.0])], vec![0.0, 0.0]).unwrap();
        let sd = compute_stats(&dead);
        assert_eq!(sd.columns[0].value_count, 0);
        assert!(!sd.can_match_range(0, f32::NEG_INFINITY, f32::INFINITY));
    }

    #[test]
    fn can_match_range_semantics() {
        let b = Batch::new(
            vec![Column::f32("f", vec![10.0, 20.0, 30.0])],
            vec![1.0, 1.0, 1.0],
        )
        .unwrap();
        let s = compute_stats(&b);
        assert!(s.can_match_range(0, 15.0, 25.0)); // overlaps
        assert!(s.can_match_range(0, 30.0, 99.0)); // touches max
        assert!(!s.can_match_range(0, 31.0, 99.0)); // above
        assert!(!s.can_match_range(0, -9.0, 9.0)); // below
        assert!(!s.can_match_range(0, 25.0, 15.0)); // inverted: matches nothing
        assert!(!s.can_match_range(0, f32::NAN, 1.0)); // NaN bound: matches nothing
        assert!(s.can_match_range(9, 0.0, 0.0), "unknown column is conservative");
    }

    #[test]
    fn rejects_corruption() {
        let b = Batch::new(
            vec![Column::f32("a", vec![1.0, 2.0])],
            vec![1.0, 1.0],
        )
        .unwrap();
        let mut bytes = encode_batch(&b);
        assert!(decode_batch(&bytes[..bytes.len() - 2]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(decode_batch(&bytes).is_err()); // bad magic
        assert!(decode_batch(&[]).is_err());
    }

    #[test]
    fn corrupt_footer_rejected_by_decode_ignored_by_stats() {
        let b = Batch::new(vec![Column::f32("a", vec![1.0])], vec![1.0]).unwrap();
        let good = encode_batch(&b);

        let mut bad_trailer = good.clone();
        let len = bad_trailer.len();
        bad_trailer[len - 1] = b'X'; // break the ZMS1 magic
        assert!(decode_batch(&bad_trailer).is_err());
        assert!(decode_stats(&bad_trailer).is_none());

        let mut bad_len = good.clone();
        let tail = bad_len.len() - TRAILER_LEN;
        bad_len[tail..tail + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_batch(&bad_len).is_err());
        assert!(decode_stats(&bad_len).is_none());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let b = Batch::new(vec![], vec![]).unwrap();
        let mut bytes = encode_batch(&b);
        bytes.push(0);
        assert!(decode_batch(&bytes).is_err());
        let mut v1 = encode_v1(&b);
        v1.push(0);
        assert!(decode_batch(&v1).is_err());
    }

    #[test]
    fn property_random_batches_roundtrip() {
        for_cases(50, |rng: &mut Rng| {
            let n = rng.below(64);
            let n_cols = rng.below(6);
            let mut cols = Vec::new();
            for ci in 0..n_cols {
                let name = format!("c{ci}");
                let mut col = if rng.bool(0.5) {
                    Column::f32(&name, (0..n).map(|_| rng.f32() * 100.0).collect())
                } else {
                    Column::i32(&name, (0..n).map(|_| rng.range(-1000, 1000) as i32).collect())
                };
                if rng.bool(0.3) {
                    let nulls: Vec<f32> =
                        (0..n).map(|_| if rng.bool(0.2) { 1.0 } else { 0.0 }).collect();
                    col = col.with_nulls(nulls);
                }
                cols.push(col);
            }
            let valid = (0..n).map(|_| if rng.bool(0.9) { 1.0 } else { 0.0 }).collect();
            let b = Batch::new(cols, valid).unwrap();

            // v2 roundtrips, and its tail stats agree with compute_stats
            let bytes = encode_batch(&b);
            assert_eq!(decode_batch(&bytes).unwrap(), b);
            assert_eq!(decode_stats(&bytes).unwrap(), compute_stats(&b));

            // the same batch as legacy v1 decodes identically
            assert_eq!(decode_batch(&encode_v1(&b)).unwrap(), b);
        });
    }
}
