//! Content-addressed immutable object store — the S3 of this lakehouse.
//!
//! PUT computes the object key from the bytes (sha256): objects are
//! immutable and deduplicated by construction, which is what makes
//! branches zero-copy (paper §3.2: "merge operations are only logical
//! changes, linking physical parquet files to a new branch, without data
//! duplication"). An injectable per-op latency models remote storage for
//! the E5 overhead experiment.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Duration;

use crate::error::{BauplanError, Result};
use crate::util::id::content_hash;

/// Is `key` a well-formed object name, safe to join to the lake
/// directory? Keys the store mints itself are lowercase hex, but keys
/// can also arrive from *untrusted* inputs — imported exports, replayed
/// journals, and (since the API server exists) network clients — so
/// every path that touches the filesystem validates first. The rule is
/// an allowlist, which rejects every traversal shape at once: no
/// separators (hence no absolute paths and no empty segments), no `.`
/// or `..` (no char for them), no NULs, bounded length.
pub fn valid_object_key(key: &str) -> bool {
    !key.is_empty()
        && key.len() <= 256
        && key.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
}

/// Counters for the §Perf accounting: how many ops / bytes the protocol
/// actually moves (metadata vs data).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub bytes_put: AtomicU64,
    pub bytes_get: AtomicU64,
    pub dedup_hits: AtomicU64,
    /// PUTs whose disk backing failed (object retained in memory only).
    /// Non-zero means the durability guarantee is degraded — the commit
    /// journal may reference objects that exist only in this process.
    pub disk_write_failures: AtomicU64,
}

impl StoreStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.puts.load(Ordering::Relaxed),
            self.gets.load(Ordering::Relaxed),
            self.bytes_put.load(Ordering::Relaxed),
            self.bytes_get.load(Ordering::Relaxed),
            self.dedup_hits.load(Ordering::Relaxed),
        )
    }
}

/// Thread-safe, content-addressed, immutable blob store.
///
/// Optionally disk-backed (`ObjectStore::on_disk`): every PUT is also
/// written to `<dir>/<hash>` and GETs fall through to disk on a memory
/// miss — which is how a persisted lake reopens (see `catalog::persist`).
pub struct ObjectStore {
    objects: RwLock<HashMap<String, Vec<u8>>>,
    /// Simulated per-operation latency (0 by default; benches raise it to
    /// model remote object storage).
    latency: Duration,
    /// Disk backing directory, if persistent.
    disk: Option<std::path::PathBuf>,
    pub stats: StoreStats,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore {
            objects: RwLock::new(HashMap::new()),
            latency: Duration::ZERO,
            disk: None,
            stats: StoreStats::default(),
        }
    }

    /// A store that sleeps `latency` on every op — models S3 round trips.
    pub fn with_latency(latency: Duration) -> ObjectStore {
        ObjectStore { latency, ..ObjectStore::new() }
    }

    /// A disk-backed store rooted at `dir` (created if missing). Objects
    /// already on disk are readable immediately (lazy loading).
    pub fn on_disk(dir: impl Into<std::path::PathBuf>) -> Result<ObjectStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(ObjectStore { disk: Some(dir), ..ObjectStore::new() })
    }

    fn simulate_latency(&self) {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
    }

    /// Store `data`, returning its content address. Idempotent: re-putting
    /// identical bytes is a dedup hit and does not copy.
    pub fn put(&self, data: Vec<u8>) -> String {
        self.simulate_latency();
        let key = content_hash(&data);
        debug_assert!(valid_object_key(&key), "content_hash minted an invalid key");
        let mut map = self.objects.write().unwrap();
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        if map.contains_key(&key) {
            self.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.bytes_put.fetch_add(data.len() as u64, Ordering::Relaxed);
            if let Some(dir) = &self.disk {
                // Content-addressed, write-once. Synced before PUT returns:
                // the commit journal fsyncs records that reference this key,
                // so the bytes must not outlive it only in the page cache.
                if persist_object(dir, &key, &data).is_err() {
                    self.stats.disk_write_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
            map.insert(key.clone(), data);
        }
        key
    }

    /// Fetch a blob by content address (falling back to disk backing).
    pub fn get(&self, key: &str) -> Result<Vec<u8>> {
        self.simulate_latency();
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        if !valid_object_key(key) {
            // refuse before any filesystem join — a traversal key must
            // not even produce a path
            return Err(BauplanError::ObjectNotFound(format!("invalid object key {key:?}")));
        }
        {
            let map = self.objects.read().unwrap();
            if let Some(d) = map.get(key) {
                self.stats.bytes_get.fetch_add(d.len() as u64, Ordering::Relaxed);
                return Ok(d.clone());
            }
        }
        if let Some(dir) = &self.disk {
            if let Ok(data) = std::fs::read(dir.join(key)) {
                self.stats.bytes_get.fetch_add(data.len() as u64, Ordering::Relaxed);
                self.objects.write().unwrap().insert(key.to_string(), data.clone());
                return Ok(data);
            }
        }
        Err(BauplanError::ObjectNotFound(key.to_string()))
    }

    pub fn contains(&self, key: &str) -> bool {
        if !valid_object_key(key) {
            return false;
        }
        self.objects.read().unwrap().contains_key(key)
            || self
                .disk
                .as_ref()
                .map(|d| d.join(key).exists())
                .unwrap_or(false)
    }

    /// Drop every object whose key is not in `live` (GC sweep). Returns
    /// (objects_removed, bytes_reclaimed).
    pub fn retain(&self, live: &std::collections::HashSet<String>) -> (usize, u64) {
        let mut map = self.objects.write().unwrap();
        let mut removed = 0;
        let mut bytes = 0;
        map.retain(|k, v| {
            if live.contains(k) {
                true
            } else {
                removed += 1;
                bytes += v.len() as u64;
                if let Some(dir) = &self.disk {
                    let _ = std::fs::remove_file(dir.join(k));
                }
                false
            }
        });
        (removed, bytes)
    }

    /// Size in bytes of one object without copying it out (run-cache
    /// byte accounting). Falls back to disk metadata on a memory miss.
    pub fn object_size(&self, key: &str) -> Option<u64> {
        if !valid_object_key(key) {
            return None;
        }
        if let Some(d) = self.objects.read().unwrap().get(key) {
            return Some(d.len() as u64);
        }
        self.disk
            .as_ref()
            .and_then(|dir| std::fs::metadata(dir.join(key)).ok())
            .map(|m| m.len())
    }

    pub fn len(&self) -> usize {
        self.objects.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total stored bytes (post-dedup) — the "physical lake size".
    pub fn stored_bytes(&self) -> u64 {
        self.objects.read().unwrap().values().map(|v| v.len() as u64).sum()
    }
}

/// Write one object durably: temp file → write → fsync → rename (the
/// same discipline the catalog's checkpoint files use). A key already
/// on disk is immutable by content addressing — skip it.
fn persist_object(dir: &std::path::Path, key: &str, data: &[u8]) -> std::io::Result<()> {
    let path = dir.join(key);
    if path.exists() {
        return Ok(());
    }
    let tmp = dir.join(format!("{key}.tmp"));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(data)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, &path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::new();
        let key = s.put(vec![1, 2, 3]);
        assert_eq!(s.get(&key).unwrap(), vec![1, 2, 3]);
        assert!(s.contains(&key));
    }

    #[test]
    fn content_addressing_dedups() {
        let s = ObjectStore::new();
        let k1 = s.put(vec![9; 100]);
        let k2 = s.put(vec![9; 100]);
        assert_eq!(k1, k2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats.dedup_hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.stored_bytes(), 100);
        assert_eq!(s.object_size(&k1), Some(100));
        assert_eq!(s.object_size("missing"), None);
    }

    #[test]
    fn key_validation_rejects_traversal_shapes() {
        // minted keys are valid
        let s = ObjectStore::new();
        let k = s.put(vec![1, 2, 3]);
        assert!(valid_object_key(&k));
        // each rejection class from the hardening checklist:
        assert!(!valid_object_key(""), "empty key");
        assert!(!valid_object_key("."), "current dir");
        assert!(!valid_object_key(".."), "parent traversal");
        assert!(!valid_object_key("../../etc/passwd"), "relative traversal");
        assert!(!valid_object_key("/etc/passwd"), "absolute path");
        assert!(!valid_object_key("a//b"), "empty segment");
        assert!(!valid_object_key("a/b"), "separator");
        assert!(!valid_object_key("a\\b"), "windows separator");
        assert!(!valid_object_key("a\0b"), "NUL byte");
        assert!(!valid_object_key("k.tmp"), "dot (tmp-file collision)");
        assert!(!valid_object_key(&"x".repeat(300)), "over-long key");
    }

    #[test]
    fn invalid_keys_never_touch_disk_reads() {
        let dir = std::env::temp_dir().join(format!("bpl_keyval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::on_disk(&dir).unwrap();
        let k = s.put(vec![9; 16]);
        assert!(s.contains(&k));
        // traversal keys are refused on every read path, not resolved
        for bad in ["../escape", "/abs", "a/../b", "..", ""] {
            assert!(matches!(s.get(bad), Err(BauplanError::ObjectNotFound(_))), "{bad}");
            assert!(!s.contains(bad), "{bad}");
            assert_eq!(s.object_size(bad), None, "{bad}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_object_errors() {
        let s = ObjectStore::new();
        assert!(matches!(
            s.get("deadbeef"),
            Err(BauplanError::ObjectNotFound(_))
        ));
    }

    #[test]
    fn concurrent_puts_are_safe() {
        let s = std::sync::Arc::new(ObjectStore::new());
        let mut handles = vec![];
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = s.put(vec![t as u8, i as u8]);
                    assert!(s.get(&key).is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8 * 50);
    }
}
