//! Content-addressed immutable object store — the S3 of this lakehouse.
//!
//! PUT computes the object key from the bytes (sha256): objects are
//! immutable and deduplicated by construction, which is what makes
//! branches zero-copy (paper §3.2: "merge operations are only logical
//! changes, linking physical parquet files to a new branch, without data
//! duplication"). An injectable per-op latency models remote storage for
//! the E5 overhead experiment.
//!
//! Reads go through a byte-budgeted LRU [`BlockCache`] and return
//! `Arc<[u8]>`: a hit is a refcount bump that skips the simulated
//! storage round trip entirely (the warm-scan path), and no call site
//! ever gets a private copy of the bytes. Content addressing makes the
//! cache trivially coherent — see `storage/block_cache.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::error::{BauplanError, Result};
use crate::storage::block_cache::{BlockCache, CacheStats};
use crate::util::id::content_hash;

/// Default block-cache budget: plenty for every test/bench table while
/// still exercising eviction on multi-GB lakes.
const DEFAULT_CACHE_BUDGET: usize = 256 << 20;

/// Is `key` a well-formed object name, safe to join to the lake
/// directory? Keys the store mints itself are lowercase hex, but keys
/// can also arrive from *untrusted* inputs — imported exports, replayed
/// journals, and (since the API server exists) network clients — so
/// every path that touches the filesystem validates first. The rule is
/// an allowlist, which rejects every traversal shape at once: no
/// separators (hence no absolute paths and no empty segments), no `.`
/// or `..` (no char for them), no NULs, bounded length.
pub fn valid_object_key(key: &str) -> bool {
    !key.is_empty()
        && key.len() <= 256
        && key.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
}

/// Counters for the §Perf accounting: how many ops / bytes the protocol
/// actually moves (metadata vs data).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub bytes_put: AtomicU64,
    pub bytes_get: AtomicU64,
    pub dedup_hits: AtomicU64,
    /// PUTs whose disk backing failed (object retained in memory only).
    /// Non-zero means the durability guarantee is degraded — the commit
    /// journal may reference objects that exist only in this process.
    pub disk_write_failures: AtomicU64,
}

impl StoreStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.puts.load(Ordering::Relaxed),
            self.gets.load(Ordering::Relaxed),
            self.bytes_put.load(Ordering::Relaxed),
            self.bytes_get.load(Ordering::Relaxed),
            self.dedup_hits.load(Ordering::Relaxed),
        )
    }
}

/// Thread-safe, content-addressed, immutable blob store.
///
/// Optionally disk-backed (`ObjectStore::on_disk`): every PUT is also
/// written to `<dir>/<hash>` and GETs fall through to disk on a memory
/// miss — which is how a persisted lake reopens (see `catalog::persist`).
/// Disk reads are promoted into the block cache (bounded), not the
/// resident object map (unbounded), so a scan over a lake bigger than
/// memory stays bounded.
pub struct ObjectStore {
    objects: RwLock<HashMap<String, Arc<[u8]>>>,
    cache: BlockCache,
    /// Simulated per-operation latency (0 by default; benches raise it to
    /// model remote object storage).
    latency: Duration,
    /// Disk backing directory, if persistent.
    disk: Option<std::path::PathBuf>,
    pub stats: StoreStats,
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore {
            objects: RwLock::new(HashMap::new()),
            cache: BlockCache::new(DEFAULT_CACHE_BUDGET),
            latency: Duration::ZERO,
            disk: None,
            stats: StoreStats::default(),
        }
    }

    /// A store that sleeps `latency` on every op — models S3 round trips.
    /// Block-cache hits skip the sleep: that *is* the point of the cache.
    pub fn with_latency(latency: Duration) -> ObjectStore {
        ObjectStore { latency, ..ObjectStore::new() }
    }

    /// A disk-backed store rooted at `dir` (created if missing). Objects
    /// already on disk are readable immediately (lazy loading).
    pub fn on_disk(dir: impl Into<std::path::PathBuf>) -> Result<ObjectStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(ObjectStore { disk: Some(dir), ..ObjectStore::new() })
    }

    /// Replace the block cache with one holding at most `bytes`
    /// (0 disables caching — every read pays the full storage path;
    /// the cold-scan baseline in `bench_scan`).
    pub fn with_cache_budget(mut self, bytes: usize) -> ObjectStore {
        self.cache = BlockCache::new(bytes);
        self
    }

    /// Block-cache counters (`store.cache_*` metrics, `/metrics` hit-rate).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    fn simulate_latency(&self) {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
    }

    /// Store `data`, returning its content address. Idempotent: re-putting
    /// identical bytes is a dedup hit and does not copy.
    pub fn put(&self, data: Vec<u8>) -> String {
        self.simulate_latency();
        let key = content_hash(&data);
        debug_assert!(valid_object_key(&key), "content_hash minted an invalid key");
        let mut map = self.objects.write().unwrap();
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        if map.contains_key(&key) {
            self.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.bytes_put.fetch_add(data.len() as u64, Ordering::Relaxed);
            if let Some(dir) = &self.disk {
                // Content-addressed, write-once. Synced before PUT returns:
                // the commit journal fsyncs records that reference this key,
                // so the bytes must not outlive it only in the page cache.
                if persist_object(dir, &key, &data).is_err() {
                    self.stats.disk_write_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
            let data: Arc<[u8]> = Arc::from(data);
            self.cache.insert(&key, data.clone());
            map.insert(key.clone(), data);
        }
        key
    }

    /// Fetch a blob by content address (falling back to disk backing).
    /// Zero-copy: the returned handle shares the stored allocation.
    pub fn get(&self, key: &str) -> Result<Arc<[u8]>> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        if !valid_object_key(key) {
            // refuse before any filesystem join — a traversal key must
            // not even produce a path
            return Err(BauplanError::ObjectNotFound(format!("invalid object key {key:?}")));
        }
        if let Some(d) = self.cache.get(key) {
            self.stats.bytes_get.fetch_add(d.len() as u64, Ordering::Relaxed);
            return Ok(d);
        }
        self.simulate_latency();
        {
            let map = self.objects.read().unwrap();
            if let Some(d) = map.get(key) {
                self.stats.bytes_get.fetch_add(d.len() as u64, Ordering::Relaxed);
                self.cache.insert(key, d.clone());
                return Ok(d.clone());
            }
        }
        if let Some(dir) = &self.disk {
            if let Ok(data) = std::fs::read(dir.join(key)) {
                let data: Arc<[u8]> = Arc::from(data);
                self.stats.bytes_get.fetch_add(data.len() as u64, Ordering::Relaxed);
                self.cache.insert(key, data.clone());
                return Ok(data);
            }
        }
        Err(BauplanError::ObjectNotFound(key.to_string()))
    }

    pub fn contains(&self, key: &str) -> bool {
        if !valid_object_key(key) {
            return false;
        }
        self.objects.read().unwrap().contains_key(key)
            || self
                .disk
                .as_ref()
                .map(|d| d.join(key).exists())
                .unwrap_or(false)
    }

    /// Drop every object whose key is not in `live` (GC sweep). Returns
    /// (objects_removed, bytes_reclaimed).
    pub fn retain(&self, live: &std::collections::HashSet<String>) -> (usize, u64) {
        // Purge dead cache entries first: a disk-promoted object may live
        // only in the cache, and its backing file must go too.
        for k in self.cache.retain(|k| live.contains(k)) {
            if let Some(dir) = &self.disk {
                let _ = std::fs::remove_file(dir.join(&k));
            }
        }
        let mut map = self.objects.write().unwrap();
        let mut removed = 0;
        let mut bytes = 0;
        map.retain(|k, v| {
            if live.contains(k) {
                true
            } else {
                removed += 1;
                bytes += v.len() as u64;
                if let Some(dir) = &self.disk {
                    let _ = std::fs::remove_file(dir.join(k));
                }
                false
            }
        });
        (removed, bytes)
    }

    /// Size in bytes of one object without copying it out (run-cache
    /// byte accounting). Falls back to disk metadata on a memory miss.
    pub fn object_size(&self, key: &str) -> Option<u64> {
        if !valid_object_key(key) {
            return None;
        }
        if let Some(d) = self.objects.read().unwrap().get(key) {
            return Some(d.len() as u64);
        }
        self.disk
            .as_ref()
            .and_then(|dir| std::fs::metadata(dir.join(key)).ok())
            .map(|m| m.len())
    }

    pub fn len(&self) -> usize {
        self.objects.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total stored bytes (post-dedup) — the "physical lake size".
    pub fn stored_bytes(&self) -> u64 {
        self.objects.read().unwrap().values().map(|v| v.len() as u64).sum()
    }
}

/// Write one object durably: temp file → write → fsync → rename (the
/// same discipline the catalog's checkpoint files use). A key already
/// on disk is immutable by content addressing — skip it.
fn persist_object(dir: &std::path::Path, key: &str, data: &[u8]) -> std::io::Result<()> {
    let path = dir.join(key);
    if path.exists() {
        return Ok(());
    }
    let tmp = dir.join(format!("{key}.tmp"));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(data)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, &path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::new();
        let key = s.put(vec![1, 2, 3]);
        assert_eq!(&*s.get(&key).unwrap(), &[1u8, 2, 3][..]);
        assert!(s.contains(&key));
    }

    #[test]
    fn content_addressing_dedups() {
        let s = ObjectStore::new();
        let k1 = s.put(vec![9; 100]);
        let k2 = s.put(vec![9; 100]);
        assert_eq!(k1, k2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats.dedup_hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.stored_bytes(), 100);
        assert_eq!(s.object_size(&k1), Some(100));
        assert_eq!(s.object_size("missing"), None);
    }

    #[test]
    fn get_returns_shared_handle_and_hits_cache() {
        let s = ObjectStore::new();
        let key = s.put(vec![7; 64]);
        let a = s.get(&key).unwrap();
        let b = s.get(&key).unwrap();
        // both handles share one allocation — zero-copy reads
        assert!(Arc::ptr_eq(&a, &b));
        let cs = s.cache_stats();
        assert!(cs.hits >= 2, "PUT write-through makes every read a hit");
        assert_eq!(cs.misses, 0);
    }

    #[test]
    fn zero_budget_cache_still_reads_correctly() {
        let s = ObjectStore::new().with_cache_budget(0);
        let key = s.put(vec![5; 32]);
        assert_eq!(&*s.get(&key).unwrap(), &[5u8; 32][..]);
        assert_eq!(s.cache_stats().entries, 0);
    }

    #[test]
    fn disk_reads_promote_into_cache_not_resident_map() {
        let dir = std::env::temp_dir().join(format!("bpl_diskcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let key = {
            let s = ObjectStore::on_disk(&dir).unwrap();
            s.put(vec![3; 128])
        };
        // reopened store: memory map empty, object only on disk
        let s = ObjectStore::on_disk(&dir).unwrap();
        assert_eq!(s.len(), 0);
        assert_eq!(&*s.get(&key).unwrap(), &[3u8; 128][..]);
        assert_eq!(s.len(), 0, "disk promotion is bounded by the cache budget");
        assert_eq!(s.cache_stats().entries, 1);
        assert!(s.get(&key).is_ok());
        assert!(s.cache_stats().hits >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_purges_cache_and_disk() {
        let dir = std::env::temp_dir().join(format!("bpl_gccache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::on_disk(&dir).unwrap();
        let key = s.put(vec![1; 16]);
        assert!(s.get(&key).is_ok());
        let (removed, bytes) = s.retain(&std::collections::HashSet::new());
        assert_eq!((removed, bytes), (1, 16));
        assert_eq!(s.cache_stats().entries, 0);
        assert!(matches!(s.get(&key), Err(BauplanError::ObjectNotFound(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_validation_rejects_traversal_shapes() {
        // minted keys are valid
        let s = ObjectStore::new();
        let k = s.put(vec![1, 2, 3]);
        assert!(valid_object_key(&k));
        // each rejection class from the hardening checklist:
        assert!(!valid_object_key(""), "empty key");
        assert!(!valid_object_key("."), "current dir");
        assert!(!valid_object_key(".."), "parent traversal");
        assert!(!valid_object_key("../../etc/passwd"), "relative traversal");
        assert!(!valid_object_key("/etc/passwd"), "absolute path");
        assert!(!valid_object_key("a//b"), "empty segment");
        assert!(!valid_object_key("a/b"), "separator");
        assert!(!valid_object_key("a\\b"), "windows separator");
        assert!(!valid_object_key("a\0b"), "NUL byte");
        assert!(!valid_object_key("k.tmp"), "dot (tmp-file collision)");
        assert!(!valid_object_key(&"x".repeat(300)), "over-long key");
    }

    #[test]
    fn invalid_keys_never_touch_disk_reads() {
        let dir = std::env::temp_dir().join(format!("bpl_keyval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::on_disk(&dir).unwrap();
        let k = s.put(vec![9; 16]);
        assert!(s.contains(&k));
        // traversal keys are refused on every read path, not resolved
        for bad in ["../escape", "/abs", "a/../b", "..", ""] {
            assert!(matches!(s.get(bad), Err(BauplanError::ObjectNotFound(_))), "{bad}");
            assert!(!s.contains(bad), "{bad}");
            assert_eq!(s.object_size(bad), None, "{bad}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_object_errors() {
        let s = ObjectStore::new();
        assert!(matches!(
            s.get("deadbeef"),
            Err(BauplanError::ObjectNotFound(_))
        ));
    }

    #[test]
    fn concurrent_puts_are_safe() {
        let s = std::sync::Arc::new(ObjectStore::new());
        let mut handles = vec![];
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = s.put(vec![t as u8, i as u8]);
                    assert!(s.get(&key).is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8 * 50);
    }
}
