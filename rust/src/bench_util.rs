//! Tiny benchmark harness (criterion is not in the offline crate set).
//!
//! Usage in a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = bench_util::Bench::new("branch_create");
//! b.run("create 1 branch", || { ... });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to exceed a
//! minimum measurement window; mean / p50 / p99 over per-iteration times
//! are printed as aligned rows so bench output doubles as the paper's
//! table reproduction.

use std::time::{Duration, Instant};

/// One measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

/// A named group of measurements.
pub struct Bench {
    pub group: String,
    pub warmup_iters: u64,
    pub min_window: Duration,
    pub max_iters: u64,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench {
            group: group.into(),
            warmup_iters: 3,
            min_window: Duration::from_millis(200),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Quick-mode constructor for expensive end-to-end cases.
    pub fn heavy(group: &str) -> Bench {
        Bench {
            warmup_iters: 1,
            min_window: Duration::from_millis(50),
            max_iters: 50,
            ..Bench::new(group)
        }
    }

    /// Measure `f` and record under `name`. Returns the measurement.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times: Vec<Duration> = Vec::new();
        let window_start = Instant::now();
        while times.len() < 2
            || (window_start.elapsed() < self.min_window
                && (times.len() as u64) < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort();
        let iters = times.len() as u64;
        let mean = times.iter().sum::<Duration>() / iters as u32;
        let p50 = times[times.len() / 2];
        let p99 = times[(times.len() as f64 * 0.99) as usize % times.len()];
        let m = Measurement { name: name.into(), iters, mean, p50, p99 };
        println!(
            "  {:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p99 {:>12?}",
            m.name, m.iters, m.mean, m.p50, m.p99
        );
        self.results.push(m.clone());
        m
    }

    /// Print the group header; call before the first `run`.
    pub fn header(&self) {
        println!("\n=== bench: {} ===", self.group);
    }

    /// Final summary (machine-greppable `BENCH` lines).
    pub fn report(&self) {
        for m in &self.results {
            println!(
                "BENCH {} | {} | iters={} mean_ns={} p50_ns={} p99_ns={}",
                self.group,
                m.name,
                m.iters,
                m.mean.as_nanos(),
                m.p50.as_nanos(),
                m.p99.as_nanos()
            );
        }
    }
}

/// Black-box: prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// `raw_table -> p0..p{width-1}` — one maximally wide wavefront of
/// independent grouping nodes. Shared by the wavefront-scheduler bench
/// and integration tests so they exercise the same workload.
pub fn wide_pipeline(width: usize) -> crate::dag::PipelineSpec {
    use crate::contracts::schema::SchemaRegistry;
    use crate::dag::{NodeSpec, PipelineSpec};
    let mut spec = PipelineSpec::new("wide", SchemaRegistry::with_paper_schemas())
        .source("raw_table", "RawSchema");
    for i in 0..width {
        spec = spec.node(
            NodeSpec::new(&format!("p{i}"), "ParentSchema", "parent")
                .input("raw_table", "RawSchema"),
        );
    }
    spec
}

/// [`wide_pipeline`] plus a join consuming every middle node — a
/// `width`-wide diamond (two wavefronts). The multi-input join is a
/// scheduling shape planned at the DAG level (`spec.plan()`); the
/// `child` op reads its first input.
pub fn diamond_pipeline(width: usize) -> crate::dag::PipelineSpec {
    use crate::dag::NodeSpec;
    let mut join = NodeSpec::new("join", "ChildSchema", "child")
        .with_params(vec![0.0, 1e6, 0.5, 1.0]);
    for i in 0..width {
        join = join.input(&format!("p{i}"), "ParentSchema");
    }
    wide_pipeline(width).node(join)
}

/// [`wide_pipeline`] rendered as a `.bpln` project text — the form the
/// API server's run endpoint accepts, so the loopback bench submits the
/// same workload the in-process scheduler bench runs.
pub fn wide_pipeline_text(width: usize) -> String {
    let mut text = String::from(
        "pipeline wide\n\n\
         schema RawSchema {\n\
         \x20 col1: str\n\
         \x20 col2: timestamp\n\
         \x20 col3: float in [0, 1e6]\n\
         }\n\n\
         schema ParentSchema {\n\
         \x20 col1: str from RawSchema.col1\n\
         \x20 col2: timestamp from RawSchema.col2\n\
         \x20 _S: float\n\
         }\n\n\
         source raw_table: RawSchema\n\n",
    );
    for i in 0..width {
        text.push_str(&format!(
            "node p{i}: ParentSchema <- raw_table(RawSchema) op=parent\n"
        ));
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_pipeline_text_plans_like_the_builder() {
        let parsed = crate::dag::parser::parse_pipeline(&wide_pipeline_text(3)).unwrap();
        let built = wide_pipeline(3);
        let p1 = parsed.plan().unwrap();
        let p2 = built.plan().unwrap();
        assert_eq!(p1.outputs(), p2.outputs());
        for (a, b) in p1.nodes.iter().zip(p2.nodes.iter()) {
            assert_eq!(a.op, b.op);
        }
    }

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test");
        b.min_window = Duration::from_millis(5);
        let m = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(m.iters >= 2);
        assert!(m.mean > Duration::ZERO);
        assert!(m.p99 >= m.p50);
    }
}
