//! `bauplan` — CLI entrypoint for the correct-by-design lakehouse.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match bauplan::cli::parse_args(&args) {
        Ok(cmd) => bauplan::cli::execute(cmd),
        Err(e) => {
            eprintln!("error: {e}");
            eprint!("{}", bauplan::cli::HELP);
            2
        }
    };
    std::process::exit(code);
}
