//! Textual pipeline project format — the "DAG code folder" as one file.
//!
//! The paper's Listings 3–5 annotate vanilla SQL/Python with schema
//! contracts. Our textual equivalent keeps the same information content
//! in a grammar small enough to parse by hand:
//!
//! ```text
//! pipeline taxi_daily
//!
//! schema RawSchema {
//!   col1: str
//!   col2: timestamp
//!   col3: float in [0, 1e6]
//! }
//!
//! schema ParentSchema {
//!   col1: str from RawSchema.col1
//!   col2: timestamp from RawSchema.col2
//!   _S: float
//! }
//!
//! schema Grand {
//!   col2: timestamp from ChildSchema.col2
//!   col4: int from ChildSchema.col4 cast     # explicit narrowing
//! }
//!
//! source raw_table: RawSchema
//!
//! node parent_table: ParentSchema <- raw_table(RawSchema) op=parent
//! node child_table: ChildSchema <- parent_table(ParentSchema) \
//!     op=child params=[0, 1e6, 0.5, 1.0]
//! ```
//!
//! Field modifiers: `?` suffix on the type for nullable
//! (`col5: float?`), `in [lo, hi]` bounds, `from Schema.col` lineage,
//! `cast` and `notnull` annotations. `#` starts a comment.

use crate::contracts::schema::{Field, Schema, SchemaRegistry};
use crate::contracts::types::{FieldType, LogicalType};
use crate::dag::{NodeSpec, PipelineSpec};
use crate::error::{BauplanError, Result};

/// Parse a pipeline project text into a [`PipelineSpec`].
pub fn parse_pipeline(text: &str) -> Result<PipelineSpec> {
    let mut name = String::from("unnamed");
    let mut registry = SchemaRegistry::new();
    let mut nodes: Vec<NodeSpec> = Vec::new();
    let mut sources: Vec<(String, String)> = Vec::new();

    // Pre-pass: join `\` line continuations, strip comments/blank lines.
    let mut lines: Vec<String> = Vec::new();
    let mut pending = String::new();
    for raw in text.lines() {
        let no_comment = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        };
        let trimmed = no_comment.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(stripped) = trimmed.strip_suffix('\\') {
            pending.push_str(stripped);
            pending.push(' ');
            continue;
        }
        pending.push_str(trimmed);
        lines.push(std::mem::take(&mut pending));
    }
    if !pending.is_empty() {
        lines.push(pending);
    }

    let mut i = 0;
    while i < lines.len() {
        let line = &lines[i];
        if let Some(rest) = line.strip_prefix("pipeline ") {
            name = rest.trim().to_string();
            i += 1;
        } else if let Some(rest) = line.strip_prefix("schema ") {
            let schema_name = rest
                .strip_suffix('{')
                .unwrap_or(rest)
                .trim()
                .to_string();
            if schema_name.is_empty() {
                return Err(BauplanError::Parse(format!("bad schema header: {line}")));
            }
            let mut fields = Vec::new();
            i += 1;
            loop {
                if i >= lines.len() {
                    return Err(BauplanError::Parse(format!(
                        "schema '{schema_name}' not closed")));
                }
                if lines[i] == "}" {
                    i += 1;
                    break;
                }
                fields.push(parse_field(&lines[i])?);
                i += 1;
            }
            registry.register(Schema::new(&schema_name, fields))?;
        } else if let Some(rest) = line.strip_prefix("source ") {
            let (t, s) = rest.split_once(':').ok_or_else(|| {
                BauplanError::Parse(format!("bad source line: {line}"))
            })?;
            sources.push((t.trim().into(), s.trim().into()));
            i += 1;
        } else if let Some(rest) = line.strip_prefix("node ") {
            nodes.push(parse_node(rest)?);
            i += 1;
        } else {
            return Err(BauplanError::Parse(format!("unrecognized line: {line}")));
        }
    }

    let mut spec = PipelineSpec::new(&name, registry);
    for (t, s) in sources {
        spec = spec.source(&t, &s);
    }
    for n in nodes {
        spec = spec.node(n);
    }
    Ok(spec)
}

/// `col4: int from ChildSchema.col4 cast` / `col5: float? in [0, 10]`
fn parse_field(line: &str) -> Result<Field> {
    let (fname, rest) = line.split_once(':').ok_or_else(|| {
        BauplanError::Parse(format!("bad field line: {line}"))
    })?;
    let fname = fname.trim();
    let mut tokens = rest.split_whitespace().peekable();

    let ty_tok = tokens
        .next()
        .ok_or_else(|| BauplanError::Parse(format!("missing type: {line}")))?;
    let (ty_name, nullable) = match ty_tok.strip_suffix('?') {
        Some(t) => (t, true),
        None => (ty_tok, false),
    };
    let logical = LogicalType::parse(ty_name).ok_or_else(|| {
        BauplanError::Parse(format!("unknown type '{ty_name}' in: {line}"))
    })?;
    let mut ty = FieldType::new(logical);
    if nullable {
        ty = ty.nullable();
    }

    let mut field = Field::new(fname, ty);
    while let Some(tok) = tokens.next() {
        match tok {
            "from" => {
                let origin = tokens.next().ok_or_else(|| {
                    BauplanError::Parse(format!("missing lineage target: {line}"))
                })?;
                let (s, c) = origin.split_once('.').ok_or_else(|| {
                    BauplanError::Parse(format!(
                        "lineage must be Schema.column: {line}"))
                })?;
                field = field.inherited(s, c);
            }
            "cast" => field = field.cast(),
            "notnull" => field = field.not_null(),
            "unique" => field = field.unique(),
            "in" => {
                // expect `[lo, hi]` possibly split across tokens
                let mut buf = String::new();
                while let Some(t) = tokens.next() {
                    buf.push_str(t);
                    if t.ends_with(']') {
                        break;
                    }
                }
                let inner = buf
                    .trim_start_matches('[')
                    .trim_end_matches(']');
                let (lo, hi) = inner.split_once(',').ok_or_else(|| {
                    BauplanError::Parse(format!("bad bounds: {line}"))
                })?;
                let lo: f64 = lo.trim().parse().map_err(|_| {
                    BauplanError::Parse(format!("bad bound '{lo}': {line}"))
                })?;
                let hi: f64 = hi.trim().parse().map_err(|_| {
                    BauplanError::Parse(format!("bad bound '{hi}': {line}"))
                })?;
                field.ty = field.ty.clone().bounded(lo, hi);
            }
            other => {
                return Err(BauplanError::Parse(format!(
                    "unknown field modifier '{other}': {line}")));
            }
        }
    }
    Ok(field)
}

/// `parent_table: ParentSchema <- raw_table(RawSchema) op=parent params=[...]`
fn parse_node(rest: &str) -> Result<NodeSpec> {
    let (out, rest) = rest.split_once(':').ok_or_else(|| {
        BauplanError::Parse(format!("bad node line: {rest}"))
    })?;
    let (out_schema, rest) = rest.split_once("<-").ok_or_else(|| {
        BauplanError::Parse(format!("node missing '<-': {rest}"))
    })?;
    // inputs: comma-separated `table(Schema)` until the first `op=`
    let (inputs_part, attrs_part) = match rest.find("op=") {
        Some(i) => (&rest[..i], &rest[i..]),
        None => {
            return Err(BauplanError::Parse(format!("node missing op=: {rest}")));
        }
    };
    let mut node_inputs = Vec::new();
    for piece in inputs_part.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        let (t, s) = piece.split_once('(').ok_or_else(|| {
            BauplanError::Parse(format!("input must be table(Schema): {piece}"))
        })?;
        let s = s.trim_end_matches(')');
        node_inputs.push((t.trim().to_string(), s.trim().to_string()));
    }

    let mut op = String::new();
    let mut params: Vec<f32> = Vec::new();
    let mut rest_attrs = attrs_part.trim();
    while !rest_attrs.is_empty() {
        if let Some(v) = rest_attrs.strip_prefix("op=") {
            let end = v.find(char::is_whitespace).unwrap_or(v.len());
            op = v[..end].to_string();
            rest_attrs = v[end..].trim_start();
        } else if let Some(v) = rest_attrs.strip_prefix("params=[") {
            let close = v.find(']').ok_or_else(|| {
                BauplanError::Parse(format!("params missing ']': {attrs_part}"))
            })?;
            for p in v[..close].split(',') {
                let p = p.trim();
                if p.is_empty() {
                    continue;
                }
                params.push(p.parse().map_err(|_| {
                    BauplanError::Parse(format!("bad param '{p}'"))
                })?);
            }
            rest_attrs = v[close + 1..].trim_start();
        } else {
            return Err(BauplanError::Parse(format!(
                "unknown node attribute '{rest_attrs}'")));
        }
    }
    if op.is_empty() {
        return Err(BauplanError::Parse("node missing op".into()));
    }

    let mut node = NodeSpec::new(out.trim(), out_schema.trim(), &op).with_params(params);
    for (t, s) in node_inputs {
        node = node.input(&t, &s);
    }
    Ok(node)
}

/// The paper pipeline in textual form — used by the CLI quickstart and
/// round-trip tests.
pub const PAPER_PIPELINE_TEXT: &str = r#"
pipeline paper_dag

schema RawSchema {
  col1: str
  col2: timestamp
  col3: float in [0, 1e6]
}

schema ParentSchema {
  col1: str from RawSchema.col1
  col2: timestamp from RawSchema.col2
  _S: float
}

schema ChildSchema {
  col2: timestamp from ParentSchema.col2
  col4: float
  col5: float?
}

schema Grand {
  col2: timestamp from ChildSchema.col2
  col4: int from ChildSchema.col4 cast
}

source raw_table: RawSchema

node parent_table: ParentSchema <- raw_table(RawSchema) op=parent
node child_table: ChildSchema <- parent_table(ParentSchema) \
    op=child params=[0, 1e6, 0.5, 1.0]
node grand_child: Grand <- child_table(ChildSchema) \
    op=grand_child params=[-1e9, 1e9, 1.0, 0.0]
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_pipeline() {
        let spec = parse_pipeline(PAPER_PIPELINE_TEXT).unwrap();
        assert_eq!(spec.name, "paper_dag");
        assert_eq!(spec.nodes.len(), 3);
        assert_eq!(spec.sources.len(), 1);
        let plan = spec.plan().unwrap();
        assert_eq!(plan.outputs(), vec!["parent_table", "child_table", "grand_child"]);
    }

    #[test]
    fn parsed_matches_builder() {
        let parsed = parse_pipeline(PAPER_PIPELINE_TEXT).unwrap();
        let built = PipelineSpec::paper_pipeline();
        let p1 = parsed.plan().unwrap();
        let p2 = built.plan().unwrap();
        assert_eq!(p1.outputs(), p2.outputs());
        for (a, b) in p1.nodes.iter().zip(p2.nodes.iter()) {
            assert_eq!(a.op, b.op);
            assert_eq!(a.params, b.params);
        }
    }

    #[test]
    fn nullable_bounds_and_annotations_parse() {
        let f = parse_field("col5: float? in [0, 10]").unwrap();
        assert!(f.ty.nullable);
        assert_eq!(f.ty.bounds, Some((0.0, 10.0)));
        let f = parse_field("col4: int from ChildSchema.col4 cast").unwrap();
        assert!(f.with_cast);
        assert_eq!(f.inherited_from, Some(("ChildSchema".into(), "col4".into())));
        let f = parse_field("col5: float from ChildSchema.col5 notnull").unwrap();
        assert!(f.not_null_filter);
    }

    #[test]
    fn binary_node_parses() {
        let n = parse_node(
            "friend: FriendSchema <- child_table(ChildSchema), grand_child(Grand) op=family_friend params=[0.5]",
        )
        .unwrap();
        assert_eq!(n.inputs.len(), 2);
        assert_eq!(n.op, "family_friend");
        assert_eq!(n.params, vec![0.5]);
    }

    #[test]
    fn errors_are_parse_errors() {
        assert!(parse_pipeline("nonsense here").is_err());
        assert!(parse_field("no_type_here:").is_err());
        assert!(parse_field("x: decimal").is_err());
        assert!(parse_node("a: B <- c(D)").is_err()); // missing op
        // unclosed schema
        assert!(parse_pipeline("schema X {\n a: int\n").is_err());
    }

    #[test]
    fn comments_and_continuations() {
        let text = "pipeline p # trailing\nsource t: RawSchema\nschema RawSchema {\n x: int # c\n}\n";
        let spec = parse_pipeline(text).unwrap();
        assert_eq!(spec.name, "p");
        assert!(spec.registry.get("RawSchema").is_ok());
    }
}
